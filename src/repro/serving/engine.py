"""Serving engine: continuous batching over a slotted KV cache.

Requests are admitted into free slots (prefill writes that slot's cache
row), every step decodes the whole active batch, finished requests are
evicted and their slots reused — the vLLM-style loop reduced to its
JAX-native essentials (slot-indexed dynamic_update_slice into stacked
caches).  Also drives the *private* (Centaur) serving path for the
paper's own models via core.private_model."""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.registry import get_api


@dataclass
class Request:
    rid: int
    prompt: list            # token ids
    max_new_tokens: int = 16
    out: list = field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.out) >= self.max_new_tokens


class RequestQueue:
    """Shared slot-scheduler plumbing for the serving engines.

    Subclasses provide `slots`, `pos`, `max_len` and `_prefill_into`;
    admission and eviction live here so the plaintext and private
    engines can never drift apart on the rules that keep them
    token-identical (same admit order, same length-cap truncation)."""

    def __init__(self):
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self._rid = itertools.count()

    def submit(self, prompt, max_new_tokens: int = 16) -> int:
        rid = next(self._rid)
        self.queue.append(Request(rid, list(prompt), max_new_tokens))
        return rid

    def _admit(self):
        for i, slot in enumerate(self.slots):
            if slot is None and self.queue:
                req = self.queue.pop(0)
                self._prefill_into(i, req)
                self.slots[i] = req

    def _evict(self):
        for i, s in enumerate(self.slots):
            if s is not None and (s.done
                                  or self.pos[i] >= self.max_len - 1):
                self.finished.append(s)
                self.slots[i] = None


class ServingEngine(RequestQueue):
    """Greedy-decoding continuous-batching server."""

    def __init__(self, cfg: ModelConfig, params, *, max_slots: int = 4,
                 max_len: int = 256):
        super().__init__()
        self.cfg = cfg
        self.params = params
        self.api = get_api(cfg)
        self.max_slots = max_slots
        self.max_len = max_len
        self.slots: list[Request | None] = [None] * max_slots
        self.pos = np.zeros(max_slots, np.int32)
        self.cache = self.api.init_cache(cfg, max_slots, max_len) \
            if self.api.init_cache else None
        self._decode = jax.jit(
            lambda p, c, t, pos: self.api.decode_step(cfg, p, c, t, pos))

    def run_to_completion(self, max_steps: int = 10_000):
        for _ in range(max_steps):
            if not self.step():
                break
        return {r.rid: r.out for r in self.finished}

    # ---- scheduler ----------------------------------------------------------
    def _prefill_into(self, slot: int, req: Request):
        toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
        logits, cache1, pos = self.api.prefill(
            self.cfg, self.params, {"tokens": toks}, max_len=self.max_len)
        # splice the single-request cache into the stacked slot cache
        self.cache = jax.tree.map(
            lambda full, one: jax.lax.dynamic_update_slice_in_dim(
                full, one.astype(full.dtype), slot, axis=1),
            self.cache, cache1)
        self.pos[slot] = pos
        req.out.append(int(jnp.argmax(logits[0])))

    def step(self) -> bool:
        """One scheduler tick: admit, decode the active batch, evict."""
        self._admit()
        # prefill emits a token and may already satisfy the request
        # (max_new_tokens=1) — never decode a finished slot
        self._evict()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return bool(self.queue)
        # uniform position decode (slots padded to max position): we
        # decode each slot at its own pos via per-slot loop when they
        # diverge, batched when aligned
        groups = {}
        for i in active:
            groups.setdefault(int(self.pos[i]), []).append(i)
        for pos, idxs in groups.items():
            toks = jnp.asarray([[self.slots[i].out[-1]] for i in idxs],
                               jnp.int32)
            sub = jax.tree.map(lambda a: a.take(jnp.asarray(idxs), axis=1),
                               self.cache)
            logits, sub = self._decode(self.params, sub, toks, pos)
            for j, i in enumerate(idxs):
                self.cache = jax.tree.map(
                    lambda full, part, j=j, i=i:
                    jax.lax.dynamic_update_slice_in_dim(
                        full, part[:, j:j + 1].astype(full.dtype), i,
                        axis=1),
                    self.cache, sub)
                self.slots[i].out.append(int(jnp.argmax(logits[j])))
                self.pos[i] = pos + 1
        self._evict()
        return True


class PrivateServingEngine(RequestQueue):
    """Continuous-batching greedy server behind any servable PPTI mode.

    The slot engine above, moved into the share domain: requests are
    admitted into free slots (private prefill writes that slot's padded
    share-cache rows), every tick decodes the whole active slot batch
    through ONE jitted batched private step per layer depth
    (core.private_model.private_decode_step with slot-stacked padded KV
    share caches and per-slot position/validity masks), finished
    requests are evicted and their slots reused.  `max_slots=1` is the
    sequential baseline: same code path, batch of one.

    `mode=` picks the protocol suite: "centaur" (the paper) or the
    SMPC baselines ("smpc"/"mpcformer"/"secformer") — all served by the
    same executor, which is what makes the paper's centaur-vs-SMPC
    serving throughput ratio measurable under identical conditions
    (benchmarks/private_serving_bench.py --mode).

    One batched step bills the ambient ledger once for all slots, so
    each tick's events are split across the active requests with
    comm.attribute — exact and sum-conserving, so per-request stats add
    up to the global ledger and a single-slot run bills identically to
    sequential serving.  Prefill runs per request and is billed to that
    request directly.  The model's TriplePool stocks `lookahead` ticks
    of the recurring batched decode shapes ahead of time (one
    vectorized offline dispatch per spec)."""

    def __init__(self, cfg: ModelConfig, params, key, *,
                 mode: str = "centaur", max_slots: int = 4,
                 max_len: int = 256, decode_jit: bool = True,
                 lookahead: int = 4):
        from repro.core import comm as _comm
        from repro.core import private_model as _pm
        assert cfg.family == "dense" and not cfg.use_mla, \
            "private serving covers the dense KV-cache decode path"
        assert mode in ("centaur", "smpc", "mpcformer", "secformer"), \
            f"no share-domain serving path for mode {mode!r}"
        super().__init__()
        self.cfg = cfg
        self.mode = mode
        self.max_slots = max_slots
        self.max_len = max_len
        self.decode_jit = decode_jit
        self.lookahead = lookahead
        self._comm = _comm
        self._pmod = _pm
        self.pm = _pm.build_private_model(cfg, params, key,
                                          mode=mode, use_pool=True)
        self.slots: list[Request | None] = [None] * max_slots
        self.pos = np.zeros(max_slots, np.int32)
        self.caches = _pm.init_slot_caches(self.pm, max_slots, max_len)
        self.stats: dict[int, dict] = {}

    # ---- per-request comm accounting ---------------------------------------
    def _accumulate(self, req: Request, led):
        st = self.stats.setdefault(req.rid, {"rounds": 0,
                                             "online_bits": 0,
                                             "offline_bits": 0,
                                             "tokens": 0})
        st["rounds"] += led.total_rounds()
        st["online_bits"] += led.total_bits()
        st["offline_bits"] += led.total_bits(False) - led.total_bits()
        st["tokens"] = len(req.out)

    # ---- scheduler ----------------------------------------------------------
    def _prefill_into(self, slot: int, req: Request):
        assert len(req.prompt) < self.max_len, "prompt fills the slot"
        toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
        with self._comm.ledger() as led:
            logits, c1 = self._pmod.private_prefill(
                self.pm, toks, max_len=self.max_len,
                jit=self.decode_jit)
        # splice the request's padded share-cache rows into its slot
        self.caches = [
            jax.tree.map(lambda full, one: full.at[slot].set(one[0]),
                         full_l, one_l)
            for full_l, one_l in zip(self.caches, c1)]
        self.pos[slot] = len(req.prompt)
        req.out.append(int(np.argmax(np.asarray(logits)[0])))
        self._accumulate(req, led)

    def step(self) -> bool:
        """One tick: admit, decode the active slot batch, evict."""
        self._admit()
        # prefill emits a token and may already satisfy the request
        # (max_new_tokens=1) — never decode a finished slot
        self._evict()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return bool(self.queue)
        idxs = jnp.asarray(active)
        toks = jnp.asarray([[self.slots[i].out[-1]] for i in active],
                           jnp.int32)
        pos = jnp.asarray(self.pos[active], jnp.int32)
        full_batch = len(active) == self.max_slots  # gather = identity
        sub = self.caches if full_batch else \
            [jax.tree.map(lambda a: a.take(idxs, axis=0), layer)
             for layer in self.caches]
        with self._comm.ledger() as tick:
            logits, sub = self._pmod.private_decode_step(
                self.pm, sub, toks, pos, jit=self.decode_jit,
                lookahead=self.lookahead)
        self.caches = sub if full_batch else [
            jax.tree.map(lambda full, part: full.at[idxs].set(part),
                         full_l, sub_l)
            for full_l, sub_l in zip(self.caches, sub)]
        lg = np.asarray(logits)
        for j, i in enumerate(active):
            self.slots[i].out.append(int(lg[j, 0].argmax()))
            self.pos[i] += 1
        # exact per-request attribution of the batched step's comm
        per = self._comm.attribute(tick.events,
                                   [self.slots[i].rid for i in active])
        for i in active:
            self._accumulate(self.slots[i], per[self.slots[i].rid])
        self._evict()
        return True

    def run_to_completion(self, max_steps: int = 10_000
                          ) -> tuple[dict, dict]:
        """Serve the queue; returns (outputs, per-request comm stats),
        both cumulative over every request this engine has finished."""
        for _ in range(max_steps):
            if not self.step():
                break
        return {r.rid: r.out for r in self.finished}, self.stats
