"""Serving engine: continuous batching over a slotted KV cache.

Requests are admitted into free slots (prefill writes that slot's cache
row), every step decodes the whole active batch, finished requests are
evicted and their slots reused — the vLLM-style loop reduced to its
JAX-native essentials (slot-indexed dynamic_update_slice into stacked
caches).  Also drives the *private* (Centaur) serving path for the
paper's own models via core.private_model."""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.registry import get_api


@dataclass
class Request:
    rid: int
    prompt: list            # token ids
    max_new_tokens: int = 16
    out: list = field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.out) >= self.max_new_tokens


class RequestQueue:
    """Shared request-admission plumbing for the serving engines."""

    def __init__(self):
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self._rid = itertools.count()

    def submit(self, prompt, max_new_tokens: int = 16) -> int:
        rid = next(self._rid)
        self.queue.append(Request(rid, list(prompt), max_new_tokens))
        return rid


class ServingEngine(RequestQueue):
    """Greedy-decoding continuous-batching server."""

    def __init__(self, cfg: ModelConfig, params, *, max_slots: int = 4,
                 max_len: int = 256):
        super().__init__()
        self.cfg = cfg
        self.params = params
        self.api = get_api(cfg)
        self.max_slots = max_slots
        self.max_len = max_len
        self.slots: list[Request | None] = [None] * max_slots
        self.pos = np.zeros(max_slots, np.int32)
        self.cache = self.api.init_cache(cfg, max_slots, max_len) \
            if self.api.init_cache else None
        self._decode = jax.jit(
            lambda p, c, t, pos: self.api.decode_step(cfg, p, c, t, pos))

    def run_to_completion(self, max_steps: int = 10_000):
        for _ in range(max_steps):
            if not self.step():
                break
        return {r.rid: r.out for r in self.finished}

    # ---- scheduler ----------------------------------------------------------
    def _admit(self):
        for i, slot in enumerate(self.slots):
            if slot is None and self.queue:
                req = self.queue.pop(0)
                self._prefill_into(i, req)
                self.slots[i] = req

    def _prefill_into(self, slot: int, req: Request):
        toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
        logits, cache1, pos = self.api.prefill(
            self.cfg, self.params, {"tokens": toks}, max_len=self.max_len)
        # splice the single-request cache into the stacked slot cache
        self.cache = jax.tree.map(
            lambda full, one: jax.lax.dynamic_update_slice_in_dim(
                full, one.astype(full.dtype), slot, axis=1),
            self.cache, cache1)
        self.pos[slot] = pos
        req.out.append(int(jnp.argmax(logits[0])))

    def step(self) -> bool:
        """One scheduler tick: admit, decode the active batch, evict."""
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return False
        # uniform position decode (slots padded to max position): we
        # decode each slot at its own pos via per-slot loop when they
        # diverge, batched when aligned
        groups = {}
        for i in active:
            groups.setdefault(int(self.pos[i]), []).append(i)
        for pos, idxs in groups.items():
            toks = jnp.asarray([[self.slots[i].out[-1]] for i in idxs],
                               jnp.int32)
            sub = jax.tree.map(lambda a: a.take(jnp.asarray(idxs), axis=1),
                               self.cache)
            logits, sub = self._decode(self.params, sub, toks, pos)
            for j, i in enumerate(idxs):
                self.cache = jax.tree.map(
                    lambda full, part, j=j, i=i:
                    jax.lax.dynamic_update_slice_in_dim(
                        full, part[:, j:j + 1].astype(full.dtype), i,
                        axis=1),
                    self.cache, sub)
                self.slots[i].out.append(int(jnp.argmax(logits[j])))
                self.pos[i] = pos + 1
        for i in list(active):
            if self.slots[i].done or self.pos[i] >= self.max_len - 1:
                self.finished.append(self.slots[i])
                self.slots[i] = None
        return True


class PrivateServingEngine(RequestQueue):
    """Greedy-decoding server behind the Centaur protocol.

    Each request runs private prefill then share-state KV-cache decode
    steps (core.private_model).  The model's dealer is a TriplePool
    (one-shot decode shapes generate on demand; recurring shapes are
    batched offline), and the online phase uses the fused block-stacked
    GEMM combine.  Comm is tracked per request so callers can report
    per-token cost like the paper's Fig. 8."""

    def __init__(self, cfg: ModelConfig, params, key, *,
                 max_len: int = 256):
        from repro.core import comm as _comm
        from repro.core import private_model as _pm
        assert cfg.family == "dense" and not cfg.use_mla, \
            "private serving covers the dense KV-cache decode path"
        super().__init__()
        self.cfg = cfg
        self.max_len = max_len
        self._comm = _comm
        self._pmod = _pm
        self.pm = _pm.build_private_model(cfg, params, key,
                                          mode="centaur", use_pool=True)
        self.stats: dict[int, dict] = {}

    def _serve_one(self, req: Request) -> dict:
        pmod = self._pmod
        toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
        with self._comm.ledger() as led:
            logits, caches = pmod.centaur_prefill(self.pm, toks)
            req.out.append(int(np.argmax(np.asarray(logits)[0])))
            while not req.done and \
                    len(req.prompt) + len(req.out) < self.max_len:
                pos = len(req.prompt) + len(req.out) - 1
                logits, caches = pmod.centaur_decode_step(
                    self.pm, caches,
                    jnp.asarray([[req.out[-1]]], jnp.int32), pos)
                req.out.append(int(np.argmax(np.asarray(logits)[0])))
        return {"rounds": led.total_rounds(),
                "online_bits": led.total_bits(),
                "offline_bits": led.total_bits(False) - led.total_bits(),
                "tokens": len(req.out)}

    def run_to_completion(self) -> tuple[dict, dict]:
        """Serve the queue; returns (outputs, per-request comm stats),
        both cumulative over every request this engine has finished."""
        while self.queue:
            req = self.queue.pop(0)
            self.stats[req.rid] = self._serve_one(req)
            self.finished.append(req)
        return {r.rid: r.out for r in self.finished}, self.stats
