"""Serving engine: continuous batching over a slotted KV cache.

Requests are admitted into free slots (prefill writes that slot's cache
row), every step decodes the whole active batch, finished requests are
evicted and their slots reused — the vLLM-style loop reduced to its
JAX-native essentials (slot-indexed dynamic_update_slice into stacked
caches).  Also drives the *private* (Centaur) serving path for the
paper's own models via core.private_model."""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.registry import get_api


@dataclass
class Request:
    rid: int
    prompt: list            # token ids
    max_new_tokens: int = 16
    out: list = field(default_factory=list)
    #: output cut short by slot capacity: the request was evicted at
    #: pos == max_len - 1 before reaching max_new_tokens
    truncated: bool = False
    #: prompt cut to the shared length cap (max_len - 1) at submit time
    prompt_truncated: bool = False

    @property
    def done(self) -> bool:
        return len(self.out) >= self.max_new_tokens


def pow2_buckets(max_len: int, lo: int = 8) -> tuple:
    """Power-of-two prefill length-bucket ladder capped at `max_len`:
    (lo, 2*lo, ..., max_len).  Every admissible prompt (<= max_len - 1
    after the shared cap) fits the last bucket."""
    out, b = [], lo
    while b < max_len:
        out.append(b)
        b *= 2
    out.append(max_len)
    return tuple(out)


class RequestQueue:
    """Shared slot-scheduler plumbing for the serving engines.

    Subclasses provide `slots`, `pos`, `max_len` and `_prefill_into`;
    admission, eviction and the length-cap policy live here so the
    plaintext and private engines can never drift apart on the rules
    that keep them token-identical (same admit order, same length-cap
    truncation)."""

    def __init__(self):
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self._rid = itertools.count()

    def submit(self, prompt, max_new_tokens: int = 16) -> int:
        """Queue a request.  ONE shared length-cap policy for every
        engine: a prompt longer than max_len - 1 is truncated to its
        first max_len - 1 tokens (prefill plus at least one generated
        token must fit the slot), and the request is flagged
        `prompt_truncated` instead of crashing one engine and silently
        overrunning the other."""
        prompt = list(prompt)
        # an empty prompt has no last-real-token to decode from: the
        # exact-length path would crash late and the bucketed path
        # would silently serve a fully-masked garbage hidden state
        assert prompt, "empty prompt"
        rid = next(self._rid)
        cap = self.max_len - 1
        truncated = len(prompt) > cap
        req = Request(rid, prompt[:cap], max_new_tokens,
                      prompt_truncated=truncated)
        self.queue.append(req)
        return rid

    def _admit(self):
        for i, slot in enumerate(self.slots):
            if slot is None and self.queue:
                req = self.queue.pop(0)
                self._prefill_into(i, req)
                self.slots[i] = req

    def _evict(self):
        for i, s in enumerate(self.slots):
            if s is not None and (s.done
                                  or self.pos[i] >= self.max_len - 1):
                # slot-capacity eviction before max_new_tokens is a
                # truncated output — flag it instead of dropping the
                # request silently
                if not s.done:
                    s.truncated = True
                self._on_finish(s)
                self.finished.append(s)
                self.slots[i] = None

    def _on_finish(self, req: Request):
        """Hook: engines surface per-request outcomes (e.g. stats)."""


class ServingEngine(RequestQueue):
    """Greedy-decoding continuous-batching server."""

    def __init__(self, cfg: ModelConfig, params, *, max_slots: int = 4,
                 max_len: int = 256):
        super().__init__()
        self.cfg = cfg
        self.params = params
        self.api = get_api(cfg)
        self.max_slots = max_slots
        self.max_len = max_len
        self.slots: list[Request | None] = [None] * max_slots
        self.pos = np.zeros(max_slots, np.int32)
        self.cache = self.api.init_cache(cfg, max_slots, max_len) \
            if self.api.init_cache else None
        self._decode = jax.jit(
            lambda p, c, t, pos: self.api.decode_step(cfg, p, c, t, pos))

    def run_to_completion(self, max_steps: int = 10_000):
        for _ in range(max_steps):
            if not self.step():
                break
        return {r.rid: r.out for r in self.finished}

    # ---- scheduler ----------------------------------------------------------
    def _prefill_into(self, slot: int, req: Request):
        toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
        logits, cache1, pos = self.api.prefill(
            self.cfg, self.params, {"tokens": toks}, max_len=self.max_len)
        # splice the single-request cache into the stacked slot cache
        self.cache = jax.tree.map(
            lambda full, one: jax.lax.dynamic_update_slice_in_dim(
                full, one.astype(full.dtype), slot, axis=1),
            self.cache, cache1)
        self.pos[slot] = pos
        req.out.append(int(jnp.argmax(logits[0])))

    def step(self) -> bool:
        """One scheduler tick: admit, decode the active batch, evict."""
        self._admit()
        # prefill emits a token and may already satisfy the request
        # (max_new_tokens=1) — never decode a finished slot
        self._evict()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return bool(self.queue)
        # uniform position decode (slots padded to max position): we
        # decode each slot at its own pos via per-slot loop when they
        # diverge, batched when aligned
        groups = {}
        for i in active:
            groups.setdefault(int(self.pos[i]), []).append(i)
        for pos, idxs in groups.items():
            toks = jnp.asarray([[self.slots[i].out[-1]] for i in idxs],
                               jnp.int32)
            sub = jax.tree.map(lambda a: a.take(jnp.asarray(idxs), axis=1),
                               self.cache)
            logits, sub = self._decode(self.params, sub, toks, pos)
            for j, i in enumerate(idxs):
                self.cache = jax.tree.map(
                    lambda full, part, j=j, i=i:
                    jax.lax.dynamic_update_slice_in_dim(
                        full, part[:, j:j + 1].astype(full.dtype), i,
                        axis=1),
                    self.cache, sub)
                self.slots[i].out.append(int(jnp.argmax(logits[j])))
                self.pos[i] = pos + 1
        self._evict()
        return True


class PrivateServingEngine(RequestQueue):
    """Continuous-batching greedy server behind any servable PPTI mode.

    The slot engine above, moved into the share domain: requests are
    admitted into free slots (private prefill writes that slot's padded
    share-cache rows), every tick decodes the whole active slot batch
    through ONE jitted batched private step per layer depth
    (core.private_model.private_decode_step with slot-stacked padded KV
    share caches and per-slot position/validity masks), finished
    requests are evicted and their slots reused.  `max_slots=1` is the
    sequential baseline: same code path, batch of one.

    `mode=` picks the protocol suite: "centaur" (the paper) or the
    SMPC baselines ("smpc"/"mpcformer"/"secformer") — all served by the
    same executor, which is what makes the paper's centaur-vs-SMPC
    serving throughput ratio measurable under identical conditions
    (benchmarks/private_serving_bench.py --mode).

    One batched step bills the ambient ledger once for all slots, so
    each tick's events are split across the active requests with
    comm.attribute — exact and sum-conserving, so per-request stats add
    up to the global ledger and a single-slot (max_slots=1) run bills
    identically to sequential serving.  Note the tick is always the
    FULL slot width (see `step`), so at partial occupancy the dummy
    rows' very real protocol traffic is amortized over the active
    requests — per-request bits are occupancy-dependent, exactly like
    bucketed prefill bills the padded bucket's S^2: padding cost is
    billed to whoever the padding serves, never dropped.  Prefill runs
    per request and is billed to that request directly.  The model's TriplePool stocks `lookahead` ticks
    of the recurring batched decode shapes ahead of time (one
    vectorized offline dispatch per spec).

    `buckets` keys the compiled-program budget under mixed-length
    traffic: None (the exact-length escape hatch) prefills at true
    prompt length — one compiled program and one S^2 comm bill per
    distinct length; "pow2" or an explicit ladder pads each prompt to
    the smallest bucket >= its length, so the engine compiles at most
    len(buckets) prefill programs + 1 decode program no matter how
    lengths mix (`compile_stats()` verifies), at the cost of billing
    the padded bucket's S^2 attention comm.

    `chunk_size=C` replaces bucketing (pass `buckets=None`): a prompt
    of any length is consumed as ceil(len/C) fixed-shape chunks run
    against the slot cache (DESIGN.md §10) — ONE compiled chunk
    program + 1 decode program under arbitrary length mixes, and the
    long-prompt comm bill drops below the bucket ladder's padded S^2
    (the amortized chunk-cache protocol opens each K/V row once and
    reuses one π1 per request per layer).  The tail chunk is padded to
    C with masked dead tokens; each chunk tick is billed to its
    request as it runs.  `max_len` must be a multiple of C so the last
    chunk of a capped prompt still fits the padded cache."""

    def __init__(self, cfg: ModelConfig, params, key, *,
                 mode: str = "centaur", max_slots: int = 4,
                 max_len: int = 256, decode_jit: bool = True,
                 lookahead: int = 4, buckets=None,
                 chunk_size: int | None = None):
        from repro.core import comm as _comm
        from repro.core import private_model as _pm
        assert cfg.family == "dense" and not cfg.use_mla, \
            "private serving covers the dense KV-cache decode path"
        assert mode in ("centaur", "smpc", "mpcformer", "secformer"), \
            f"no share-domain serving path for mode {mode!r}"
        super().__init__()
        self.cfg = cfg
        self.mode = mode
        self.max_slots = max_slots
        self.max_len = max_len
        self.decode_jit = decode_jit
        self.lookahead = lookahead
        if chunk_size is not None:
            chunk_size = int(chunk_size)
            assert buckets is None, \
                "chunk_size replaces bucketing: pass buckets=None"
            assert chunk_size >= 1, chunk_size
            # ceil((max_len - 1) / C) * C <= max_len must hold so a
            # capped prompt's padded tail chunk fits the slot cache
            assert max_len % chunk_size == 0, \
                f"max_len {max_len} must be a multiple of " \
                f"chunk_size {chunk_size}"
        self.chunk_size = chunk_size
        if buckets == "pow2":
            buckets = pow2_buckets(max_len)
        if buckets is not None:
            buckets = tuple(sorted(int(b) for b in buckets))
            assert buckets and buckets[-1] <= max_len, \
                f"buckets {buckets} exceed max_len {max_len}"
            assert buckets[-1] >= max_len - 1, \
                "largest bucket must admit every capped prompt"
        self.buckets = buckets
        self._comm = _comm
        self._pmod = _pm
        self.pm = _pm.build_private_model(cfg, params, key,
                                          mode=mode, use_pool=True)
        self.slots: list[Request | None] = [None] * max_slots
        self.pos = np.zeros(max_slots, np.int32)
        self.caches = _pm.init_slot_caches(self.pm, max_slots, max_len)
        self.stats: dict[int, dict] = {}
        self.prefills = 0
        self.chunk_ticks = 0
        self.decode_ticks = 0

    # ---- per-request comm accounting ---------------------------------------
    def _accumulate(self, req: Request, led):
        st = self.stats.setdefault(req.rid, {"rounds": 0,
                                             "online_bits": 0,
                                             "offline_bits": 0,
                                             "tokens": 0,
                                             "truncated": False,
                                             "prompt_truncated":
                                                 req.prompt_truncated})
        st["rounds"] += led.total_rounds()
        st["online_bits"] += led.total_bits()
        st["offline_bits"] += led.total_bits(False) - led.total_bits()
        st["tokens"] = len(req.out)

    def _on_finish(self, req: Request):
        if req.rid in self.stats:
            self.stats[req.rid]["truncated"] = req.truncated
            self.stats[req.rid]["tokens"] = len(req.out)

    def compile_stats(self) -> dict:
        """Compiled-program + dispatch telemetry.  Program counts read
        the model's jit cache (0 when decode_jit=False); the bucketing
        guarantee is prefill_programs <= len(buckets) and
        decode_programs <= 1 regardless of how prompt lengths mix; the
        chunking guarantee is chunk_programs == 1 (counted inside
        prefill_programs — the chunk program IS the prefill program)."""
        names = [k[0] for k in self.pm.jit_cache]
        pfx = f"{self.mode}_"
        return {"prefill_programs":
                sum(n.startswith(pfx + "prefill") for n in names),
                "chunk_programs":
                sum(n.startswith(pfx + "prefill_chunk") for n in names),
                "decode_programs":
                sum(n.startswith(pfx + "decode") for n in names),
                "prefills": self.prefills,
                "chunk_ticks": self.chunk_ticks,
                "decode_ticks": self.decode_ticks}

    # ---- scheduler ----------------------------------------------------------
    def _bucket_for(self, length: int) -> int:
        return next(b for b in self.buckets if b >= length)

    def _prefill_into(self, slot: int, req: Request):
        if self.chunk_size is not None:
            return self._prefill_chunked(slot, req)
        S = len(req.prompt)
        assert S < self.max_len, "prompt fills the slot"  # submit() caps
        toks, lens = req.prompt, None
        if self.buckets is not None:
            # pad to the smallest bucket; the pad token id is irrelevant
            # (padded columns are masked dead, padded rows overwritten)
            toks = toks + [0] * (self._bucket_for(S) - S)
            lens = jnp.asarray([S], jnp.int32)
        toks = jnp.asarray(toks, jnp.int32)[None, :]
        with self._comm.ledger() as led:
            logits, c1 = self._pmod.private_prefill(
                self.pm, toks, max_len=self.max_len,
                jit=self.decode_jit, lens=lens)
        # splice the request's padded share-cache rows into its slot
        self.caches = [
            jax.tree.map(lambda full, one: full.at[slot].set(one[0]),
                         full_l, one_l)
            for full_l, one_l in zip(self.caches, c1)]
        self.pos[slot] = S
        req.out.append(int(np.argmax(np.asarray(logits)[0])))
        self.prefills += 1
        self._accumulate(req, led)

    def _prefill_chunked(self, slot: int, req: Request):
        """Chunked prefill (DESIGN.md §10): consume the prompt as
        ceil(S/C) fixed-shape chunk ticks against a fresh single-slot
        chunk state, then splice the reconstructed share cache into the
        slot.  Each chunk tick's ledger is accumulated to the request
        as it runs — a prefill that spans several ticks stays exact and
        sum-conserving per request (`comm.attribute` with one key is
        the identity), so per-request stats keep summing to the global
        ledger."""
        C = self.chunk_size
        S = len(req.prompt)
        assert S < self.max_len, "prompt fills the slot"  # submit() caps
        n_chunks = -(-S // C)
        # pad the tail chunk; dead token ids are irrelevant (masked
        # columns, garbage rows overwritten/kept dead by decode)
        padded = req.prompt + [0] * (n_chunks * C - S)
        lens = jnp.asarray([S], jnp.int32)
        with self._comm.ledger() as led0:
            # one-time per-request state: π1 permutation material
            state = self._pmod.init_chunk_state(self.pm, 1, self.max_len)
        self._accumulate(req, led0)
        for ci in range(n_chunks):
            toks = jnp.asarray([padded[ci * C:(ci + 1) * C]], jnp.int32)
            with self._comm.ledger() as led:
                logits, state = self._pmod.private_prefill_chunk(
                    self.pm, state, toks, ci * C, lens,
                    jit=self.decode_jit, lookahead=self.lookahead)
            self.chunk_ticks += 1
            self._accumulate(req, led)
        c1 = self._pmod.chunk_state_caches(state)
        self.caches = [
            jax.tree.map(lambda full, one: full.at[slot].set(one[0]),
                         full_l, one_l)
            for full_l, one_l in zip(self.caches, c1)]
        self.pos[slot] = S
        req.out.append(int(np.argmax(np.asarray(logits)[0])))
        self.prefills += 1

    def step(self) -> bool:
        """One tick: admit, decode the full slot width, evict."""
        self._admit()
        # prefill emits a token and may already satisfy the request
        # (max_new_tokens=1) — never decode a finished slot
        self._evict()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return bool(self.queue)
        # decode the FULL slot width every tick: an empty slot runs a
        # dummy token at pos 0 (its logits are discarded and its cache
        # rows are rewritten wholesale by the next admit's prefill
        # splice), so ONE (max_slots,)-shaped program serves every tick
        # regardless of occupancy — a partial-batch gather would compile
        # one program per active-slot count and break the
        # len(buckets) + 1 program budget
        toks = jnp.asarray([[s.out[-1]] if s is not None else [0]
                            for s in self.slots], jnp.int32)
        pos = jnp.asarray([int(self.pos[i]) if s is not None else 0
                           for i, s in enumerate(self.slots)], jnp.int32)
        with self._comm.ledger() as tick:
            logits, self.caches = self._pmod.private_decode_step(
                self.pm, self.caches, toks, pos, jit=self.decode_jit,
                lookahead=self.lookahead)
        lg = np.asarray(logits)
        for i in active:
            self.slots[i].out.append(int(lg[i, 0].argmax()))
            self.pos[i] += 1
        self.decode_ticks += 1
        # exact per-request attribution of the batched step's comm
        per = self._comm.attribute(tick.events,
                                   [self.slots[i].rid for i in active])
        for i in active:
            self._accumulate(self.slots[i], per[self.slots[i].rid])
        self._evict()
        return True

    def run_to_completion(self, max_steps: int = 10_000
                          ) -> tuple[dict, dict]:
        """Serve the queue; returns (outputs, per-request comm stats),
        both cumulative over every request this engine has finished."""
        for _ in range(max_steps):
            if not self.step():
                break
        return {r.rid: r.out for r in self.finished}, self.stats
