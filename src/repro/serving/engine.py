"""Serving engine: continuous batching over a slotted KV cache.

Requests are admitted into free slots (prefill writes that slot's cache
row), every step decodes the whole active batch, finished requests are
evicted and their slots reused — the vLLM-style loop reduced to its
JAX-native essentials (slot-indexed dynamic_update_slice into stacked
caches).  Also drives the *private* (Centaur) serving path for the
paper's own models via core.private_model, with the crash-safe
transactional scheduler of DESIGN.md §11 (rollback, retry, quarantine,
graceful drain)."""
from __future__ import annotations

import contextlib
import itertools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.registry import get_api
from repro.runtime import faults
from repro.runtime.fault_tolerance import HeartbeatMonitor


@dataclass
class Request:
    rid: int
    prompt: list            # token ids
    max_new_tokens: int = 16
    out: list = field(default_factory=list)
    #: output cut short by slot capacity: the request was evicted at
    #: pos == max_len - 1 before reaching max_new_tokens
    truncated: bool = False
    #: prompt cut to the shared length cap (max_len - 1) at submit time
    prompt_truncated: bool = False
    #: scheduler outcome: ok | retried | failed | quarantined.  Retry
    #: and quarantine counts are PUBLIC metadata (same leakage class as
    #: the chunk count): they depend on protocol/infrastructure faults,
    #: never on prompt content — see DESIGN.md §11.
    status: str = "ok"
    #: failed attempts survived so far (prefill retries + decode-tick
    #: retries for this request)
    retries: int = 0
    #: earliest engine tick this request may be (re)admitted (backoff)
    not_before: int = 0

    @property
    def done(self) -> bool:
        return len(self.out) >= self.max_new_tokens


def pow2_buckets(max_len: int, lo: int = 8) -> tuple:
    """Power-of-two prefill length-bucket ladder capped at `max_len`:
    (lo, 2*lo, ..., max_len).  Every admissible prompt (<= max_len - 1
    after the shared cap) fits the last bucket."""
    out, b = [], lo
    while b < max_len:
        out.append(b)
        b *= 2
    out.append(max_len)
    return tuple(out)


class RequestQueue:
    """Shared slot-scheduler plumbing for the serving engines.

    Subclasses provide `slots`, `pos`, `max_len` and `_prefill_into`;
    admission, eviction and the length-cap policy live here so the
    plaintext and private engines can never drift apart on the rules
    that keep them token-identical (same admit order, same length-cap
    truncation).  Admission goes through `_try_prefill` so the private
    engine can make it transactional (rollback + retry + quarantine)
    without touching the shared admit order."""

    def __init__(self):
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self._rid = itertools.count()
        #: scheduler tick counter (drives retry backoff)
        self.ticks = 0
        #: graceful drain: stop admitting, finish active slots
        self.draining = False

    @staticmethod
    def _validate_limits(max_slots: int, max_len: int):
        # explicit raises, not asserts: `python -O` strips asserts and
        # would silently readmit the crashes these reject
        if max_slots < 1:
            raise faults.EngineConfigError(
                f"max_slots must be >= 1, got {max_slots}")
        if max_len < 2:
            raise faults.EngineConfigError(
                f"max_len must fit a prompt token plus one generated "
                f"token, got {max_len}")

    def submit(self, prompt, max_new_tokens: int = 16) -> int:
        """Queue a request.  ONE shared length-cap policy for every
        engine: a prompt longer than max_len - 1 is truncated to its
        first max_len - 1 tokens (prefill plus at least one generated
        token must fit the slot), and the request is flagged
        `prompt_truncated` instead of crashing one engine and silently
        overrunning the other."""
        prompt = list(prompt)
        # an empty prompt has no last-real-token to decode from: the
        # exact-length path would crash late and the bucketed path
        # would silently serve a fully-masked garbage hidden state
        if not prompt:
            raise faults.InvalidRequest(
                "empty prompt: no last real token to decode from")
        if max_new_tokens < 1:
            raise faults.InvalidRequest(
                f"max_new_tokens must be >= 1, got {max_new_tokens}")
        rid = next(self._rid)
        cap = self.max_len - 1
        truncated = len(prompt) > cap
        req = Request(rid, prompt[:cap], max_new_tokens,
                      prompt_truncated=truncated)
        self.queue.append(req)
        return rid

    def _admit(self):
        if self.draining:
            return
        for i, slot in enumerate(self.slots):
            if slot is not None:
                continue
            while True:
                # first queued request whose backoff has elapsed
                # (not_before == 0 always, for the plaintext engine:
                # identical FIFO admit order)
                ri = next((j for j, r in enumerate(self.queue)
                           if r.not_before <= self.ticks), None)
                if ri is None:
                    break
                req = self.queue.pop(ri)
                if self._try_prefill(i, req):
                    self.slots[i] = req
                    break
                # prefill failed and was requeued/quarantined by the
                # subclass: try the next admissible request for this
                # slot so one poisoned request never stalls the tick

    def _try_prefill(self, slot: int, req: Request) -> bool:
        """Admission hook: prefill `req` into `slot`, True on success.
        The base implementation lets exceptions propagate (plaintext
        engine semantics); the private engine overrides this with the
        transactional rollback/retry/quarantine path."""
        self._prefill_into(slot, req)
        return True

    def _evict(self):
        for i, s in enumerate(self.slots):
            if s is not None and (s.done
                                  or self.pos[i] >= self.max_len - 1):
                # slot-capacity eviction before max_new_tokens is a
                # truncated output — flag it instead of dropping the
                # request silently
                if not s.done:
                    s.truncated = True
                self._on_finish(s)
                self.finished.append(s)
                self._release_slot(i)
                self.slots[i] = None

    def _on_finish(self, req: Request):
        """Hook: engines surface per-request outcomes (e.g. stats)."""

    def _release_slot(self, i: int):
        """Hook: engines reclaim per-slot resources at eviction — the
        paged engine eagerly returns the slot's KV pages to the free
        list (zero-on-free) instead of leaving stale shares behind."""


class ServingEngine(RequestQueue):
    """Greedy-decoding continuous-batching server."""

    def __init__(self, cfg: ModelConfig, params, *, max_slots: int = 4,
                 max_len: int = 256):
        super().__init__()
        self._validate_limits(max_slots, max_len)
        self.cfg = cfg
        self.params = params
        self.api = get_api(cfg)
        self.max_slots = max_slots
        self.max_len = max_len
        self.slots: list[Request | None] = [None] * max_slots
        self.pos = np.zeros(max_slots, np.int32)
        self.cache = self.api.init_cache(cfg, max_slots, max_len) \
            if self.api.init_cache else None
        self._decode = jax.jit(
            lambda p, c, t, pos: self.api.decode_step(cfg, p, c, t, pos))

    def run_to_completion(self, max_steps: int = 10_000):
        for _ in range(max_steps):
            if not self.step():
                break
        return {r.rid: r.out for r in self.finished}

    # ---- scheduler ----------------------------------------------------------
    def _prefill_into(self, slot: int, req: Request):
        toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
        logits, cache1, pos = self.api.prefill(
            self.cfg, self.params, {"tokens": toks}, max_len=self.max_len)
        # splice the single-request cache into the stacked slot cache
        self.cache = jax.tree.map(
            lambda full, one: jax.lax.dynamic_update_slice_in_dim(
                full, one.astype(full.dtype), slot, axis=1),
            self.cache, cache1)
        self.pos[slot] = pos
        req.out.append(int(jnp.argmax(logits[0])))

    def step(self) -> bool:
        """One scheduler tick: admit, decode the active batch, evict."""
        self._admit()
        # prefill emits a token and may already satisfy the request
        # (max_new_tokens=1) — never decode a finished slot
        self._evict()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return bool(self.queue)
        # uniform position decode (slots padded to max position): we
        # decode each slot at its own pos via per-slot loop when they
        # diverge, batched when aligned
        groups = {}
        for i in active:
            groups.setdefault(int(self.pos[i]), []).append(i)
        for pos, idxs in groups.items():
            toks = jnp.asarray([[self.slots[i].out[-1]] for i in idxs],
                               jnp.int32)
            sub = jax.tree.map(lambda a: a.take(jnp.asarray(idxs), axis=1),
                               self.cache)
            logits, sub = self._decode(self.params, sub, toks, pos)
            for j, i in enumerate(idxs):
                self.cache = jax.tree.map(
                    lambda full, part, j=j, i=i:
                    jax.lax.dynamic_update_slice_in_dim(
                        full, part[:, j:j + 1].astype(full.dtype), i,
                        axis=1),
                    self.cache, sub)
                self.slots[i].out.append(int(jnp.argmax(logits[j])))
                self.pos[i] = pos + 1
        self._evict()
        return True


class PrivateServingEngine(RequestQueue):
    """Continuous-batching greedy server behind any servable PPTI mode.

    The slot engine above, moved into the share domain: requests are
    admitted into free slots (private prefill writes that slot's padded
    share-cache rows), every tick decodes the whole active slot batch
    through ONE jitted batched private step per layer depth
    (core.private_model.private_decode_step with slot-stacked padded KV
    share caches and per-slot position/validity masks), finished
    requests are evicted and their slots reused.  `max_slots=1` is the
    sequential baseline: same code path, batch of one.

    `mode=` picks the protocol suite: "centaur" (the paper) or the
    SMPC baselines ("smpc"/"mpcformer"/"secformer") — all served by the
    same executor, which is what makes the paper's centaur-vs-SMPC
    serving throughput ratio measurable under identical conditions
    (benchmarks/private_serving_bench.py --mode).

    One batched step bills the ambient ledger once for all slots, so
    each tick's events are split across the active requests with
    comm.attribute — exact and sum-conserving, so per-request stats add
    up to the global ledger and a single-slot (max_slots=1) run bills
    identically to sequential serving.  Note the tick is always the
    FULL slot width (see `step`), so at partial occupancy the dummy
    rows' very real protocol traffic is amortized over the active
    requests — per-request bits are occupancy-dependent, exactly like
    bucketed prefill bills the padded bucket's S^2: padding cost is
    billed to whoever the padding serves, never dropped.  Prefill runs
    per request and is billed to that request directly.  The model's TriplePool stocks `lookahead` ticks
    of the recurring batched decode shapes ahead of time (one
    vectorized offline dispatch per spec).

    `buckets` keys the compiled-program budget under mixed-length
    traffic: None (the exact-length escape hatch) prefills at true
    prompt length — one compiled program and one S^2 comm bill per
    distinct length; "pow2" or an explicit ladder pads each prompt to
    the smallest bucket >= its length, so the engine compiles at most
    len(buckets) prefill programs + 1 decode program no matter how
    lengths mix (`compile_stats()` verifies), at the cost of billing
    the padded bucket's S^2 attention comm.

    `chunk_size=C` replaces bucketing (pass `buckets=None`): a prompt
    of any length is consumed as ceil(len/C) fixed-shape chunks run
    against the slot cache (DESIGN.md §10) — ONE compiled chunk
    program + 1 decode program under arbitrary length mixes, and the
    long-prompt comm bill drops below the bucket ladder's padded S^2
    (the amortized chunk-cache protocol opens each K/V row once and
    reuses one π1 per request per layer).  The tail chunk is padded to
    C with masked dead tokens; each chunk tick is billed to its
    request as it runs.  `max_len` must be a multiple of C so the last
    chunk of a capped prompt still fits the padded cache.

    Fault tolerance (DESIGN.md §11): admission is TRANSACTIONAL — the
    slot's cache rows, `pos` and the request's output are snapshotted
    before prefill and rolled back on any `faults.ServingFault`
    (transport drop, dealer/pool failure, integrity trip); partial
    comm is still billed to the request exactly (failed work crossed
    the wire).  A failed request retries with per-retry tick backoff
    up to `max_retries`, then is QUARANTINED (terminal, slot freed).
    A whole failed decode tick likewise rolls back (nothing committed,
    partial comm attributed sum-conservingly across the active slots)
    and is retried; after `max_retries` consecutive failed ticks the
    active requests are marked `failed` and the engine itself stays
    alive.  `integrity="paranoid"` arms the party-local guards (opened
    -value envelopes at the pp seams, logits envelope, cache-splice
    structure, ledger sum-conservation) — guards record ZERO ledger
    events, so the ledger-independence contract is untouched.
    `preemption` (a PreemptionGuard) drives graceful drain; `health()`
    snapshots liveness, pool stock and the quarantine census."""

    def __init__(self, cfg: ModelConfig, params, key, *,
                 mode: str = "centaur", max_slots: int = 4,
                 max_len: int = 256, decode_jit: bool = True,
                 lookahead: int = 4, buckets=None,
                 chunk_size: int | None = None,
                 paged: bool = False, page_size: int = 16,
                 num_pages: int | None = None,
                 batch_admission: bool = True, on_token=None,
                 integrity: str = "off", max_retries: int = 2,
                 retry_backoff: int = 1, preemption=None,
                 heartbeat_timeout: float = 60.0,
                 transport="loopback", rtt_ms: float = 0.0,
                 bandwidth_bps: float | None = None,
                 dealer_proc: bool = False):
        from repro.core import comm as _comm
        from repro.core import private_model as _pm
        from repro.core.suites import masking as _masking
        from repro.runtime import transport as _transport
        if cfg.family != "dense" or cfg.use_mla:
            raise faults.EngineConfigError(
                "private serving covers the dense KV-cache decode path")
        if mode not in ("centaur", "smpc", "mpcformer", "secformer"):
            raise faults.EngineConfigError(
                f"no share-domain serving path for mode {mode!r}")
        if integrity not in ("off", "paranoid"):
            raise faults.EngineConfigError(
                f"integrity must be 'off' or 'paranoid', got "
                f"{integrity!r}")
        if max_retries < 0:
            raise faults.EngineConfigError(
                f"max_retries must be >= 0, got {max_retries}")
        if retry_backoff < 0:
            raise faults.EngineConfigError(
                f"retry_backoff must be >= 0, got {retry_backoff}")
        super().__init__()
        self._validate_limits(max_slots, max_len)
        self.cfg = cfg
        self.mode = mode
        self.max_slots = max_slots
        self.max_len = max_len
        self.decode_jit = decode_jit
        self.lookahead = lookahead
        if chunk_size is not None:
            chunk_size = int(chunk_size)
            if buckets is not None:
                raise faults.EngineConfigError(
                    "chunk_size replaces bucketing: pass buckets=None")
            if chunk_size < 1:
                raise faults.EngineConfigError(
                    f"chunk_size must be >= 1, got {chunk_size}")
            # ceil((max_len - 1) / C) * C <= max_len must hold so a
            # capped prompt's padded tail chunk fits the slot cache
            if max_len % chunk_size != 0:
                raise faults.EngineConfigError(
                    f"max_len {max_len} must be a multiple of "
                    f"chunk_size {chunk_size}")
        self.chunk_size = chunk_size
        if buckets == "pow2":
            buckets = pow2_buckets(max_len)
        if buckets is not None:
            buckets = tuple(sorted(int(b) for b in buckets))
            if not buckets or buckets[-1] > max_len:
                raise faults.EngineConfigError(
                    f"buckets {buckets} exceed max_len {max_len}")
            if buckets[-1] < max_len - 1:
                raise faults.EngineConfigError(
                    "largest bucket must admit every capped prompt")
        self.buckets = buckets
        self.paged = bool(paged)
        self.batch_admission = bool(batch_admission)
        #: streaming hook: called as on_token(rid, token) the moment a
        #: token is COMMITTED to a request (prefill first token and
        #: every decode tick) — launch scripts stream partial outputs
        #: per tick instead of polling run_to_completion.  A rolled-back
        #: fault retries re-emit from the rollback point.
        self.on_token = on_token
        if self.paged:
            if chunk_size is None:
                raise faults.EngineConfigError(
                    "paged serving runs on the chunked prefill path: "
                    "pass chunk_size")
            page_size = int(page_size)
            if page_size < 1 or page_size % chunk_size != 0:
                raise faults.EngineConfigError(
                    f"page_size {page_size} must be a positive multiple "
                    f"of chunk_size {chunk_size} (prefix pages must end "
                    f"on a chunk boundary)")
            if max_len % page_size != 0:
                raise faults.EngineConfigError(
                    f"max_len {max_len} must be a multiple of "
                    f"page_size {page_size}")
        self.page_size = page_size if self.paged else None
        self._comm = _comm
        self._pmod = _pm
        # ---- transport runtime (DESIGN.md §14) ------------------------------
        #: the comm seam's byte mover: loopback (default, bit-exact
        #: with the pre-transport runtime) or a real cross-process
        #: socket with rtt/bandwidth shaping.  Every protocol block the
        #: engine runs is wrapped in `comm.transported(self.transport)`.
        self.transport = _transport.make_transport(
            transport, rtt_ms=rtt_ms, bandwidth_bps=bandwidth_bps)
        self._dealer_client = None
        dealer_factory = None
        if dealer_proc:
            from repro.runtime import dealer_service as _ds
            self._dealer_client = _ds.DealerClient.spawn()
            dealer_factory = (lambda k, _c=self._dealer_client:
                              _ds.make_async_pool(k, _c))
        # one-time weight-share opens (DESIGN.md §12) happen at build:
        # bill them to the engine lifetime, not to any request
        with _comm.ledger() as boot, _comm.transported(self.transport):
            self.pm = _pm.build_private_model(
                cfg, params, key, mode=mode, use_pool=True,
                dealer_factory=dealer_factory)
        #: bits of the once-per-lifetime `W - B_w` weight opens
        #: (smpc-family modes; 0 for centaur's plaintext-permuted
        #: weights).  Constant in tokens served by construction.
        self.weight_open_bits = sum(
            e.bits for e in boot.events if e.protocol == "weight_open")
        self.slots: list[Request | None] = [None] * max_slots
        self.pos = np.zeros(max_slots, np.int32)
        if self.paged:
            from repro.serving.paging import PageAllocator
            #: padded page-table width: every slot's table is nb entries
            #: so the jitted tick is shape-static at any occupancy
            self.nb = max_len // self.page_size
            if num_pages is None:
                num_pages = 1 + max_slots * self.nb
            self.pools = _pm.init_page_pool(self.pm, num_pages,
                                            self.page_size)
            self.alloc = PageAllocator(num_pages, self.page_size)
            self.page_table = np.zeros((max_slots, self.nb), np.int32)
            # slot-width per-layer π1 registry (identity = inert rows
            # for empty slots; admission splices fresh per-request rows)
            _suite = self.pm.suite()
            self.pst = [_suite.chunk_perm_identity(max_slots, max_len)
                        for _ in range(cfg.num_layers)]
            self._prefixes: dict = {}
            #: engine-lifetime prefix-cache fill bill (like
            #: weight_open_bits: billed to the cache, not any request)
            self.prefix_bits = 0
            self.prefix_hits = 0
            self.caches = None
        else:
            self.caches = _pm.init_slot_caches(self.pm, max_slots,
                                               max_len)
        self.stats: dict[int, dict] = {}
        self.prefills = 0
        self.chunk_ticks = 0
        self.decode_ticks = 0
        # ---- fault tolerance ------------------------------------------------
        self.integrity = integrity
        self.max_retries = max_retries
        self.retry_backoff = retry_backoff
        self.preemption = preemption
        # honest decoded logits are O(1-100); anything past the mask
        # envelope is a corrupted share or a ring wrap
        self._logit_limit = 4.0 * _masking.MASK_MAGNITUDE
        self.quarantined: list[Request] = []
        self.failed: list[Request] = []
        self.fault_log: list[faults.FaultLogEntry] = []
        self.prefill_failures = 0
        self._tick_failures = 0          # consecutive failed decode ticks
        #: logical-party liveness: in this SPMD simulation all parties
        #: run in-process, so beats are derived from protocol progress
        #: (a dealer fault withholds the dealer's beat)
        self.heartbeats = HeartbeatMonitor(timeout=heartbeat_timeout)
        self._beat()

    # ---- per-request comm accounting ---------------------------------------
    def _accumulate(self, req: Request, led):
        st = self.stats.setdefault(req.rid, {"rounds": 0,
                                             "online_bits": 0,
                                             "offline_bits": 0,
                                             "tokens": 0,
                                             "truncated": False,
                                             "prompt_truncated":
                                                 req.prompt_truncated,
                                             "status": req.status,
                                             "retries": req.retries})
        st["rounds"] += led.total_rounds()
        st["online_bits"] += led.total_bits()
        st["offline_bits"] += led.total_bits(False) - led.total_bits()
        st["tokens"] = len(req.out)
        st["status"] = req.status
        st["retries"] = req.retries

    @contextlib.contextmanager
    def _billed(self, req: Request):
        """Ledger scope whose events are ALWAYS accumulated to `req` —
        a fault mid-protocol keeps its partial comm billed exactly
        (those bytes crossed the wire; dropping them would break the
        stats == global-ledger conservation invariant)."""
        with self._comm.ledger() as led:
            try:
                yield led
            finally:
                self._accumulate(req, led)

    def _on_finish(self, req: Request):
        if req.status not in ("failed", "quarantined"):
            req.status = "retried" if req.retries else "ok"
        if req.rid in self.stats:
            self.stats[req.rid]["truncated"] = req.truncated
            self.stats[req.rid]["tokens"] = len(req.out)
            self.stats[req.rid]["status"] = req.status
            self.stats[req.rid]["retries"] = req.retries

    def compile_stats(self) -> dict:
        """Compiled-program + dispatch telemetry.  Program counts read
        the model's jit cache (0 when decode_jit=False); the bucketing
        guarantee is prefill_programs <= len(buckets) and
        decode_programs <= 1 regardless of how prompt lengths mix; the
        chunking guarantee is chunk_programs == 1 (counted inside
        prefill_programs — the chunk program IS the prefill program)."""
        names = [k[0] for k in self.pm.jit_cache]
        pfx = f"{self.mode}_"
        return {"prefill_programs":
                sum(n.startswith(pfx + "prefill") for n in names),
                "chunk_programs":
                sum(n.startswith(pfx + "prefill_chunk") for n in names),
                "decode_programs":
                sum(n.startswith(pfx + "decode") for n in names),
                "prefills": self.prefills,
                "chunk_ticks": self.chunk_ticks,
                "decode_ticks": self.decode_ticks}

    # ---- fault bookkeeping --------------------------------------------------
    def _dealer_alive(self) -> bool:
        """Real dealer-process liveness when one exists (dealer_proc):
        the AsyncTriplePool exposes `dealer_alive()` — False the moment
        the process dies or its stream EOFs, so the heartbeat monitor
        genuinely misses beats on a kill.  In-process pools have no
        process to lose; their dealer beat tracks protocol progress as
        before."""
        alive = getattr(self.pm.dealer, "dealer_alive", None)
        return True if alive is None else bool(alive())

    def _beat(self, dealer: bool = True):
        self.heartbeats.beat("p0")
        self.heartbeats.beat("p1")
        if dealer and self._dealer_alive():
            self.heartbeats.beat("dealer")

    def _note_fault(self, err: Exception, phase: str, rid,
                    retries: int = 0, outcome: str = "retried"):
        self.fault_log.append(faults.FaultLogEntry(
            tick=self.ticks, phase=phase, rid=rid,
            error=type(err).__name__, detail=str(err),
            retries=retries, outcome=outcome))

    def _quarantine(self, req: Request):
        """Terminal: the request exceeded max_retries.  Its stats entry
        (partial comm included) survives; the slot/queue forget it."""
        req.status = "quarantined"
        self.quarantined.append(req)
        self._accumulate(req, self._comm.CommLedger())  # ensure entry
        self.stats[req.rid]["tokens"] = len(req.out)

    def _register_failure(self, req: Request, err: Exception,
                          phase: str):
        """Shared retry/quarantine policy for a per-request fault."""
        req.retries += 1
        if req.retries > self.max_retries:
            self._quarantine(req)
            self._note_fault(err, phase, req.rid, req.retries,
                             "quarantined")
        else:
            req.status = "retried"
            req.not_before = self.ticks + self.retry_backoff * req.retries
            self._note_fault(err, phase, req.rid, req.retries, "retried")

    def _check_conservation(self, per: dict, tick) -> None:
        """Paranoid invariant: comm.attribute's per-request split must
        sum EXACTLY to the tick ledger (party-local arithmetic on
        already-public metadata; bills nothing)."""
        if self.integrity != "paranoid":
            return
        bits = sum(led.total_bits(False) for led in per.values())
        rounds = sum(led.total_rounds(False) for led in per.values())
        if (bits != tick.total_bits(False)
                or rounds != tick.total_rounds(False)):
            raise faults.ProtocolIntegrityError(
                f"attribution broke sum-conservation: "
                f"{bits}/{rounds} != {tick.total_bits(False)}"
                f"/{tick.total_rounds(False)}")

    def _bill_shared(self, tick, reqs):
        """Attribute one shared (possibly partial) batched tick's
        events across its requests — exact and sum-conserving either
        way.  Used by the decode tick (across active slots) and the
        batched paged prefill tick (across the admission batch)."""
        rids = [r.rid for r in reqs]
        per = self._comm.attribute(tick.events, rids)
        self._check_conservation(per, tick)
        for r in reqs:
            self._accumulate(r, per[r.rid])

    def _bill_tick(self, tick, active):
        self._bill_shared(tick, [self.slots[i] for i in active])

    def _emit(self, req: Request, tok: int):
        """Commit one generated token (and stream it, if a callback is
        registered)."""
        req.out.append(tok)
        if self.on_token is not None:
            self.on_token(req.rid, tok)

    # ---- scheduler ----------------------------------------------------------
    def _bucket_for(self, length: int) -> int:
        return next(b for b in self.buckets if b >= length)

    def _admit(self):
        """Batched paged admission (DESIGN.md §13): collect ONE
        admissible queued request per free slot and prefill them all in
        a single run of batched chunk ticks — ceil(S/C) dispatches for
        the whole admission wave instead of ceil(S/C) per request.
        Falls back to the base one-at-a-time loop for dense engines and
        for paged engines built with batch_admission=False (the
        sequential reference the batched path is tested token-identical
        against)."""
        if not (self.paged and self.batch_admission):
            return super()._admit()
        if self.draining:
            return
        while True:
            pairs = []
            for i, slot in enumerate(self.slots):
                if slot is not None:
                    continue
                ri = next((j for j, r in enumerate(self.queue)
                           if r.not_before <= self.ticks), None)
                if ri is None:
                    break
                pairs.append((i, self.queue.pop(ri)))
            if not pairs:
                return
            for i, req in self._paged_prefill(pairs):
                self.slots[i] = req
            # a failed/deferred batch re-entered the queue behind a
            # backoff window (not_before > ticks), so the next loop
            # iteration admits remaining traffic or terminates

    def _try_prefill(self, slot: int, req: Request) -> bool:
        """Transactional admission: snapshot the slot's cache rows,
        `pos` and the request output; roll all three back on any
        ServingFault so the slot is bit-identical to before the attempt
        (cache arrays are immutable — the snapshot is just the old list
        of per-layer trees).  Partial comm stays billed to the request
        (`_billed`), the fault is logged, and the request either backs
        off into the queue or is quarantined."""
        if self.paged:
            # sequential paged admission: a one-request batch through
            # the same transactional batched path
            return bool(self._paged_prefill([(slot, req)]))
        snap_caches = list(self.caches)
        snap_pos = int(self.pos[slot])
        snap_out = len(req.out)
        try:
            with faults.phase("prefill", rid=req.rid), \
                    faults.integrity(self.integrity):
                self._prefill_into(slot, req)
            self._beat()
            return True
        except Exception as err:
            self.caches = snap_caches
            self.pos[slot] = snap_pos
            del req.out[snap_out:]
            if not isinstance(err, faults.ServingFault):
                raise
            self.prefill_failures += 1
            self._beat(dealer=not isinstance(err, faults.DealerFault))
            self._register_failure(req, err, "prefill")
            if req.status != "quarantined":
                # back into the queue behind its backoff window
                self.queue.append(req)
            return False

    def _guard_logits(self, logits, rid, what: str):
        """Engine-side decoded-logits seam: chaos injection point plus
        the paranoid envelope (party-local — the output party holds the
        decoded logits in the clear; bills nothing)."""
        if faults._INJECTORS:
            logits = faults.on_logits(rid, logits)
        if self.integrity == "paranoid":
            faults.check_finite_logits(logits, self._logit_limit, what)
        return logits

    def _splice(self, slot: int, c1):
        """Splice a request's padded share-cache rows into its slot,
        with the paranoid structural guard (a suite returning the wrong
        shape/dtype would silently corrupt the whole slot batch)."""
        new = [jax.tree.map(lambda full, one: full.at[slot].set(one[0]),
                            full_l, one_l)
               for full_l, one_l in zip(self.caches, c1)]
        if self.integrity == "paranoid":
            faults.check_tree_match(new, self.caches,
                                    f"prefill cache splice (slot {slot})")
        self.caches = new

    def _prefill_into(self, slot: int, req: Request):
        if self.chunk_size is not None:
            return self._prefill_chunked(slot, req)
        S = len(req.prompt)
        toks, lens = req.prompt, None
        if self.buckets is not None:
            # pad to the smallest bucket; the pad token id is irrelevant
            # (padded columns are masked dead, padded rows overwritten)
            toks = toks + [0] * (self._bucket_for(S) - S)
            lens = jnp.asarray([S], jnp.int32)
        toks = jnp.asarray(toks, jnp.int32)[None, :]
        with self._billed(req):
            logits, c1 = self._pmod.private_prefill(
                self.pm, toks, max_len=self.max_len,
                jit=self.decode_jit, lens=lens)
        lg = self._guard_logits(np.array(logits)[0], req.rid,
                                f"prefill logits (rid {req.rid})")
        self._splice(slot, c1)
        self.pos[slot] = S
        self._emit(req, int(np.argmax(lg)))
        self.prefills += 1

    def _prefill_chunked(self, slot: int, req: Request):
        """Chunked prefill (DESIGN.md §10): consume the prompt as
        ceil(S/C) fixed-shape chunk ticks against a fresh single-slot
        chunk state, then splice the reconstructed share cache into the
        slot.  Each chunk tick's ledger is accumulated to the request
        as it runs — a prefill that spans several ticks stays exact and
        sum-conserving per request (`comm.attribute` with one key is
        the identity), so per-request stats keep summing to the global
        ledger, including the partial ticks of an attempt that faults
        halfway."""
        C = self.chunk_size
        S = len(req.prompt)
        n_chunks = -(-S // C)
        # pad the tail chunk; dead token ids are irrelevant (masked
        # columns, garbage rows overwritten/kept dead by decode)
        padded = req.prompt + [0] * (n_chunks * C - S)
        lens = jnp.asarray([S], jnp.int32)
        with self._billed(req):
            # one-time per-request state: π1 permutation material
            state = self._pmod.init_chunk_state(self.pm, 1, self.max_len)
        for ci in range(n_chunks):
            toks = jnp.asarray([padded[ci * C:(ci + 1) * C]], jnp.int32)
            with self._billed(req):
                logits, state = self._pmod.private_prefill_chunk(
                    self.pm, state, toks, ci * C, lens,
                    jit=self.decode_jit, lookahead=self.lookahead,
                    final=(ci == n_chunks - 1))
            self.chunk_ticks += 1
        lg = self._guard_logits(np.array(logits)[0], req.rid,
                                f"prefill logits (rid {req.rid})")
        c1 = self._pmod.chunk_state_caches(state)
        self._splice(slot, c1)
        self.pos[slot] = S
        self._emit(req, int(np.argmax(lg)))
        self.prefills += 1

    # ---- paged serving (DESIGN.md §13) --------------------------------------
    def register_prefix(self, tokens) -> int:
        """Cache a shared prompt prefix: allocate pages for every FULLY
        covered page of `tokens`, run the dense chunked-prefill cache
        protocol over those rows once, and scatter the opened
        values + persistent masks into the pages.  Later prompts that
        start with this prefix map those pages copy-on-write and skip
        their online prefill chunks (and the open/π1 work inside them)
        entirely.

        Leakage: a prefix HIT changes only the number of chunk ticks a
        prompt runs — public metadata of the same class as the chunk
        count itself (lengths are public by the serving model; WHICH
        prefix matched is a function of public prompt identity the
        operator registered).  The fill's comm is billed to the engine
        lifetime (`prefix_bits`, like `weight_open_bits`), not to any
        request.  Returns the number of cached pages."""
        if not self.paged:
            raise faults.EngineConfigError(
                "register_prefix requires a paged engine (paged=True)")
        toks = list(tokens)[:self.max_len - 1]
        P = self.page_size
        covered = len(toks) // P
        if covered < 1:
            raise faults.EngineConfigError(
                f"prefix shorter than one page ({P} tokens)")
        key = tuple(toks)
        if key in self._prefixes:
            return self._prefixes[key]["covered"]
        pages = self.alloc.alloc(covered)
        if pages is None:
            raise faults.EngineConfigError(
                f"page pool cannot hold a {covered}-page prefix "
                f"({self.alloc.free_count} pages free)")
        rows = covered * P
        C = self.chunk_size
        with self._comm.ledger() as led, \
                self._comm.transported(self.transport):
            state = self._pmod.init_chunk_state(self.pm, 1, self.max_len)
            lens = jnp.asarray([rows], jnp.int32)
            for ci in range(rows // C):      # P % C == 0: exact chunks
                tk = jnp.asarray([toks[ci * C:(ci + 1) * C]], jnp.int32)
                _, state = self._pmod.private_prefill_chunk(
                    self.pm, state, tk, ci * C, lens,
                    jit=self.decode_jit, lookahead=self.lookahead,
                    final=False)
        self.prefix_bits += led.total_bits(False)
        pid = jnp.asarray(pages)

        def fill(a, d):
            return a.at[pid].set(
                d[:, :rows].reshape(covered, P, *d.shape[2:]))
        self.pools = [
            jax.tree.map(fill, pl, {"ek": lst["ek"], "ev": lst["ev"],
                                    "bk": lst["bk"], "bv": lst["bv"]})
            for pl, lst in zip(self.pools, state)]
        self._prefixes[key] = {"tokens": key, "pages": pages,
                               "covered": covered}
        return covered

    def _match_prefix(self, prompt):
        """Longest registered prefix this prompt starts with, capped so
        at least one real prompt row remains for the chunk phase (the
        last token must be prefilled live to produce logits).  Returns
        (shared_page_count, entry) or None — host-side comparison of
        public token ids; bills nothing."""
        best = None
        for ent in self._prefixes.values():
            pl = len(ent["tokens"])
            if len(prompt) < pl or tuple(prompt[:pl]) != ent["tokens"]:
                continue
            k = min(ent["covered"], (len(prompt) - 1) // self.page_size)
            if k > 0 and (best is None or k > best[0]):
                best = (k, ent)
        return best

    def _release_slot(self, i: int):
        """Eagerly return slot i's pages to the free list at eviction.
        Pages whose COW refcount hits zero are ZEROED across every
        layer (zero-on-free): a recycled page must read as pristine
        unwritten rows — zero share opened against zero mask — never as
        a prior request's (ek, bk) open-mask pairing."""
        if not self.paged:
            return
        freed = [pid for pid in map(int, self.page_table[i])
                 if pid and self.alloc.release(pid)]
        self.page_table[i] = 0
        if freed:
            idx = jnp.asarray(freed)
            self.pools = [jax.tree.map(lambda a: a.at[idx].set(0), pl)
                          for pl in self.pools]

    def _prefill_tick_inputs(self, plans, ci: int):
        """Inputs of batched paged chunk tick `ci`: the FULL slot width
        every tick (one shape-static program at any occupancy).
        Non-prefilling slots — active decoders and empty slots alike —
        run dummy tokens at pos 0 / lens 1 through an all-scratch page
        table row, so their garbage K/V rows land in the scratch page
        and are zeroed in-program.  A request whose prompt finished
        early re-runs its FINAL chunk (re-opened rows stay consistent:
        the fresh mask pair still satisfies ek + bk = K)."""
        C, B = self.chunk_size, self.max_slots
        toks = np.zeros((B, C), np.int32)
        pos = np.zeros(B, np.int32)
        lens = np.ones(B, np.int32)
        pt = np.zeros((B, self.nb), np.int32)
        for p in plans:
            b = p["slot"]
            cib = min(ci, p["n_chunks"] - 1)
            p0 = p["off"] + cib * C
            pad = p["off"] + p["n_chunks"] * C - p["S"]
            padded = p["req"].prompt + [0] * pad
            toks[b] = padded[p0:p0 + C]
            pos[b] = p0
            lens[b] = p["S"]
            pt[b] = self.page_table[b]
        return (jnp.asarray(toks), jnp.asarray(pt), jnp.asarray(pos),
                jnp.asarray(lens))

    def _paged_prefill(self, pairs):
        """Transactional batched paged admission: plan (prefix match,
        page allocation), then prefill every request in `pairs` with
        ONE batched chunk tick per chunk index — max(ceil(S_i/C))
        dispatches for the whole wave.  Page exhaustion is a CAPACITY
        condition: the request re-enters the queue front for next tick,
        unbilled and unpunished.  A protocol fault rolls back pools,
        page table, π1 registry, positions, outputs and the allocator
        to the pre-batch snapshot (partial comm stays billed,
        sum-conserved across the batch) and retries/quarantines each
        member.  Returns the admitted (slot, request) list; the caller
        writes `self.slots`."""
        C, P = self.chunk_size, self.page_size
        suite = self.pm.suite()
        a_snap = self.alloc.snapshot()
        plans, deferred = [], []
        for slot, req in pairs:
            S = len(req.prompt)
            hit = self._match_prefix(req.prompt)
            shared = list(hit[1]["pages"][:hit[0]]) if hit else []
            off = len(shared) * P
            n_chunks = -(-(S - off) // C)
            n_fresh = -(-(off + n_chunks * C) // P) - len(shared)
            fresh = self.alloc.alloc(n_fresh)
            if fresh is None:
                # capacity, not a fault: wait a tick for pages to free
                req.not_before = self.ticks + 1
                deferred.append(req)
                continue
            for pid in shared:
                self.alloc.retain(pid)
            if shared:
                self.prefix_hits += 1
            plans.append({"slot": slot, "req": req, "S": S, "off": off,
                          "n_chunks": n_chunks,
                          "pages": shared + fresh})
        self.queue[:0] = deferred          # FIFO order preserved
        if not plans:
            return []
        snap = (list(self.pools), self.page_table.copy(),
                list(self.pst), self.pos.copy(),
                {p["req"].rid: len(p["req"].out) for p in plans})
        for p in plans:
            row = np.zeros(self.nb, np.int32)
            row[:len(p["pages"])] = p["pages"]
            self.page_table[p["slot"]] = row
        reqs = [p["req"] for p in plans]
        first_tok, pend = {}, None
        try:
            with faults.phase("prefill"), \
                    faults.integrity(self.integrity):
                for p in plans:
                    # per-request π1 draw (billed to the request),
                    # spliced into the slot-width registry
                    with self._billed(p["req"]):
                        subs = [suite.chunk_perm_state(1, self.max_len)
                                for _ in range(self.cfg.num_layers)]
                    for li, sub in enumerate(subs):
                        self.pst[li] = suite.chunk_perm_insert(
                            self.pst[li], p["slot"], sub)
                for ci in range(max(p["n_chunks"] for p in plans)):
                    toks, pt_in, ps, ln = \
                        self._prefill_tick_inputs(plans, ci)
                    with self._comm.ledger() as tick:
                        pend = tick
                        last, self.pools = \
                            self._pmod.private_prefill_chunk_paged(
                                self.pm, self.pools, pt_in, self.pst,
                                toks, ps, ln, jit=self.decode_jit,
                                lookahead=self.lookahead)
                    self._bill_shared(tick, reqs)
                    pend = None
                    self.chunk_ticks += 1
                    for p in plans:
                        if p["n_chunks"] - 1 != ci:
                            continue
                        # this request's final chunk: run its head row
                        with self._billed(p["req"]):
                            lgs = self._pmod.private_chunk_head(
                                self.pm,
                                last[p["slot"]:p["slot"] + 1],
                                jit=self.decode_jit)
                        lg = self._guard_logits(
                            np.array(lgs)[0], p["req"].rid,
                            f"prefill logits (rid {p['req'].rid})")
                        first_tok[p["req"].rid] = int(np.argmax(lg))
        except Exception as err:
            if pend is not None:
                # the tick that faulted: bill its partial comm exactly
                self._bill_shared(pend, reqs)
            (self.pools, self.page_table, self.pst, self.pos,
             snap_out) = (snap[0], snap[1], snap[2], snap[3], snap[4])
            self.alloc.restore(a_snap)
            for p in plans:
                del p["req"].out[snap_out[p["req"].rid]:]
            if not isinstance(err, faults.ServingFault):
                raise
            self.prefill_failures += 1
            self._beat(dealer=not isinstance(err, faults.DealerFault))
            for p in plans:
                self._register_failure(p["req"], err, "prefill")
                if p["req"].status != "quarantined":
                    self.queue.append(p["req"])
            return []
        admitted = []
        for p in plans:
            self.pos[p["slot"]] = p["S"]
            self._emit(p["req"], first_tok[p["req"].rid])
            self.prefills += 1
            admitted.append((p["slot"], p["req"]))
        self._beat()
        return admitted

    def step(self) -> bool:
        """One tick: admit, decode the full slot width, evict.

        Crash safety: the decode is transactional.  A ServingFault
        anywhere in the batched step commits NOTHING (caches, pos and
        outputs are untouched since the new caches are only adopted on
        success), bills the partial tick sum-conservingly across the
        active requests, and retries next tick; `max_retries`
        consecutive failed ticks mark the active requests `failed` and
        free their slots — the engine itself never dies.  A per-slot
        fault detected at the logits seam (NaN / envelope) rolls back
        ONLY that slot's cache rows; the slot retries the same position
        next tick (other slots commit and advance normally)."""
        with self._comm.transported(self.transport):
            return self._step()

    def _step(self) -> bool:
        if self.preemption is not None and self.preemption.should_stop():
            self.draining = True
        self.ticks += 1
        self._admit()
        # prefill emits a token and may already satisfy the request
        # (max_new_tokens=1) — never decode a finished slot
        self._evict()
        if self.paged:
            # decode growth: the tick's new K/V row lands at pos[i] —
            # allocate that page now, or finish the request truncated
            # when the pool is dry (the slot-capacity eviction class;
            # never a protocol fault)
            for i, s in enumerate(self.slots):
                if s is None:
                    continue
                bi = int(self.pos[i]) // self.page_size
                if self.page_table[i, bi]:
                    continue
                got = self.alloc.alloc(1)
                if got is not None:
                    self.page_table[i, bi] = got[0]
                    continue
                if not s.done:
                    s.truncated = True
                self._on_finish(s)
                self.finished.append(s)
                self._release_slot(i)
                self.slots[i] = None
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return bool(self.queue) and not self.draining
        # decode the FULL slot width every tick: an empty slot runs a
        # dummy token at pos 0 (its logits are discarded and its cache
        # rows are rewritten wholesale by the next admit's prefill
        # splice), so ONE (max_slots,)-shaped program serves every tick
        # regardless of occupancy — a partial-batch gather would compile
        # one program per active-slot count and break the
        # len(buckets) + 1 program budget
        toks = jnp.asarray([[s.out[-1]] if s is not None else [0]
                            for s in self.slots], jnp.int32)
        pos = jnp.asarray([int(self.pos[i]) if s is not None else 0
                           for i, s in enumerate(self.slots)], jnp.int32)
        old_state = self.pools if self.paged else self.caches
        if self.paged:
            # empty slots point at scratch: their dummy write is zeroed
            # in-program instead of corrupting a live page
            pt_in = np.zeros((self.max_slots, self.nb), np.int32)
            for i in active:
                pt_in[i] = self.page_table[i]
            pt_in = jnp.asarray(pt_in)
        try:
            with faults.phase("decode"), \
                    faults.integrity(self.integrity), \
                    self._comm.ledger() as tick:
                if self.paged:
                    logits, new_caches = \
                        self._pmod.private_decode_step_paged(
                            self.pm, self.pools, pt_in, self.pst, toks,
                            pos, jit=self.decode_jit,
                            lookahead=self.lookahead)
                else:
                    logits, new_caches = self._pmod.private_decode_step(
                        self.pm, self.caches, toks, pos,
                        jit=self.decode_jit, lookahead=self.lookahead)
        except Exception as err:
            # nothing was committed; bill the partial tick exactly
            self._bill_tick(tick, active)
            if not isinstance(err, faults.ServingFault):
                raise
            self._beat(dealer=not isinstance(err, faults.DealerFault))
            self._tick_failures += 1
            if self._tick_failures > self.max_retries:
                # persistent protocol outage: fail the active fleet so
                # the engine survives to serve new traffic
                for i in active:
                    req = self.slots[i]
                    req.status = "failed"
                    req.retries += 1
                    self._note_fault(err, "decode", req.rid,
                                     req.retries, "failed")
                    self._accumulate(req, self._comm.CommLedger())
                    self.failed.append(req)
                    self._release_slot(i)
                    self.slots[i] = None
                self._tick_failures = 0
            else:
                self._note_fault(err, "decode", None,
                                 self._tick_failures, "retried")
            return True
        self._tick_failures = 0
        self._beat()
        if self.integrity == "paranoid":
            faults.check_tree_match(new_caches, old_state,
                                    "decode cache write")
        lg = np.array(logits)
        bad = []
        with faults.phase("decode"):
            for i in active:
                req = self.slots[i]
                try:
                    lg[i] = self._guard_logits(
                        lg[i], req.rid, f"decode logits (rid {req.rid})")
                except faults.ProtocolIntegrityError as err:
                    # per-slot fault: roll back this slot only; the
                    # request retries the SAME position next tick or
                    # quarantines
                    bad.append(i)
                    self._register_failure(req, err, "decode")
        if bad:
            if self.paged:
                # per-slot rollback in page space: restore every page
                # the bad slots own (restoring a COW prefix page is a
                # value no-op — sharers hold identical prefix rows)
                pids = np.unique(self.page_table[np.asarray(bad)])
                bidx = jnp.asarray(pids[pids != 0])
            else:
                bidx = jnp.asarray(bad)
            new_caches = [
                jax.tree.map(lambda nw, old: nw.at[bidx].set(old[bidx]),
                             nl, ol)
                for nl, ol in zip(new_caches, old_state)]
        if self.paged:
            self.pools = new_caches
        else:
            self.caches = new_caches
        for i in active:
            if i in bad:
                continue
            self._emit(self.slots[i], int(lg[i, 0].argmax()))
            self.pos[i] += 1
        self.decode_ticks += 1
        # exact per-request attribution of the batched step's comm —
        # afflicted slots did the same protocol work, so they are
        # billed the same share
        self._bill_tick(tick, active)
        for i in bad:
            if self.slots[i].status == "quarantined":
                self._release_slot(i)
                self.slots[i] = None
        self._evict()
        return True

    def run_to_completion(self, max_steps: int = 10_000
                          ) -> tuple[dict, dict]:
        """Serve the queue; returns (outputs, per-request comm stats),
        both cumulative over every request this engine has finished."""
        for _ in range(max_steps):
            if not self.step():
                break
        return {r.rid: r.out for r in self.finished}, self.stats

    # ---- graceful drain + health -------------------------------------------
    def drain(self, max_steps: int = 10_000) -> tuple[dict, dict]:
        """Graceful drain (PreemptionGuard path): stop admitting, run
        the active slots to completion, return outputs + stats.  Queued
        requests stay queued (a restarted engine can resubmit them);
        partial outputs of still-active requests are NOT flushed here
        because draining runs them to their natural finish."""
        self.draining = True
        for _ in range(max_steps):
            if all(s is None for s in self.slots):
                break
            if not self.step():
                break
        return {r.rid: r.out for r in self.finished}, self.stats

    def health(self) -> dict:
        """Liveness/robustness snapshot (launch/serve.py --health):
        logical-party heartbeats, triple-pool stock, slot occupancy,
        quarantine census and the survived-fault log summary."""
        dead = set(self.heartbeats.dead_hosts())
        dealer = self.pm.dealer
        out = {
            "parties": {h: ("dead" if h in dead else "alive")
                        for h in self.heartbeats.last},
            "all_alive": not dead,
            "pool": dealer.stock() if hasattr(dealer, "stock") else None,
            "slots": {"total": self.max_slots,
                      "active": sum(s is not None for s in self.slots)},
            "weight_open_bits": self.weight_open_bits,
            "transport": self.transport.stats(),
            "queue_depth": len(self.queue),
            "quarantined": [r.rid for r in self.quarantined],
            "failed": [r.rid for r in self.failed],
            "faults": faults.summarize_faults(self.fault_log),
            "retries": {"prefill_failures": self.prefill_failures,
                        "tick_failures": self._tick_failures},
            "ticks": self.ticks,
            "draining": self.draining,
        }
        if self.paged:
            # free/used page census + prefix-cache telemetry (bench
            # reads high_water for the live-page memory ratio)
            out["pages"] = dict(self.alloc.stats(),
                                prefix_cached=len(self._prefixes),
                                prefix_hits=self.prefix_hits,
                                prefix_bits=self.prefix_bits)
        return out

    def close(self):
        """Release runtime processes: the transport peer and (when
        dealer_proc) the dealer service.  Idempotent; loopback engines
        have nothing to release."""
        t = getattr(self, "transport", None)
        if t is not None:
            t.close()
        c = getattr(self, "_dealer_client", None)
        if c is not None:
            c.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
