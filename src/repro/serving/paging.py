"""Host-side page allocator for the paged share-domain KV cache
(DESIGN.md §13).

Pure Python/numpy bookkeeping over PUBLIC metadata: which physical page
each slot's page-table entry points at, free-list membership and COW
refcounts.  Nothing here touches shares or records comm events — page
allocation order is a function of admission order and prompt LENGTHS
only, so the ledger-independence contract is untouched by paging.

Physical page 0 is the scratch page: never allocated, never refcounted;
unallocated page-table entries point at it and every paged program
re-zeroes it after its scatter (see executor._scatter_pages).
"""
from __future__ import annotations

import numpy as np

from repro.runtime import faults


class PageAllocator:
    """Free-list allocator with copy-on-write refcounts.

    ``alloc`` returns None instead of raising when the pool cannot
    cover a request — page exhaustion is a CAPACITY condition the
    engine resolves by scheduling (requeue at admission, truncate at
    decode growth), not a protocol fault.
    """

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 2:
            raise faults.EngineConfigError(
                f"page pool needs the scratch page plus at least one "
                f"allocatable page, got n_pages={n_pages}")
        if page_size < 1:
            raise faults.EngineConfigError(
                f"page_size must be >= 1, got {page_size}")
        self.n_pages = n_pages
        self.page_size = page_size
        # LIFO free list: freshly freed pages are reused first, which
        # is exactly what the recycled-page regression test stresses
        self._free = list(range(n_pages - 1, 0, -1))
        self.ref = np.zeros(n_pages, np.int32)
        #: most pages ever simultaneously live — the numerator of the
        #: live-page memory ratio the serving bench gates on
        self.high_water = 0

    # ---- queries ------------------------------------------------------------
    @property
    def total(self) -> int:
        """Allocatable pages (the scratch page is not allocatable)."""
        return self.n_pages - 1

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def used(self) -> int:
        return self.total - len(self._free)

    def stats(self) -> dict:
        return {"total": self.total, "free": self.free_count,
                "used": self.used, "high_water": self.high_water,
                "page_size": self.page_size,
                "shared": int(np.sum(self.ref > 1))}

    # ---- allocation ---------------------------------------------------------
    def alloc(self, n: int) -> list[int] | None:
        """Take n pages (ref 1 each) or None if fewer than n are free —
        all-or-nothing, so a partially admitted request never leaks
        pages."""
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self.ref[p] = 1
        self.high_water = max(self.high_water, self.used)
        return pages

    def retain(self, page: int):
        """Add a copy-on-write reference (shared prefix hit)."""
        if page == 0 or self.ref[page] < 1:
            raise faults.EngineConfigError(
                f"retain of unallocated page {page}")
        self.ref[page] += 1

    def release(self, page: int) -> bool:
        """Drop one reference; True when the page actually returned to
        the free list (refcount hit zero) — the caller must then zero
        its pool rows (the zero-on-free invariant: a recycled page must
        never replay a prior request's open-mask pairing)."""
        if page == 0:
            return False
        if self.ref[page] < 1:
            raise faults.EngineConfigError(
                f"release of free page {page}")
        self.ref[page] -= 1
        if self.ref[page] == 0:
            self._free.append(page)
            return True
        return False

    # ---- transactional snapshot (batched-admission rollback) ---------------
    def snapshot(self) -> tuple:
        return (list(self._free), self.ref.copy(), self.high_water)

    def restore(self, snap: tuple):
        self._free, self.ref, self.high_water = (
            list(snap[0]), snap[1].copy(), snap[2])
