"""Collective matmul: overlap tensor-parallel gathers with compute.

Standard TP linears all-gather the row-sharded operand and then run one
big GEMM — serializing ICI behind the MXU.  `ring_allgather_matmul`
instead walks the ring with lax.ppermute: at every step each device
multiplies the chunk it currently holds while the next chunk is in
flight, hiding (N-1)/N of the gather latency (the classic
"collective matmul" / Wang et al. schedule).

Used inside shard_map over the `model` axis; §Perf lists it as the
collective-term lever for TP-bound cells.  Correctness vs the
all-gather-then-matmul reference is tested on 8 virtual devices in
tests/test_collective_matmul.py.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# jax.shard_map landed in 0.6; fall back to the experimental home on the
# pinned 0.4.x CPU toolchain.  pvary (explicit-sharding replication) does
# not exist there and is a no-op under the older rep-rule checker.
_shard_map = getattr(jax, "shard_map", None)
if _shard_map is None:
    from jax.experimental.shard_map import shard_map as _shard_map
_pvary = getattr(jax.lax, "pvary", lambda x, axes: x)


def ring_allgather_matmul(a_local, b_local, axis_name: str):
    """Per-shard body: a_local (m_loc, k) row-shard of A; b_local (k, n_loc)
    column-shard of B.  Returns (m, n_loc) = A @ b_local with the
    all-gather of A overlapped against per-chunk GEMMs."""
    n_dev = jax.lax.psum(1, axis_name)
    my = jax.lax.axis_index(axis_name)
    m_loc = a_local.shape[0]
    perm = [(i, (i + 1) % n_dev) for i in range(n_dev)]

    def step(carry, i):
        chunk, acc = carry
        src = (my - i) % n_dev           # owner of the chunk we hold
        part = jnp.dot(chunk, b_local,
                       preferred_element_type=jnp.float32)
        acc = jax.lax.dynamic_update_slice_in_dim(
            acc, part.astype(acc.dtype), src * m_loc, 0)
        chunk = jax.lax.ppermute(chunk, axis_name, perm)
        return (chunk, acc), None

    acc0 = _pvary(
        jnp.zeros((n_dev * m_loc, b_local.shape[1]), jnp.float32),
        (axis_name,))
    (chunk, acc), _ = jax.lax.scan(step, (a_local, acc0),
                                   jnp.arange(n_dev))
    return acc


def tp_matmul_overlapped(a, b, mesh, axis: str = "model"):
    """Global entry: A (m, k) row-sharded over `axis`, B (k, n)
    column-sharded over `axis` -> A @ B column-sharded over `axis`."""
    fn = _shard_map(
        partial(ring_allgather_matmul, axis_name=axis),
        mesh=mesh,
        in_specs=(P(axis, None), P(None, axis)),
        out_specs=P(None, axis))
    return fn(a, b)
