"""AdamW (pure JAX) with hooks for ZeRO-1 sharding and int8 gradient
compression with error feedback.

Optimizer state leaves mirror the parameter tree, so distributing the
optimizer is just a PartitionSpec choice (launch/sharding.py assigns the
`data` axis to the largest dim of each moment — ZeRO-1)."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

P32 = jnp.float32


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    # int8 gradient compression (error feedback) for DP all-reduce
    compress_grads: bool = False


def init_opt_state(params, opt: OptConfig):
    zeros = lambda p: jax.tree.map(          # noqa: E731
        lambda a: jnp.zeros(a.shape, P32), p)
    state = {"m": zeros(params), "v": zeros(params),
             "step": jnp.zeros((), jnp.int32)}
    if opt.compress_grads:
        state["err"] = zeros(params)
    return state


def _schedule(opt: OptConfig, step):
    warm = jnp.minimum(step / max(opt.warmup_steps, 1), 1.0)
    return opt.lr * warm


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(a.astype(P32)))
                        for a in jax.tree.leaves(tree)))


def compress_int8(g, err):
    """Quantize g+err to int8 per-tensor scale; return (dequantized,
    new error).  The dequantized value is what the (cheap) all-reduce
    would have carried; err accumulates the residual locally."""
    t = g.astype(P32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(t)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(t / scale), -127, 127)
    deq = q * scale
    return deq, t - deq


def adamw_update(opt: OptConfig, params, grads, state):
    step = state["step"] + 1
    if opt.compress_grads:
        pairs = jax.tree.map(compress_int8, grads, state["err"])
        grads = jax.tree.map(lambda p: p[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda p: p[1], pairs,
                               is_leaf=lambda x: isinstance(x, tuple))

    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, opt.grad_clip / (gnorm + 1e-9))
    lr = _schedule(opt, step)

    def upd(p, g, m, v):
        g = g.astype(P32) * clip
        m = opt.b1 * m + (1 - opt.b1) * g
        v = opt.b2 * v + (1 - opt.b2) * jnp.square(g)
        mhat = m / (1 - opt.b1 ** step)
        vhat = v / (1 - opt.b2 ** step)
        delta = mhat / (jnp.sqrt(vhat) + opt.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + opt.weight_decay * p.astype(P32)
        return (p.astype(P32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"m": new_m, "v": new_v, "step": step}
    if opt.compress_grads:
        new_state["err"] = new_err
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
