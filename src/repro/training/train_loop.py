"""Train-step builder (microbatched gradient accumulation) and the
fault-tolerant host loop.

The jitted step is pure: (params, opt_state, batch) -> (params,
opt_state, metrics).  Everything stateful — checkpointing, preemption,
straggler telemetry, data cursor — lives in the host loop and is
restart-exact."""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.registry import get_api
from repro.models import shard_ctx

from .optimizer import OptConfig, adamw_update, init_opt_state

P32 = jnp.float32


def build_train_step(cfg: ModelConfig, opt: OptConfig,
                     num_microbatches: int = 1):
    api = get_api(cfg)

    def loss_fn(params, batch):
        return api.train_loss(cfg, params, batch)

    def train_step(params, opt_state, batch):
        if num_microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def split(a):
                if a.ndim == 3 and a.shape[0] == 3:  # M-RoPE (3, B, S)
                    mb = a.shape[1] // num_microbatches
                    return a.reshape(3, num_microbatches, mb,
                                     a.shape[2]).transpose(1, 0, 2, 3)
                return a.reshape((num_microbatches,
                                  a.shape[0] // num_microbatches)
                                 + a.shape[1:])

            mb = jax.tree.map(split, batch)

            def acc_fn(carry, mbatch):
                loss_acc, g_acc = carry
                mbatch = jax.tree.map(
                    lambda a: shard_ctx.act(a) if a.ndim >= 2 else a,
                    mbatch)
                loss, g = jax.value_and_grad(loss_fn)(params, mbatch)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(P32), g_acc, g)
                return (loss_acc + loss, g_acc), None

            g0 = jax.tree.map(lambda a: jnp.zeros(a.shape, P32), params)
            (loss, grads), _ = jax.lax.scan(acc_fn, (jnp.zeros((), P32),
                                                     g0), mb)
            loss = loss / num_microbatches
            grads = jax.tree.map(lambda a: a / num_microbatches, grads)
        params, opt_state, om = adamw_update(opt, params, grads, opt_state)
        return params, opt_state, {"loss": loss, **om}

    return train_step


@dataclass
class TrainResult:
    step: int
    losses: list
    restarts: int = 0


def run_training(cfg: ModelConfig, opt: OptConfig, pipeline, *,
                 num_steps: int, checkpoint_mgr=None, ckpt_every: int = 50,
                 preemption=None, straggler=None, num_microbatches: int = 1,
                 params=None, log_every: int = 10, jit: bool = True
                 ) -> TrainResult:
    """Fault-tolerant training loop: resume-exact from the latest
    checkpoint (params + optimizer + data cursor), cooperative
    preemption, per-step straggler telemetry."""
    api = get_api(cfg)
    if params is None:
        params = api.init_params(cfg, jax.random.key(0))
    opt_state = init_opt_state(params, opt)
    start_step = 0

    if checkpoint_mgr is not None:
        restored = checkpoint_mgr.restore_latest(
            like={"params": params, "opt_state": opt_state})
        if restored is not None:
            params, opt_state, start_step = (restored["params"],
                                             restored["opt_state"],
                                             restored["step"])
            pipeline.resume(start_step)

    step_fn = build_train_step(cfg, opt, num_microbatches)
    if jit:
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    losses = []
    for step in range(start_step, num_steps):
        t0 = time.monotonic()
        batch = next(pipeline)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if straggler is not None:
            straggler.observe(host=0, step=step,
                              duration=time.monotonic() - t0)
        if step % log_every == 0 or step == num_steps - 1:
            losses.append((step, float(metrics["loss"])))
        if checkpoint_mgr is not None and (step + 1) % ckpt_every == 0:
            checkpoint_mgr.save(step + 1, {"params": params,
                                           "opt_state": opt_state})
        if preemption is not None and preemption.should_stop():
            if checkpoint_mgr is not None:
                checkpoint_mgr.save(step + 1, {"params": params,
                                               "opt_state": opt_state})
                checkpoint_mgr.wait()
            return TrainResult(step + 1, losses)

    if checkpoint_mgr is not None:
        checkpoint_mgr.save(num_steps, {"params": params,
                                        "opt_state": opt_state})
        checkpoint_mgr.wait()
    return TrainResult(num_steps, losses)
