"""repro: Centaur hybrid privacy-preserving Transformer inference
(ACL 2025) as a production-grade multi-pod JAX framework.

Subpackages: core (the paper's protocols + private engine), models,
configs, data, training, serving, checkpoint, runtime, kernels, launch.
"""
__version__ = "1.0.0"
