"""2-of-2 additive secret sharing over Z_{2^64} (paper §2.2).

A `ShareTensor` carries both parties' shares through one SPMD program —
the simulation form of the two-party protocol.  In the multi-pod
deployment mapping (launch/private_dryrun.py) the party axis is sharded
over the `pod` mesh axis and share exchange lowers to collective-permute.

All communication is billed through core.comm at trace time.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.runtime import faults

from . import comm, ring


@jax.tree_util.register_pytree_node_class
@dataclass
class ShareTensor:
    """x = (s0 + s1) mod 2^64 with signed-int64 representatives."""
    s0: jax.Array
    s1: jax.Array

    # pytree protocol -------------------------------------------------------
    def tree_flatten(self):
        return (self.s0, self.s1), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    # convenience ------------------------------------------------------------
    @property
    def shape(self):
        return self.s0.shape

    @property
    def ndim(self):
        return self.s0.ndim

    def reshape(self, *shape):
        return ShareTensor(self.s0.reshape(*shape), self.s1.reshape(*shape))

    def transpose(self, *axes):
        return ShareTensor(self.s0.transpose(*axes), self.s1.transpose(*axes))

    def __getitem__(self, idx):
        return ShareTensor(self.s0[idx], self.s1[idx])

    def astuple(self):
        return self.s0, self.s1

    # ring arithmetic (communication-free, Pi_Add) ----------------------------
    def __add__(self, other):
        if isinstance(other, ShareTensor):
            return ShareTensor(self.s0 + other.s0, self.s1 + other.s1)
        # public ring constant: added to share 0 only
        other = jnp.asarray(other, ring.RING_DTYPE)
        return ShareTensor(self.s0 + other, self.s1)

    __radd__ = __add__

    def __sub__(self, other):
        if isinstance(other, ShareTensor):
            return ShareTensor(self.s0 - other.s0, self.s1 - other.s1)
        other = jnp.asarray(other, ring.RING_DTYPE)
        return ShareTensor(self.s0 - other, self.s1)

    def __neg__(self):
        return ShareTensor(-self.s0, -self.s1)

    def mul_public(self, c_ring, frac_bits: int = ring.FRAC_BITS):
        """Multiply by a public fixed-point constant (free), rescale."""
        c_ring = jnp.asarray(c_ring, ring.RING_DTYPE)
        return ShareTensor(ring.truncate(self.s0 * c_ring, frac_bits),
                           ring.truncate(self.s1 * c_ring, frac_bits))

    def truncate(self, frac_bits: int = ring.FRAC_BITS):
        return ShareTensor(ring.truncate(self.s0, frac_bits),
                           ring.truncate(self.s1, frac_bits))


# ---- share lifecycle ------------------------------------------------------

def share(key, x_ring) -> ShareTensor:
    """Split a ring tensor into fresh additive shares."""
    s0 = ring.rand_ring(key, jnp.shape(x_ring))
    return ShareTensor(s0, jnp.asarray(x_ring, ring.RING_DTYPE) - s0)


def share_float(key, x, frac_bits: int = ring.FRAC_BITS) -> ShareTensor:
    return share(key, ring.encode(x, frac_bits))


def reconstruct(st: ShareTensor):
    return st.s0 + st.s1


def reconstruct_float(st: ShareTensor, frac_bits: int = ring.FRAC_BITS,
                      dtype=jnp.float32):
    return ring.decode(reconstruct(st), frac_bits, dtype)


# ---- protocol-level reveal/reshare (each costs communication) --------------

def reveal(st: ShareTensor, protocol: str = "reveal"):
    """Open a shared tensor to one party: the other party sends its share.

    1 round, numel * 64 bits (one share crosses the link)."""
    comm.record(protocol, rounds=1,
                bits=comm.numel(st.shape) * comm.RING_BITS)
    # payload seam: the sending party's share crosses the ambient
    # transport one-way (header-only ack closes the round); the opener
    # reconstructs with the share that arrived.
    (s1,) = comm.exchange(protocol, (st.s1,), reply=False)
    out = st.s0 + s1
    # chaos seam: the receiving party's reconstructed value
    if faults._INJECTORS:
        out = faults.on_open(protocol, out)
    return out


def reshare(key, x_ring, protocol: str = "reshare") -> ShareTensor:
    """Party holding plaintext x re-shares it: sends one share across.

    1 round, numel * 64 bits."""
    comm.record(protocol, rounds=1,
                bits=comm.numel(jnp.shape(x_ring)) * comm.RING_BITS)
    return share(key, x_ring)
