"""Baseline SMPC nonlinearities (the frameworks Centaur is compared to).

Implements the CrypTen/PUMA-style fixed-point approximations with real
Beaver-triple arithmetic so that (a) communication is billed with the
baselines' true cost structure and (b) the approximation error that
motivates the paper's Table 3 is reproduced, not asserted.

Secure comparisons (needed for max / piecewise selection) are *costed*
with a documented constant (2 rounds, 384 bits per compared element —
an optimistic DReLU-style protocol) while the selection itself uses the
reconstructed plaintext (a standard cost-model shortcut; the selected
branch values are still computed with Beaver ops).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import beaver, comm, ring
from .sharing import ShareTensor, reconstruct

COMPARE_ROUNDS = 2
COMPARE_BITS_PER_EL = 384


def _bill_compare(n_elements: int, protocol: str):
    comm.record(protocol, rounds=COMPARE_ROUNDS,
                bits=n_elements * COMPARE_BITS_PER_EL)


def _oracle(x: ShareTensor):
    """Plaintext view used ONLY for comparison outcomes (cost billed)."""
    return ring.decode(reconstruct(x))


def smpc_exp(x: ShareTensor, dealer, iters: int = 8) -> ShareTensor:
    """CrypTen limit approximation: (1 + x/2^k)^(2^k) via k squarings.
    Cost: k rounds, k * 128 * numel bits (matches the paper's 1024
    bits/scalar for k=8).

    Domain: diverges for x < -2^k (e.g. causal-mask logits at -1e4), so
    inputs are clamped to [-2^k, .] first — a comparison-based clamp in
    the real protocol, billed accordingly (CrypTen clamps the same
    way)."""
    lo = -float(2 ** iters) + 1.0
    _bill_compare(comm.numel(x.shape), "exp_clamp")
    xv = jnp.maximum(_oracle(x), lo)
    x = ShareTensor(ring.encode(xv) - x.s1, x.s1)  # re-embed clamped
    y = x.mul_public(ring.encode(1.0 / 2 ** iters)) + ring.encode(1.0)
    for _ in range(iters):
        y = beaver.square(y, dealer)
    return y


def smpc_reciprocal(x: ShareTensor, dealer, iters: int = 10) -> ShareTensor:
    """Newton-Raphson with CrypTen's exp-based initial guess."""
    y = smpc_exp(ShareTensor(-x.s0 + ring.encode(0.5), -x.s1), dealer) \
        .mul_public(ring.encode(3.0)) + ring.encode(0.003)
    two = ring.encode(2.0)
    for _ in range(iters):
        xy = beaver.mul(x, y, dealer)
        y = beaver.mul(y, ShareTensor(two - xy.s0, -xy.s1), dealer)
    return y


def _nr_inv_sqrt(x: ShareTensor, dealer, iters: int) -> ShareTensor:
    """The bare NR ladder: y <- y (3 - x y^2) / 2, exp-based init."""
    e = smpc_exp(ShareTensor(-(x.s0 >> 1) - ring.encode(0.2),
                             -(x.s1 >> 1)), dealer)
    y = e.mul_public(ring.encode(2.2)) + ring.encode(0.2)
    three = ring.encode(3.0)
    for _ in range(iters):
        y2 = beaver.square(y, dealer)
        xy2 = beaver.mul(x, y2, dealer)
        y = beaver.mul(y, ShareTensor(three - xy2.s0, -xy2.s1),
                       dealer).mul_public(ring.encode(0.5))
    return y


def smpc_inv_sqrt(x: ShareTensor, dealer, iters: int = 8,
                  bound: float | None = None) -> ShareTensor:
    """1/sqrt(x) via NR: y <- y (3 - x y^2) / 2, exp-based init.

    The bare ladder (bound=None, CrypTen's fixed-range behavior)
    converges only for x in roughly [1e-2, 64]: above ~100 the
    exp-based init lands outside the NR basin and the iteration
    diverges — the documented relu2-arch failure, where norm
    statistics reach the thousands.

    `bound`, a PUBLIC upper bound on x (per-config architecture
    knowledge, not data), widens the domain with a power-of-two
    pre-scale: inv_sqrt(2^{2k} x') = 2^{-k} inv_sqrt(x'), k chosen so
    bound / 2^{2k} <= 64.  The scale and its inverse are local
    arithmetic share shifts — no communication.  A single shifted
    ladder cannot cover the whole range, though: the down-shift drops
    the 2k low bits that small inputs live in (and re-running the NR
    at a finer fixed point instead would put y^2 * 2^{2 frac} within
    reach of 2^63, turning the +-1 LSB local-truncation error model
    into catastrophic wrap failures at ~0.1% per element).  So both
    ladders run — the bare one (exact where x < 64, more iterations to
    reach large 1/sqrt outputs) and the pre-scaled one (valid on
    [64, bound], where the dropped low bits are noise) — and ONE
    billed comparison against the public threshold 64 selects per
    element, the module's standard oracle-selection shortcut."""
    if bound is None or bound <= 64.0:
        return _nr_inv_sqrt(x, dealer, iters)
    # the 2k-bit pre-shift eats fractional bits: past 2^16 the shifted
    # ladder's lower edge (64 / 4^k) drops below the NR's convergent
    # range / fixed-point resolution and outputs silently collapse
    assert bound <= 65536.0, \
        f"inv_sqrt pre-scale supports bounds up to 2^16, got {bound}"
    k = int(np.ceil((np.log2(float(bound)) - 6.0) / 2.0))
    lo = _nr_inv_sqrt(x, dealer, iters + 8)
    hi = _nr_inv_sqrt(ShareTensor(x.s0 >> (2 * k), x.s1 >> (2 * k)),
                      dealer, iters)
    hi = ShareTensor(hi.s0 >> k, hi.s1 >> k)
    _bill_compare(comm.numel(x.shape), "inv_sqrt_range")
    small = _oracle(x) < 64.0
    return ShareTensor(jnp.where(small, lo.s0, hi.s0),
                       jnp.where(small, lo.s1, hi.s1))


def smpc_max(x: ShareTensor, dealer, axis: int = -1) -> ShareTensor:
    """Tree-reduction max: log2(n) comparison rounds billed."""
    n = x.shape[axis]
    rounds = int(np.ceil(np.log2(max(n, 2))))
    _bill_compare(comm.numel(x.shape) * rounds, "max")
    m = jnp.max(_oracle(x), axis=axis, keepdims=True)
    # the max enters subsequent math as a *shared* value; model it as a
    # fresh sharing (selection moves shares, costs are in the compares)
    return ShareTensor(ring.encode(m), jnp.zeros_like(ring.encode(m)))


def smpc_softmax(x: ShareTensor, dealer, axis: int = -1) -> ShareTensor:
    m = smpc_max(x, dealer, axis)
    e = smpc_exp(x - ShareTensor(m.s0, m.s1), dealer)
    s = ShareTensor(jnp.sum(e.s0, axis, keepdims=True),
                    jnp.sum(e.s1, axis, keepdims=True))
    r = smpc_reciprocal(s, dealer)
    rb = ShareTensor(jnp.broadcast_to(r.s0, e.shape),
                     jnp.broadcast_to(r.s1, e.shape))
    return beaver.mul(e, rb, dealer)


# GeLU piecewise polynomial (PUMA-style): fit once at import
import math  # noqa: E402

_GELU_DEG = 6
_xs = np.linspace(-4.0, 4.0, 4001)
_GELU_COEF = np.polyfit(
    _xs, 0.5 * _xs * (1.0 + np.vectorize(math.erf)(_xs / np.sqrt(2.0))),
    _GELU_DEG)


def smpc_gelu(x: ShareTensor, dealer) -> ShareTensor:
    """Piecewise: x>4 -> x; x<-4 -> 0; else degree-6 poly (Horner with
    Beaver muls).  Two comparisons per element billed."""
    _bill_compare(2 * comm.numel(x.shape), "gelu_select")
    xo = _oracle(x)
    lo, hi = xo < -4.0, xo > 4.0
    acc = ShareTensor(jnp.full(x.shape, ring.encode(_GELU_COEF[0]),
                               ring.RING_DTYPE), jnp.zeros(x.shape,
                                                           ring.RING_DTYPE))
    for c in _GELU_COEF[1:]:
        acc = beaver.mul(acc, x, dealer) + ring.encode(float(c))
    # oracle-selected branches (costs billed above)
    mid = ring.decode(reconstruct(acc))
    sel = jnp.where(hi, xo, jnp.where(lo, 0.0, mid))
    return ShareTensor(ring.encode(sel), jnp.zeros(x.shape,
                                                   ring.RING_DTYPE))


def smpc_relu2(x: ShareTensor, dealer) -> ShareTensor:
    """relu(x)^2 (squared-ReLU archs): one DReLU comparison selects x
    or 0 (billed; selection via the documented oracle shortcut), then a
    Beaver square."""
    _bill_compare(comm.numel(x.shape), "relu_select")
    sel = jnp.maximum(_oracle(x), 0.0)
    r = ShareTensor(ring.encode(sel) - x.s1, x.s1)  # re-embed selected
    return beaver.square(r, dealer)


def smpc_silu(x: ShareTensor, dealer) -> ShareTensor:
    """silu(x) = x * sigmoid(x); sigmoid via exp + NR reciprocal — the
    CrypTen-style composition that gives SMPC baselines SwiGLU coverage
    (llama-family shapes) with the baselines' true cost structure.

    Domain: the NR reciprocal only converges for arguments < ~666, i.e.
    exp(-x) + 1 needs x >= ~-6.5, so inputs are clamped to [-6, .)
    first (one billed comparison, like smpc_exp's own clamp) and the
    clamped value is used in the product too — silu saturates at
    silu(-6) ~= -0.015 below the clamp, a bounded error where the
    unclamped composition returns ring-overflow garbage."""
    _bill_compare(comm.numel(x.shape), "silu_clamp")
    xv = jnp.maximum(_oracle(x), -6.0)
    xc = ShareTensor(ring.encode(xv) - x.s1, x.s1)  # re-embed clamped
    e = smpc_exp(ShareTensor(-xc.s0, -xc.s1), dealer)
    sig = smpc_reciprocal(e + ring.encode(1.0), dealer)
    return beaver.mul(xc, sig, dealer)


def smpc_layernorm(x: ShareTensor, gamma_sh: ShareTensor,
                   beta_sh: ShareTensor, dealer,
                   eps: float = 1e-5,
                   var_bound: float | None = None) -> ShareTensor:
    d = x.shape[-1]
    mu = ShareTensor(jnp.sum(x.s0, -1, keepdims=True),
                     jnp.sum(x.s1, -1, keepdims=True)).mul_public(
                         ring.encode(1.0 / d))
    c = x - ShareTensor(jnp.broadcast_to(mu.s0, x.shape),
                        jnp.broadcast_to(mu.s1, x.shape))
    sq = beaver.square(c, dealer)
    var = ShareTensor(jnp.sum(sq.s0, -1, keepdims=True),
                      jnp.sum(sq.s1, -1, keepdims=True)).mul_public(
                          ring.encode(1.0 / d)) + ring.encode(eps)
    inv = smpc_inv_sqrt(var, dealer, bound=var_bound)
    invb = ShareTensor(jnp.broadcast_to(inv.s0, x.shape),
                       jnp.broadcast_to(inv.s1, x.shape))
    y = beaver.mul(c, invb, dealer)
    gb = ShareTensor(jnp.broadcast_to(gamma_sh.s0, x.shape),
                     jnp.broadcast_to(gamma_sh.s1, x.shape))
    return beaver.mul(y, gb, dealer) + ShareTensor(
        jnp.broadcast_to(beta_sh.s0, x.shape),
        jnp.broadcast_to(beta_sh.s1, x.shape))


def smpc_tanh(x: ShareTensor, dealer) -> ShareTensor:
    """tanh(x) = 2 sigmoid(2x) - 1; sigmoid via exp + reciprocal."""
    e = smpc_exp(ShareTensor(-2 * x.s0, -2 * x.s1), dealer)
    r = smpc_reciprocal(e + ring.encode(1.0), dealer)
    return r.mul_public(ring.encode(2.0)) - ring.encode(1.0)


# ---- MPCFormer substitutions (paper Eq. 8) ----------------------------------

def quad_gelu(x: ShareTensor, dealer) -> ShareTensor:
    """Quad(x) = 0.125 x^2 + 0.25 x + 0.5 — MPCFormer's GeLU."""
    sq = beaver.square(x, dealer).mul_public(ring.encode(0.125))
    return sq + x.mul_public(ring.encode(0.25)) + ring.encode(0.5)


def quad_softmax(x: ShareTensor, dealer, axis: int = -1,
                 c: float = 5.0) -> ShareTensor:
    """2Quad(x) = (x+c)^2 / sum (x+c)^2 — MPCFormer's Softmax.

    Causal-mask handling: MPCFormer zeroes masked positions by mapping
    them to x = -c (so (x+c)^2 = 0) rather than -1e4 (which 2Quad would
    square into an overflow).  Clamp billed as one comparison."""
    _bill_compare(comm.numel(x.shape), "quad_clamp")
    xv = jnp.maximum(_oracle(x), -c)
    x = ShareTensor(ring.encode(xv) - x.s1, x.s1)
    sq = beaver.square(x + ring.encode(c), dealer)
    s = ShareTensor(jnp.sum(sq.s0, axis, keepdims=True),
                    jnp.sum(sq.s1, axis, keepdims=True))
    # NR reciprocal converges only for y0*x < 2; the sum of n squares
    # can reach ~n*(x+c)^2, so pre-scale by the public 1/(4n) bound
    # (free) and fold the scale back into the product.
    scale = 1.0 / (4.0 * x.shape[axis])
    r = smpc_reciprocal(s.mul_public(ring.encode(scale)), dealer)
    rs = r.mul_public(ring.encode(scale))
    rb = ShareTensor(jnp.broadcast_to(rs.s0, sq.shape),
                     jnp.broadcast_to(rs.s1, sq.shape))
    return beaver.mul(sq, rb, dealer)
