# The paper's primary contribution: the Centaur hybrid PPTI protocol
# stack.  `ring` must be imported first (it enables 64-bit mode before
# any ring tensor exists).
from . import ring  # noqa: F401  (isort: keep first)
from . import beaver, comm, nonlinear, permute, protocols, sharing  # noqa: F401
from .sharing import ShareTensor, reconstruct, reconstruct_float, share, share_float  # noqa: F401
