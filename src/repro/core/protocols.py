"""The Centaur protocol set (paper §5.2, Table 1).

=================  ============================  ======  ================
protocol           signature                     rounds  bits
=================  ============================  ======  ================
Pi_Add             [x],[y] -> [x+y]              0       0
Pi_ScalMul         A, [X]  -> [A X^T]            0       0
Pi_MatMul          [X],[Y] -> [X Y^T]            1       256 n^2
Pi_PPP             [X]     -> [X pi]             1       256 n^2
Pi_PPSM/GeLU/LN    [X pi]  -> [f(X) pi]          2       128 n^2
=================  ============================  ======  ================
"""
from __future__ import annotations

import jax.numpy as jnp

from . import beaver, comm, permute, ring
from .sharing import ShareTensor


def scal_mul(w_ring, x: ShareTensor, frac_bits: int = ring.FRAC_BITS,
             rescale: bool = True) -> ShareTensor:
    """Pi_ScalMul: x @ w^T with permuted-plaintext w (out, in).

    Communication-free: each party multiplies its own share locally.
    """
    comm.record("scalmul", rounds=0, bits=0)
    wt = jnp.swapaxes(jnp.asarray(w_ring, ring.RING_DTYPE), -1, -2)
    z = ShareTensor(ring.ring_matmul(x.s0, wt), ring.ring_matmul(x.s1, wt))
    return z.truncate(frac_bits) if rescale else z


def linear(w_ring, b_ring, x: ShareTensor,
           frac_bits: int = ring.FRAC_BITS) -> ShareTensor:
    """Permuted-plaintext linear layer: x @ w^T + b (b already at scale f)."""
    y = scal_mul(w_ring, x, frac_bits)
    if b_ring is not None:
        y = y + jnp.asarray(b_ring, ring.RING_DTYPE)
    return y


def matmul(x: ShareTensor, y: ShareTensor, dealer,
           frac_bits: int = ring.FRAC_BITS) -> ShareTensor:
    """Pi_MatMul: share x share matmul via Beaver triples."""
    return beaver.matmul(x, y, dealer, frac_bits)


def pp_permute(x: ShareTensor, p, axis: int = -1) -> ShareTensor:
    """Pi_PPP: [X] -> [X pi] for a permutation unknown to both parties.

    Numerics: gather on both shares (exactly equivalent to the paper's
    Beaver matmul against the shared dense permutation matrix — see
    pp_permute_exact and tests/test_protocols.py).  Cost billed at the
    protocol's Pi_MatMul price: 1 round, 2*(numel(X) + n^2)*64 bits.
    """
    n = int(x.shape[axis])
    bits = 2 * (comm.numel(x.shape) + n * n) * comm.RING_BITS
    comm.record("ppp", rounds=1, bits=bits)
    return ShareTensor(permute.apply_perm(x.s0, p, axis),
                       permute.apply_perm(x.s1, p, axis))


def _gather_batched(x: ShareTensor, perms, axis: int) -> ShareTensor:
    """Apply one independent index-permutation per leading-axis element
    (perms: (B, n)) along `axis` of both shares — the shared gather
    body of the per-slot and cached-π1 Pi_PPP variants."""
    B, n = perms.shape
    assert int(x.shape[0]) == B and int(x.shape[axis]) == n, \
        (x.shape, perms.shape, axis)
    ax = axis % x.ndim
    idx_shape = [1] * x.ndim
    idx_shape[0], idx_shape[ax] = B, n
    idx = perms.reshape(idx_shape)
    return ShareTensor(jnp.take_along_axis(x.s0, idx, axis=ax),
                       jnp.take_along_axis(x.s1, idx, axis=ax))


def pp_permute_batched(x: ShareTensor, perms, axis: int = -1
                       ) -> ShareTensor:
    """Pi_PPP with an INDEPENDENT permutation per leading-axis element.

    Continuous-batching decode permutes every serving slot's attention
    scores with its own fresh π1 (perms: (B, n)); a shared permutation
    would let P1 align revealed score columns across tenants.  Billed
    at the Pi_MatMul price per slot: 1 round,
    2*(numel(X) + B n^2)*64 bits — for B == 1 exactly the sequential
    pp_permute cost."""
    B, n = perms.shape
    bits = 2 * (comm.numel(x.shape) + B * n * n) * comm.RING_BITS
    comm.record("ppp", rounds=1, bits=bits)
    return _gather_batched(x, perms, axis)


def pp_permute_setup(n_perms: int, n: int):
    """Bill the one-time shared permutation-matrix material for a π
    that later `pp_permute_cached` calls reuse.

    `pp_permute`'s per-call bill is 2*(numel(X) + n^2)*64: the n^2 term
    is the Beaver material for the shared dense permutation matrix.
    Chunked prefill (DESIGN.md §10) draws ONE π1 per request per layer
    and permutes every chunk's scores under it, so the matrix term is
    paid once here (per independent permutation) and each chunk pays
    only for its own data."""
    comm.record("ppp", rounds=1,
                bits=2 * n_perms * n * n * comm.RING_BITS)


def pp_permute_cached(x: ShareTensor, perms, axis: int = -1
                      ) -> ShareTensor:
    """Pi_PPP against a permutation whose shared-matrix material was
    already billed by `pp_permute_setup`: per-call cost is the data
    opens only — 1 round, 2*numel(X)*64 bits.  `perms` is (B, n), one
    independent permutation per leading-axis element (pass the
    precomputed inverse to undo a cached permutation)."""
    comm.record("ppp", rounds=1,
                bits=2 * comm.numel(x.shape) * comm.RING_BITS)
    return _gather_batched(x, perms, axis)


def pp_permute_exact(x: ShareTensor, p_shared: ShareTensor,
                     dealer) -> ShareTensor:
    """Reference Pi_PPP (paper Algorithm 6): Beaver matmul against the
    secret-shared 0/1 permutation matrix.  Entries are *raw* ring
    integers (not fixed-point scaled) so no truncation occurs and the
    result is bit-exact equal to the gather fast path."""
    return beaver.matmul(x, p_shared, dealer, rescale=False,
                         protocol="ppp")
