"""End-to-end private transformer inference (paper §5).

Five execution modes over the *same* plaintext parameters, each a
``ProtocolSuite`` (core/suites/) driven by ONE shared layer/block
executor (core/suites/executor.py):

  centaur   — the paper: permuted-plaintext weights (Pi_ScalMul linears),
              secret-shared activations, share<->permuted-state conversion
              for exact nonlinearities (Pi_PPSM/PPGeLU/PPLN, Pi_PPP).
  smpc      — PUMA/CrypTen-like baseline: weights and activations both
              shared; every linear is a Beaver Pi_MatMul; nonlinearities
              via iterative fixed-point approximations (core.smpc_nl).
  mpcformer — MPCFormer baseline: smpc linears + Quad/2Quad substitution
              (reproduces the accuracy drop of paper Table 3).
  secformer — 2Quad softmax with exact-structure GeLU/SiLU approximation.
  permute   — Yuan et al. (STI) baseline: plaintext compute on permuted
              weights/data; exposes O1 = QK^T etc. (the paper's Fig. 4
              privacy failure, reproduced by benchmarks/privacy_attack).

Families: encoder (BERT incl. pooler adaptation), dense decoders
(GPT-2 / llama-style with RoPE + GQA + SwiGLU), fine-grained MoE
(expert-permuted router — beyond-paper extension), and Mamba2 SSM blocks
(Pi_PPSSD).  The engine mirrors models/* exactly so Centaur's output can
be compared bit-for-bit (up to fixed-point) against plaintext.

This module is the assembly + compatibility surface: it prepares a
`PrivateModel` for a mode and keeps the historical entry points
(`centaur_forward`, `smpc_forward`, `private_forward`, prefill/decode)
as thin wrappers over the suite executor.  `pm.exposed` records what
the cloud P1 actually observes per mode — the attack surface evaluated
by benchmarks/privacy_attack.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import beaver, comm, permute, protocols, ring
from .sharing import reconstruct, share
from .suites import (KeyStream, PrivateModel, encrypt_tokens,  # noqa: F401
                     get_suite)
from .suites import centaur as _centaur
from .suites import executor as _exec
from .suites import smpc as _smpc
from .suites.centaur import rope_on_shares  # noqa: F401  (compat)
from .suites.executor import init_slot_caches  # noqa: F401  (compat)

P32 = jnp.float32


# =============================================================================
# model assembly (initialization phase, paper §5.1)
# =============================================================================

def build_private_model(cfg, params, key, mode: str = "centaur",
                        use_pool: bool = False,
                        dealer_factory=None) -> PrivateModel:
    ks = KeyStream(key)
    dk = ks()
    if dealer_factory is not None:
        # runtime injection seam: the serving engine passes a factory
        # that builds an AsyncTriplePool backed by a dealer process —
        # seeded with the SAME KeyStream draw the in-process pool would
        # get, so the triple PRG stream is identical either way
        dealer = dealer_factory(dk)
    else:
        dealer = (beaver.TriplePool(dk) if use_pool
                  else beaver.TripleDealer(dk))
    d = cfg.d_model
    perms = {"d": permute.gen_perm(ks(), d)}
    if cfg.family in ("dense", "encoder", "moe"):
        ffd = cfg.moe_d_ff if cfg.family == "moe" else cfg.d_ff
        perms["ff"] = permute.gen_perm(ks(), ffd)
        if cfg.use_mla:
            perms["q_lora"] = permute.gen_perm(ks(), cfg.q_lora_rank)
            perms["kv_lora"] = permute.gen_perm(ks(), cfg.kv_lora_rank)
        if cfg.family == "moe":
            perms["e"] = permute.gen_perm(ks(), cfg.n_routed_experts)
            perms["shared_ff"] = permute.gen_perm(
                ks(), cfg.n_shared_experts * cfg.moe_d_ff)
    if cfg.family in ("ssm", "hybrid"):
        perms.update(_centaur.mamba_channel_perms(cfg, ks))
    if cfg.family == "hybrid":
        perms["ff"] = permute.gen_perm(ks(), cfg.d_ff)
    perms["v"] = permute.gen_perm(ks(), cfg.vocab_size)

    pm = PrivateModel(cfg, mode, perms, {}, ks, dealer)
    if mode in ("centaur", "permute"):
        pm.wp = _centaur.prepare_permuted(cfg, params, perms)
    elif mode in ("smpc", "mpcformer", "secformer"):
        pm.wp = _smpc.prepare_shared(cfg, params, ks, dealer)
    else:
        raise ValueError(mode)
    return pm


# =============================================================================
# forward passes — thin wrappers over the suite executor
# =============================================================================

def private_forward(pm: PrivateModel, tokens, jit: bool = False):
    """Full private forward in pm.mode; returns plaintext logits after
    the client reconstructs the output (class logits for BERT)."""
    return _exec.model_forward(pm, tokens, jit=jit)


def centaur_forward(pm: PrivateModel, tokens):
    assert pm.mode == "centaur", pm.mode
    return _exec.model_forward(pm, tokens)


def centaur_forward_jit(pm: PrivateModel, tokens):
    assert pm.mode == "centaur", pm.mode
    return _exec.model_forward(pm, tokens, jit=True)


def smpc_forward(pm: PrivateModel, tokens):
    """PUMA/MPCFormer-style baseline (encoder/dense families)."""
    return _exec.model_forward(pm, tokens)


def smpc_forward_jit(pm: PrivateModel, tokens):
    return _exec.model_forward(pm, tokens, jit=True)


def permute_forward(pm: PrivateModel, tokens):
    assert pm.mode == "permute", pm.mode
    return _exec.model_forward(pm, tokens)


# =============================================================================
# private serving: slot-stacked padded KV-cache prefill/decode, any
# servable suite (DESIGN.md §7).  The centaur_* names are kept from the
# pre-suite API; they serve whatever mode pm was built with.
# =============================================================================

def private_prefill(pm: PrivateModel, tokens, max_len: int | None = None,
                    jit: bool = False, lens=None):
    """Private prefill; `lens` (B,) true prompt lengths switches on the
    bucketed padded path (tokens pre-padded to a public bucket length,
    logits gathered at the last real token) — see executor.prefill."""
    return _exec.prefill(pm, tokens, max_len=max_len, jit=jit, lens=lens)


def private_decode_step(pm: PrivateModel, caches, token, pos,
                        jit: bool = False, lookahead: int = 4):
    return _exec.decode_step(pm, caches, token, pos, jit=jit,
                             lookahead=lookahead)


def init_chunk_state(pm: PrivateModel, n_slots: int, max_len: int):
    """Chunked-prefill cache/mask/permutation state (DESIGN.md §10)."""
    return _exec.init_chunk_state(pm, n_slots, max_len)


def private_prefill_chunk(pm: PrivateModel, state, token, pos, lens,
                          jit: bool = False, lookahead: int = 4,
                          final: bool | None = None):
    """One chunked-prefill tick: the next (B, C) prompt tokens against
    the running chunk state; ONE compiled program per (C, max_len)
    serves every chunk of every prompt length.  Logits are returned on
    the final chunk only (the head runs as its own tiny program once
    per request) — see executor.prefill_chunk."""
    return _exec.prefill_chunk(pm, state, token, pos, lens, jit=jit,
                               lookahead=lookahead, final=final)


def chunk_state_caches(state):
    """Decode-ready per-layer share KV caches from a finished chunked
    prefill."""
    return _exec.chunk_state_caches(state)


def private_chunk_head(pm: PrivateModel, last, jit: bool = False):
    """The adaptation head over gathered last-token hidden rows as its
    own tiny program — the paged engine runs it once per request at
    that request's final batched-prefill tick."""
    return _exec.chunk_head(pm, last, jit=jit)


def init_page_pool(pm: PrivateModel, n_pages: int, page_size: int):
    """Paged share-domain KV cache pools (DESIGN.md §13): per-layer
    (n_pages, page_size) pages of opened values + persistent masks;
    physical page 0 is the always-zero scratch page."""
    return _exec.init_page_pool(pm, n_pages, page_size)


def private_prefill_chunk_paged(pm: PrivateModel, pools, pt, pst,
                                token, pos, lens, jit: bool = False,
                                lookahead: int = 4):
    """One batched paged chunked-prefill tick over the full slot width
    — see executor.prefill_chunk_paged."""
    return _exec.prefill_chunk_paged(pm, pools, pt, pst, token, pos,
                                     lens, jit=jit, lookahead=lookahead)


def private_decode_step_paged(pm: PrivateModel, pools, pt, pst, token,
                              pos, jit: bool = False,
                              lookahead: int = 4):
    """One batched paged decode tick (C=1 chunk flow + head under the
    request's cached π1) — see executor.decode_step_paged."""
    return _exec.decode_step_paged(pm, pools, pt, pst, token, pos,
                                   jit=jit, lookahead=lookahead)


centaur_prefill = private_prefill
centaur_decode_step = private_decode_step


# =============================================================================
# private Whisper (enc-dec): completes private coverage of the pool
# =============================================================================

def prepare_whisper_private(cfg, params, key):
    """Permuted Theta' for the whisper backbone.  Frontend embeddings
    are client data: they enter the permuted feature space via Pi_PPP
    (the shared permutation matrix keeps pi hidden from both parties)."""
    ks = KeyStream(key)
    dealer = beaver.TripleDealer(ks())
    pd = permute.gen_perm(ks(), cfg.d_model)
    pf = permute.gen_perm(ks(), cfg.d_ff)
    perms = {"d": pd, "ff": pf,
             "v": permute.gen_perm(ks(), cfg.vocab_size)}
    h, dh = cfg.num_heads, cfg.dh
    iq = jnp.arange(h * dh)
    enc_linear, norm_perm = _centaur.enc_linear, _centaur.norm_perm

    def attn(a):
        return {"wq": enc_linear(a["wq"], None, pd, iq),
                "wk": enc_linear(a["wk"], None, pd, iq),
                "wv": enc_linear(a["wv"], None, pd, iq),
                "wo": enc_linear(a["wo"], None, iq, pd)}

    def mlp(f):
        return {"up": enc_linear(f["w_up"], f["b_up"], pd, pf),
                "down": enc_linear(f["w_down"], f["b_down"], pf, pd)}

    wp = {"enc_layers": [], "dec_layers": []}
    for i in range(cfg.encoder_layers):
        p_l = jax.tree.map(lambda a: a[i], params["enc_layers"])
        wp["enc_layers"].append({
            "ln1": norm_perm(p_l["ln1"], pd), "attn": attn(p_l["attn"]),
            "ln2": norm_perm(p_l["ln2"], pd), "ffn": mlp(p_l["ffn"])})
    for i in range(cfg.num_layers):
        p_l = jax.tree.map(lambda a: a[i], params["dec_layers"])
        wp["dec_layers"].append({
            "ln1": norm_perm(p_l["ln1"], pd), "attn": attn(p_l["attn"]),
            "lnx": norm_perm(p_l["lnx"], pd),
            "xattn": attn(p_l["xattn"]),
            "ln2": norm_perm(p_l["ln2"], pd), "ffn": mlp(p_l["ffn"])})
    wp["embed"] = {"tok": ring.encode(permute.apply_perm(
        jnp.asarray(params["embed"]["tok"], P32), pd, 1))}
    wp["enc_pos"] = ring.encode(permute.apply_perm(
        jnp.asarray(params["enc_pos"], P32), pd, 1))
    wp["dec_pos"] = ring.encode(permute.apply_perm(
        jnp.asarray(params["dec_pos"], P32), pd, 1))
    wp["enc_norm"] = norm_perm(params["enc_norm"], pd)
    wp["dec_norm"] = norm_perm(params["dec_norm"], pd)
    wp["head"] = enc_linear(params["embed"]["tok"], None, pd, perms["v"])
    return PrivateModel(cfg, "centaur", perms, wp, ks, dealer)


def whisper_private_forward(pm: PrivateModel, embeds, tokens):
    """Private enc-dec inference: client shares frame embeddings and
    decoder tokens; returns de-permuted decoder logits.  The encoder
    and decoder stacks run on the shared executor (cross-attention is
    the executor's `kv=` path)."""
    cfg = pm.cfg
    suite = get_suite(pm)
    _, Se, _ = embeds.shape
    _, Sd = tokens.shape
    wp = pm.wp
    # encoder: client embeds -> shares -> Pi_PPP into pi-space
    x = share(pm.ks(), ring.encode(jnp.asarray(embeds, P32)))
    with comm.tag("embedding"):
        x = protocols.pp_permute(x, pm.perms["d"], axis=-1)
        x = x + wp["enc_pos"][:Se][None]
    for p in wp["enc_layers"]:
        hx = suite.norm(p["ln1"], x)
        x = x + _exec.attention(suite, p["attn"], hx, causal=False)[0]
        hx = suite.norm(p["ln2"], x)
        x = x + _exec.ffn(suite, p["ffn"], hx)
    enc = suite.norm(wp["enc_norm"], x)

    # decoder
    xoh = encrypt_tokens(pm, tokens)
    with comm.tag("embedding"):
        y = protocols.scal_mul(jnp.swapaxes(wp["embed"]["tok"], 0, 1),
                               xoh, rescale=False)
        y = y + wp["dec_pos"][:Sd][None]
    for p in wp["dec_layers"]:
        hy = suite.norm(p["ln1"], y)
        y = y + _exec.attention(suite, p["attn"], hy, causal=True)[0]
        hy = suite.norm(p["lnx"], y)
        y = y + _exec.attention(suite, p["xattn"], hy, kv=enc,
                                causal=False)[0]
        hy = suite.norm(p["ln2"], y)
        y = y + _exec.ffn(suite, p["ffn"], hy)
    y = suite.norm(wp["dec_norm"], y)
    with comm.tag("adaptation"):
        logits_p = protocols.linear(wp["head"]["w"], None, y)
    yv = ring.decode(reconstruct(logits_p), dtype=P32)
    return permute.apply_inv_perm(yv, pm.perms["v"], -1)
