"""End-to-end private transformer inference (paper §5).

Four execution modes over the *same* plaintext parameters:

  centaur   — the paper: permuted-plaintext weights (Pi_ScalMul linears),
              secret-shared activations, share<->permuted-state conversion
              for exact nonlinearities (Pi_PPSM/PPGeLU/PPLN, Pi_PPP).
  smpc      — PUMA/CrypTen-like baseline: weights and activations both
              shared; every linear is a Beaver Pi_MatMul; nonlinearities
              via iterative fixed-point approximations (core.smpc_nl).
  mpcformer — MPCFormer baseline: smpc linears + Quad/2Quad substitution
              (reproduces the accuracy drop of paper Table 3).
  permute   — Yuan et al. (STI) baseline: plaintext compute on permuted
              weights/data; exposes O1 = QK^T etc. (the paper's Fig. 4
              privacy failure, reproduced by benchmarks/privacy_attack).

Families: encoder (BERT incl. pooler adaptation), dense decoders
(GPT-2 / llama-style with RoPE + GQA + SwiGLU), fine-grained MoE
(expert-permuted router — beyond-paper extension), and Mamba2 SSM blocks
(Pi_PPSSD).  The engine mirrors models/* exactly so Centaur's output can
be compared bit-for-bit (up to fixed-point) against plaintext.

`exposed` records what the cloud P1 actually observes per mode — the
attack surface evaluated by benchmarks/privacy_attack.py.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp

from . import beaver, comm, nonlinear, permute, protocols, ring, smpc_nl
from .sharing import ShareTensor, reconstruct, share

P32 = jnp.float32


# =============================================================================
# key stream
# =============================================================================

class KeyStream:
    def __init__(self, key):
        self._key = key

    def __call__(self):
        self._key, k = jax.random.split(self._key)
        return k


# =============================================================================
# parameter preparation (initialization phase, paper §5.1)
# =============================================================================

def _enc_linear(w, b, p_in, p_out):
    """Permute then ring-encode a linear layer (weights (out, in))."""
    wp, bp = permute.permute_linear(jnp.asarray(w, P32),
                                    None if b is None else jnp.asarray(
                                        b, P32), p_in, p_out)
    return {"w": ring.encode(wp),
            "b": None if bp is None else ring.encode(bp)}


def _share_linear(w, b, ks):
    out = {"w": share(ks(), ring.encode(jnp.asarray(w, P32)))}
    out["b"] = None if b is None else share(ks(), ring.encode(
        jnp.asarray(b, P32)))
    return out


@dataclass
class PrivateModel:
    cfg: Any
    mode: str
    perms: dict                      # named index-permutations
    wp: dict                         # prepared parameters
    ks: KeyStream
    dealer: Any                      # TripleDealer or TriplePool
    exposed: dict = field(default_factory=dict)
    pool: Any = None                 # lazily-built beaver.TriplePool
    jit_cache: dict = field(default_factory=dict)

    def expose(self, name, value):
        """Record an intermediate as seen by the cloud platform P1."""
        if name not in self.exposed:
            self.exposed[name] = value

    def triple_pool(self):
        if self.pool is None:
            # a pool built with use_pool=True is the model's dealer;
            # reuse it so jitted paths and eager paths draw from (and
            # bill) one offline phase
            self.pool = (self.dealer
                         if isinstance(self.dealer, beaver.TriplePool)
                         else beaver.TriplePool(self.ks()))
        return self.pool


def _mamba_channel_perms(cfg, ks):
    """Structured permutations for Pi_PPSSD: heads x headdim x state."""
    H, Pd, N, G = (cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state,
                   cfg.ssm_ngroups)
    pH = permute.gen_perm(ks(), H)
    pP = permute.gen_perm(ks(), Pd)
    pN = permute.gen_perm(ks(), N)
    # channel perm for the x part (H x P flattened)
    pXP = (pH[:, None] * Pd + pP[None, :]).reshape(-1)
    # B/C parts (G x N flattened); groups left in place (G is tiny/public)
    pGN = (jnp.arange(G)[:, None] * N + pN[None, :]).reshape(-1)
    return {"H": pH, "P": pP, "N": pN, "XP": pXP, "GN": pGN}


def build_private_model(cfg, params, key, mode: str = "centaur",
                        use_pool: bool = False) -> PrivateModel:
    ks = KeyStream(key)
    dealer = (beaver.TriplePool(ks()) if use_pool
              else beaver.TripleDealer(ks()))
    d = cfg.d_model
    perms = {"d": permute.gen_perm(ks(), d)}
    if mode == "permute" or mode == "centaur":
        pass
    if cfg.family in ("dense", "encoder", "moe"):
        ffd = cfg.moe_d_ff if cfg.family == "moe" else cfg.d_ff
        perms["ff"] = permute.gen_perm(ks(), ffd)
        if cfg.use_mla:
            perms["q_lora"] = permute.gen_perm(ks(), cfg.q_lora_rank)
            perms["kv_lora"] = permute.gen_perm(ks(), cfg.kv_lora_rank)
        if cfg.family == "moe":
            perms["e"] = permute.gen_perm(ks(), cfg.n_routed_experts)
            perms["shared_ff"] = permute.gen_perm(
                ks(), cfg.n_shared_experts * cfg.moe_d_ff)
    if cfg.family in ("ssm", "hybrid"):
        perms.update(_mamba_channel_perms(cfg, ks))
    if cfg.family == "hybrid":
        perms["ff"] = permute.gen_perm(ks(), cfg.d_ff)
    perms["v"] = permute.gen_perm(ks(), cfg.vocab_size)

    pm = PrivateModel(cfg, mode, perms, {}, ks, dealer)
    if mode in ("centaur", "permute"):
        pm.wp = _prepare_permuted(cfg, params, perms)
    elif mode in ("smpc", "mpcformer", "secformer"):
        pm.wp = _prepare_shared(cfg, params, ks)
    else:
        raise ValueError(mode)
    return pm


def _norm_perm(p_norm, p):
    out = {"g": permute.apply_perm(jnp.asarray(p_norm["g"], P32), p)}
    if "b" in p_norm:
        out["b"] = permute.apply_perm(jnp.asarray(p_norm["b"], P32), p)
    return out


def _prepare_permuted(cfg, params, perms):
    """Theta' = permuted parameters (centaur: ring-encoded for ScalMul;
    permute-mode uses the same permuted floats)."""
    pd, ident = perms["d"], None
    if cfg.family == "hybrid":
        return _prepare_hybrid_permuted(cfg, params, perms)
    wp = {"layers": []}
    emb = jnp.asarray(params["embed"]["tok"], P32)
    wp["embed"] = {"tok": ring.encode(permute.apply_perm(emb, pd, 1))}
    if "pos" in params["embed"]:
        wp["embed"]["pos"] = ring.encode(permute.apply_perm(
            jnp.asarray(params["embed"]["pos"], P32), pd, 1))
    if "embed_norm" in params:
        wp["embed_norm"] = _norm_perm(params["embed_norm"], pd)

    nl = cfg.num_layers
    for i in range(nl):
        p_l = jax.tree.map(lambda a: a[i], params["layers"]) \
            if cfg.family != "ssm" else jax.tree.map(
                lambda a: a[i], params["layers"])
        wp["layers"].append(_prepare_layer_permuted(cfg, p_l, perms))

    wp["final_norm"] = _norm_perm(params["final_norm"], pd)
    if cfg.family == "encoder":
        wp["pooler"] = _enc_linear(params["pooler"]["w"],
                                   params["pooler"]["b"], pd, pd)
        wp["classifier"] = _enc_linear(params["classifier"]["w"],
                                       params["classifier"]["b"], pd,
                                       jnp.arange(2))
    else:
        head_w = (params["embed"]["tok"] if cfg.tie_embeddings
                  else params["head"]["w"])
        wp["head"] = _enc_linear(head_w, None, pd, perms["v"])
    return wp


def _prepare_hybrid_permuted(cfg, params, perms):
    """Zamba2: per-layer Pi_PPSSD mamba blocks + ONE shared attention
    block (permuted once, applied every attn_every layers)."""
    pd = perms["d"]
    wp = {"layers": [], "embed": {"tok": ring.encode(permute.apply_perm(
        jnp.asarray(params["embed"]["tok"], P32), pd, 1))}}
    for i in range(cfg.num_layers):
        p_l = jax.tree.map(lambda a: a[i], params["mamba_layers"])
        wp["layers"].append({
            "ln1": _norm_perm(p_l["ln"], pd),
            "mamba": _prepare_mamba_permuted(cfg, p_l["mamba"], perms),
        })
    sh = params["shared"]
    h, hk, dh = cfg.num_heads, cfg.num_kv_heads, cfg.dh
    pf = perms["ff"]
    wp["shared"] = {
        "ln1": _norm_perm(sh["ln1"], pd),
        "ln2": _norm_perm(sh["ln2"], pd),
        "attn": {
            "wq": _enc_linear(sh["attn"]["wq"], None, pd,
                              jnp.arange(h * dh)),
            "wk": _enc_linear(sh["attn"]["wk"], None, pd,
                              jnp.arange(hk * dh)),
            "wv": _enc_linear(sh["attn"]["wv"], None, pd,
                              jnp.arange(hk * dh)),
            "wo": _enc_linear(sh["attn"]["wo"], None,
                              jnp.arange(h * dh), pd),
        },
        "ffn": {
            "w_gate": _enc_linear(sh["ffn"]["w_gate"], None, pd, pf),
            "w_up": _enc_linear(sh["ffn"]["w_up"], None, pd, pf),
            "w_down": _enc_linear(sh["ffn"]["w_down"], None, pf, pd),
        },
    }
    wp["final_norm"] = _norm_perm(params["final_norm"], pd)
    wp["head"] = _enc_linear(params["head"]["w"], None, pd, perms["v"])
    return wp


def _prepare_layer_permuted(cfg, p_l, perms):
    pd = perms["d"]
    ident_d = jnp.arange(cfg.d_model)
    out = {"ln1": _norm_perm(p_l["ln"] if cfg.family == "ssm"
                             else p_l["ln1"], pd)}
    if cfg.family == "ssm":
        out["mamba"] = _prepare_mamba_permuted(cfg, p_l["mamba"], perms)
        return out
    out["ln2"] = _norm_perm(p_l["ln2"], pd)
    a = p_l["attn"]
    if cfg.use_mla:
        # MLA: latent projections get their own perms; per-head Q/K/V
        # stay unpermuted (share-state through Pi_MatMul); the k_pe rows
        # of wkv_a stay unpermuted so RoPE can act on shares.
        pq, pkv = perms["q_lora"], perms["kv_lora"]
        h = cfg.num_heads
        qn, qr, vd = (cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                      cfg.v_head_dim)
        kv_rows = jnp.concatenate([pkv, cfg.kv_lora_rank
                                   + jnp.arange(qr)])
        out["attn"] = {
            "wq_a": _enc_linear(a["wq_a"], None, pd, pq),
            "q_norm": _norm_perm(a["q_norm"], pq),
            "wq_b": _enc_linear(a["wq_b"], None, pq,
                                jnp.arange(h * (qn + qr))),
            "wkv_a": _enc_linear(a["wkv_a"], None, pd, kv_rows),
            "kv_norm": _norm_perm(a["kv_norm"], pkv),
            "wkv_b": _enc_linear(a["wkv_b"], None, pkv,
                                 jnp.arange(h * (qn + vd))),
            "wo": _enc_linear(a["wo"], None, jnp.arange(h * vd), pd),
        }
    else:
        h, hk, dh = cfg.num_heads, cfg.num_kv_heads, cfg.dh
        ident_q = jnp.arange(h * dh)
        ident_kv = jnp.arange(hk * dh)
        out["attn"] = {
            "wq": _enc_linear(a["wq"], None, pd, ident_q),
            "wk": _enc_linear(a["wk"], None, pd, ident_kv),
            "wv": _enc_linear(a["wv"], None, pd, ident_kv),
            "wo": _enc_linear(a["wo"], None, ident_q, pd),
        }
    f = p_l["ffn"]
    pf = perms["ff"]
    if cfg.family == "moe":
        pe = perms["e"]
        out["ffn"] = {
            # router: feature-permuted in, expert-permuted out
            "router": _enc_linear(f["router"], None, pd, pe),
            # per-expert weights: stored in permuted-expert order
            "w_gate": ring.encode(permute.apply_perm(permute.apply_perm(
                permute.apply_perm(jnp.asarray(f["w_gate"], P32), pe, 0),
                pd, 1), pf, 2)),
            "w_up": ring.encode(permute.apply_perm(permute.apply_perm(
                permute.apply_perm(jnp.asarray(f["w_up"], P32), pe, 0),
                pd, 1), pf, 2)),
            "w_down": ring.encode(permute.apply_perm(permute.apply_perm(
                permute.apply_perm(jnp.asarray(f["w_down"], P32), pe, 0),
                pf, 1), pd, 2)),
        }
        if cfg.n_shared_experts:
            psf = perms["shared_ff"]
            out["ffn"]["shared"] = {
                "w_gate": _enc_linear(f["shared"]["w_gate"], None, pd, psf),
                "w_up": _enc_linear(f["shared"]["w_up"], None, pd, psf),
                "w_down": _enc_linear(f["shared"]["w_down"], None, psf, pd),
            }
    elif cfg.ffn_type == "swiglu":
        out["ffn"] = {
            "w_gate": _enc_linear(f["w_gate"], None, pd, pf),
            "w_up": _enc_linear(f["w_up"], None, pd, pf),
            "w_down": _enc_linear(f["w_down"], None, pf, pd),
        }
    else:
        out["ffn"] = {
            "up": _enc_linear(f["w_up"], f["b_up"], pd, pf),
            "down": _enc_linear(f["w_down"], f["b_down"], pf, pd),
        }
    return out


def _prepare_mamba_permuted(cfg, m, perms):
    """Permute a Mamba2 block for Pi_PPSSD: in_proj output channels get
    the structured perm [z:XP | x:XP | B,C:GN | dt:H]; conv is depthwise
    so its channel axis permutes identically; P1 holds the mid-block
    weights in *plaintext permuted* form (it evaluates conv+SSD+gate in
    the clear on permuted data)."""
    pd = perms["d"]
    di = cfg.d_inner
    gn = cfg.ssm_ngroups * cfg.ssm_state
    pXP, pGN, pH = perms["XP"], perms["GN"], perms["H"]
    # output-channel permutation of in_proj rows
    rows = jnp.concatenate([
        pXP,                                   # z
        di + pXP,                              # x (conv part)
        2 * di + pGN,                          # B
        2 * di + gn + pGN,                     # C
        2 * di + 2 * gn + pH,                  # dt
    ])
    w_in = jnp.take(jnp.take(jnp.asarray(m["in_proj"], P32), rows, 0),
                    pd, 1)
    conv_rows = jnp.concatenate([pXP, di + pGN, di + gn + pGN])
    return {
        "in_proj": {"w": ring.encode(w_in), "b": None},
        # P1-side plaintext (permuted) mid-block weights
        "conv_w": jnp.take(jnp.asarray(m["conv_w"], P32), conv_rows, 0),
        "conv_b": jnp.take(jnp.asarray(m["conv_b"], P32), conv_rows, 0),
        "A_log": jnp.take(jnp.asarray(m["A_log"], P32), pH, 0),
        "D": jnp.take(jnp.asarray(m["D"], P32), pH, 0),
        "dt_bias": jnp.take(jnp.asarray(m["dt_bias"], P32), pH, 0),
        "gate_norm": _norm_perm(m["gate_norm"], pXP),
        "out_proj": _enc_linear(m["out_proj"], None, pXP, pd),
    }


def _prepare_shared(cfg, params, ks):
    """smpc baseline: every parameter secret-shared."""
    def enc_share(a):
        return share(ks(), ring.encode(jnp.asarray(a, P32)))
    return jax.tree.map(enc_share, params)


# =============================================================================
# shared-state helpers
# =============================================================================

def rope_on_shares(x: ShareTensor, cos, sin):
    """Public per-position rotation applied locally to each share."""
    half = x.shape[-1] // 2
    c = ring.encode(cos)[..., None, :]
    s = ring.encode(sin)[..., None, :]

    def rot(t):
        t1, t2 = t[..., :half], t[..., half:]
        r1 = ring.truncate(t1 * c - t2 * s)
        r2 = ring.truncate(t2 * c + t1 * s)
        return jnp.concatenate([r1, r2], -1)

    return ShareTensor(rot(x.s0), rot(x.s1))


def _pp_apply2(pm: PrivateModel, fn, x: ShareTensor, y: ShareTensor,
               protocol: str):
    """Joint reveal of two permuted-state tensors, plaintext combine at
    P1, single reshare (beyond-paper: cheaper than a Beaver product for
    SwiGLU's silu(g) * u)."""
    xv = ring.decode(reconstruct(x), dtype=P32)
    yv = ring.decode(reconstruct(y), dtype=P32)
    out = fn(xv, yv)
    comm.record(protocol, rounds=2,
                bits=(comm.numel(x.shape) + comm.numel(y.shape)
                      + comm.numel(out.shape)) * comm.RING_BITS)
    return share(pm.ks(), ring.encode(out))


# =============================================================================
# private layers — centaur mode
# =============================================================================

def _linear(pm, wdict, x: ShareTensor):
    return protocols.linear(wdict["w"], wdict["b"], x)


def _c_attention(pm: PrivateModel, p, x: ShareTensor, layer_idx: int,
                 kv: ShareTensor | None = None,
                 causal: bool | None = None):
    """Paper §5.2.1 attention: ScalMul projections -> Pi_MatMul QK^T ->
    Pi_PPP -> Pi_PPSM -> Pi_MatMul with pi1-permuted V -> ScalMul out.
    `kv`: cross-attention source (encoder output shares) — K/V are
    ScalMul'd from it instead of x."""
    cfg = pm.cfg
    B, S, _ = x.shape
    kv_in = x if kv is None else kv
    T = kv_in.shape[1]
    h, hk, dh, g = cfg.num_heads, cfg.num_kv_heads, cfg.dh, cfg.q_groups
    with comm.tag("linear"):
        q = _linear(pm, p["wq"], x)          # unpermuted feature dim
        k = _linear(pm, p["wk"], kv_in)
        v = _linear(pm, p["wv"], kv_in)
    q = q.reshape(B, S, h, dh)
    k = k.reshape(B, T, hk, dh)
    v = v.reshape(B, T, hk, dh)

    if cfg.pos_embed == "rope":
        pos = jnp.arange(S)[None, :].repeat(B, 0)
        from repro.models.layers import rope_freqs
        cos, sin = rope_freqs(cfg, pos, dh)
        q = rope_on_shares(q, cos, sin)
        k = rope_on_shares(k, cos, sin)

    # heads to batch: (B,hk,g,S,dh) x (B,hk,S,dh)
    q = q.reshape(B, S, hk, g, dh).transpose(0, 2, 3, 1, 4)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    kt = ShareTensor(jnp.swapaxes(k.s0, -1, -2), jnp.swapaxes(k.s1, -1, -2))
    kt = ShareTensor(jnp.broadcast_to(kt.s0[:, :, None], (B, hk, g, dh, T)),
                     jnp.broadcast_to(kt.s1[:, :, None], (B, hk, g, dh, T)))
    with comm.tag("linear"):
        o1 = beaver.matmul(q, kt, pm.dealer)     # (B,hk,g,S,T)
    o1 = o1.mul_public(ring.encode(dh ** -0.5))
    if cfg.causal if causal is None else causal:
        mask = jnp.tril(jnp.ones((S, T))) - 1.0  # 0 / -1
        o1 = o1 + ring.encode(mask * 1e4)

    # Pi_PPP with a fresh per-request sequence permutation pi1
    pi1 = permute.gen_perm(pm.ks(), T)
    with comm.tag("softmax"):
        o1p = protocols.pp_permute(o1, pi1, axis=-1)
        if layer_idx == 0:
            pm.expose("O1", ring.decode(reconstruct(o1p), dtype=P32))
        o2p = nonlinear.pp_softmax(o1p, pm.ks())
    with comm.tag("softmax"):
        vp = protocols.pp_permute(v, pi1, axis=-2)  # rows permuted by pi1
    vp = ShareTensor(jnp.broadcast_to(vp.s0[:, :, None],
                                      (B, hk, g, T, dh)),
                     jnp.broadcast_to(vp.s1[:, :, None],
                                      (B, hk, g, T, dh)))
    with comm.tag("linear"):
        o3 = beaver.matmul(o2p, vp, pm.dealer)   # (B,hk,g,S,dh)
    o3 = o3.transpose(0, 3, 1, 2, 4).reshape(B, S, h * dh)
    with comm.tag("linear"):
        return _linear(pm, p["wo"], o3)          # output permuted by pi_d


def _c_mla_attention(pm: PrivateModel, p, x: ShareTensor,
                     layer_idx: int):
    """Private MLA (deepseek-v2): latent down-projections are ScalMuls
    with latent-dim permutations + Pi_PPLN on the permuted latents;
    per-head scores follow the paper's Pi_MatMul -> Pi_PPP -> Pi_PPSM
    flow with [q_nope|q_pe] / [k_nope|k_pe] concatenated heads."""
    cfg = pm.cfg
    B, S, _ = x.shape
    h = cfg.num_heads
    qn, qr, vd = (cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                  cfg.v_head_dim)
    with comm.tag("linear"):
        q_lat = _linear(pm, p["wq_a"], x)
    q_lat = _c_norm(pm, p["q_norm"], q_lat)
    with comm.tag("linear"):
        q = _linear(pm, p["wq_b"], q_lat).reshape(B, S, h, qn + qr)
        kv_a = _linear(pm, p["wkv_a"], x)
    ckv = kv_a[..., :cfg.kv_lora_rank]
    k_pe = kv_a[..., cfg.kv_lora_rank:]
    ckv = _c_norm(pm, p["kv_norm"], ckv)
    with comm.tag("linear"):
        kv = _linear(pm, p["wkv_b"], ckv).reshape(B, S, h, qn + vd)

    from repro.models.layers import rope_freqs
    pos = jnp.arange(S)[None, :].repeat(B, 0)
    cos, sin = rope_freqs(cfg, pos, qr)
    q_pe = rope_on_shares(q[..., qn:], cos, sin)
    k_pe = rope_on_shares(k_pe.reshape(B, S, 1, qr), cos, sin)

    # concat heads: q_cat (B,h,S,qn+qr); k_cat (B,h,qn+qr,T)
    q_cat = ShareTensor(
        jnp.concatenate([q.s0[..., :qn], q_pe.s0], -1),
        jnp.concatenate([q.s1[..., :qn], q_pe.s1], -1)).transpose(
            0, 2, 1, 3)
    k_pe_b = ShareTensor(
        jnp.broadcast_to(k_pe.s0, (B, S, h, qr)),
        jnp.broadcast_to(k_pe.s1, (B, S, h, qr)))
    k_cat = ShareTensor(
        jnp.concatenate([kv.s0[..., :qn], k_pe_b.s0], -1),
        jnp.concatenate([kv.s1[..., :qn], k_pe_b.s1], -1)).transpose(
            0, 2, 3, 1)
    v = kv[..., qn:].transpose(0, 2, 1, 3)         # (B,h,S,vd)

    with comm.tag("linear"):
        o1 = beaver.matmul(q_cat, k_cat, pm.dealer)
    o1 = o1.mul_public(ring.encode((qn + qr) ** -0.5))
    mask = jnp.tril(jnp.ones((S, S))) - 1.0
    o1 = o1 + ring.encode(mask * 1e4)
    pi1 = permute.gen_perm(pm.ks(), S)
    with comm.tag("softmax"):
        o1p = protocols.pp_permute(o1, pi1, axis=-1)
        if layer_idx == 0:
            pm.expose("O1", ring.decode(reconstruct(o1p), dtype=P32))
        o2p = nonlinear.pp_softmax(o1p, pm.ks())
        vp = protocols.pp_permute(v, pi1, axis=-2)
    with comm.tag("linear"):
        o3 = beaver.matmul(o2p, vp, pm.dealer)     # (B,h,S,vd)
    o3 = o3.transpose(0, 2, 1, 3).reshape(B, S, h * vd)
    with comm.tag("linear"):
        return _linear(pm, p["wo"], o3)


def _act_fn(cfg):
    if cfg.act == "silu":
        return jax.nn.silu
    if cfg.act == "relu2":
        return lambda v: jnp.square(jax.nn.relu(v))
    return lambda v: jax.nn.gelu(v, approximate=False)


def _c_ffn(pm: PrivateModel, p, x: ShareTensor, layer_idx: int):
    cfg = pm.cfg
    if cfg.family == "moe":
        return _c_moe(pm, p, x, layer_idx)
    if cfg.ffn_type == "swiglu":
        act = _act_fn(cfg)
        with comm.tag("linear"):
            gt = _linear(pm, p["w_gate"], x)
            up = _linear(pm, p["w_up"], x)
        with comm.tag("gelu"):
            if layer_idx == 0:
                pm.expose("O5", ring.decode(reconstruct(gt), dtype=P32))
            hidden = _pp_apply2(pm, lambda a, b: act(a) * b,
                                gt, up, "ppsilu")
        with comm.tag("linear"):
            return _linear(pm, p["w_down"], hidden)
    with comm.tag("linear"):
        o5 = _linear(pm, p["up"], x)
    with comm.tag("gelu"):
        if layer_idx == 0:
            pm.expose("O5", ring.decode(reconstruct(o5), dtype=P32))
        act = (nonlinear.pp_gelu if cfg.act == "gelu"
               else nonlinear.pp_silu)(o5, pm.ks())
    with comm.tag("linear"):
        return _linear(pm, p["down"], act)


def _c_moe(pm: PrivateModel, p, x: ShareTensor, layer_idx: int):
    """Beyond-paper MoE: expert-permuted router reveal + dispatch of
    *shares* by plaintext assignments; per-expert ScalMul FFNs.

    Simulation computes all experts on all tokens (tiny test configs)
    but bills communication for the dispatched tokens only."""
    cfg = pm.cfg
    B, S, d = x.shape
    T = B * S
    E, K = cfg.n_routed_experts, cfg.top_k
    xf = x.reshape(T, d)
    with comm.tag("linear"):
        logits = protocols.scal_mul(p["router"]["w"], xf)
    with comm.tag("softmax"):
        gates, idx = nonlinear.pp_topk_router(logits, K)

    f = cfg.moe_d_ff
    with comm.muted():
        # (E, T, f) gate/up for all tokens — simulation-only shortcut
        def expert_out(e):
            # stacked expert weights are (E, in, out): transpose for
            # the (out, in) ScalMul convention
            we_g = {"w": jnp.swapaxes(p["w_gate"][e], 0, 1), "b": None}
            we_u = {"w": jnp.swapaxes(p["w_up"][e], 0, 1), "b": None}
            we_d = {"w": jnp.swapaxes(p["w_down"][e], 0, 1), "b": None}
            g_ = _linear(pm, we_g, xf)
            u_ = _linear(pm, we_u, xf)
            hidden = _pp_apply2(pm, lambda a, b: _act_fn(cfg)(a) * b,
                                g_, u_, "ppsilu")
            return _linear(pm, we_d, hidden)

        outs = [expert_out(e) for e in range(E)]
    # true cost: dispatched rows = T*K through one expert FFN each
    comm.record("ppsilu", rounds=2,
                bits=(3 * T * K * f) * comm.RING_BITS)

    y0 = jnp.zeros((T, d), ring.RING_DTYPE)
    y = ShareTensor(y0, y0)
    for j in range(K):
        gate_j = ring.encode(gates[:, j:j + 1])
        sel = idx[:, j]
        s0 = jnp.stack([o.s0 for o in outs])[sel, jnp.arange(T)]
        s1 = jnp.stack([o.s1 for o in outs])[sel, jnp.arange(T)]
        y = y + ShareTensor(s0, s1).mul_public(gate_j)
    if cfg.n_shared_experts:
        sh = p["shared"]
        with comm.tag("linear"):
            g_ = _linear(pm, sh["w_gate"], xf)
            u_ = _linear(pm, sh["w_up"], xf)
        with comm.tag("gelu"):
            hidden = _pp_apply2(pm, lambda a, b: _act_fn(cfg)(a) * b,
                                g_, u_, "ppsilu")
        with comm.tag("linear"):
            y = y + _linear(pm, sh["w_down"], hidden)
    return y.reshape(B, S, d)


def _c_norm(pm: PrivateModel, p_norm, x: ShareTensor, tag="layernorm",
            expose_as=None):
    cfg = pm.cfg
    with comm.tag(tag):
        if expose_as:
            pm.expose(expose_as, ring.decode(reconstruct(x), dtype=P32))
        if cfg.norm_type == "layernorm":
            return nonlinear.pp_layernorm(x, p_norm["g"], p_norm["b"],
                                          pm.ks(), eps=cfg.norm_eps)
        return nonlinear.pp_rmsnorm(x, p_norm["g"], pm.ks(),
                                    eps=cfg.norm_eps)


def _c_mamba_block(pm: PrivateModel, p, x: ShareTensor, layer_idx: int):
    """Pi_PPSSD: ScalMul in_proj -> reveal permuted zxbcdt -> P1 runs
    conv+SiLU+SSD+gated-norm in plaintext (channel-permuted weights) ->
    reshare -> ScalMul out_proj."""
    cfg = pm.cfg
    B, S, _ = x.shape
    with comm.tag("linear"):
        zxbcdt = _linear(pm, p["in_proj"], x)

    from repro.models import mamba2 as m2

    def p1_block(v):
        import repro.models.mamba2 as mm
        z, xBC, dt_raw = mm._split_proj(cfg, v)
        dt = jax.nn.softplus(dt_raw + p["dt_bias"])
        xBC = jax.nn.silu(mm.causal_conv(p["conv_w"], p["conv_b"], xBC))
        xs, Bv, Cv = mm._split_xbc(cfg, xBC)
        H, Pd = cfg.ssm_nheads, cfg.ssm_headdim
        xs = xs.reshape(B, S, H, Pd)
        Bv = Bv.reshape(B, S, cfg.ssm_ngroups, cfg.ssm_state)
        Cv = Cv.reshape(B, S, cfg.ssm_ngroups, cfg.ssm_state)
        A = -jnp.exp(p["A_log"])
        y = mm.ssd_chunked(xs, dt, A, Bv, Cv, min(cfg.ssm_chunk, S))
        y = y + p["D"][None, None, :, None] * xs
        y = y.reshape(B, S, cfg.d_inner)
        y = y * jax.nn.silu(z)
        from repro.models.layers import rmsnorm
        return rmsnorm(p["gate_norm"], y, cfg.norm_eps)

    with comm.tag("ssm"):
        if layer_idx == 0:
            pm.expose("SSD_in", ring.decode(reconstruct(zxbcdt), dtype=P32))
        y = nonlinear.pp_block(p1_block, zxbcdt, pm.ks(), "ppssd")
    with comm.tag("linear"):
        return _linear(pm, p["out_proj"], y)


# layer index >= 1 disables the i == 0 exposure hooks (the jitted and
# serving paths pass this so no traced intermediate escapes into
# pm.exposed)
_NO_EXPOSE = 1


def _c_block(pm: PrivateModel, p, x: ShareTensor, i: int, attn_fn):
    """The transformer residual skeleton shared by the full forward,
    prefill and slotted decode (pre/post-norm handling, exposure hooks
    only for i == 0).  attn_fn(h) -> (attn_out, extra); `extra` carries
    a KV cache for the serving paths, None for the plain forward."""
    cfg = pm.cfg
    h = _c_norm(pm, p["ln1"], x) if cfg.prenorm else x
    attn, extra = attn_fn(h)
    x = x + attn
    if not cfg.prenorm:
        x = _c_norm(pm, p["ln1"], x,
                    expose_as="O4" if i == 0 else None)
    elif i == 0:
        pm.expose("O4", ring.decode(reconstruct(x), dtype=P32))
    h = _c_norm(pm, p["ln2"], x) if cfg.prenorm else x
    f = _c_ffn(pm, p["ffn"], h, i)
    x = x + f
    if not cfg.prenorm:
        x = _c_norm(pm, p["ln2"], x,
                    expose_as="O6" if i == 0 else None)
    elif i == 0:
        pm.expose("O6", ring.decode(reconstruct(x), dtype=P32))
    return x, extra


def _c_layer(pm: PrivateModel, p, x: ShareTensor, i: int) -> ShareTensor:
    """One centaur transformer layer (dense/encoder/moe families)."""
    attn = _c_mla_attention if pm.cfg.use_mla else _c_attention
    out, _ = _c_block(pm, p, x, i,
                      lambda h: (attn(pm, p["attn"], h, i), None))
    return out


def _c_head(pm: PrivateModel, x: ShareTensor):
    """Adaptation layer + de-permutation (shared by eager/jit paths)."""
    cfg = pm.cfg
    with comm.tag("adaptation"):
        if cfg.family == "encoder":
            pooled = protocols.linear(pm.wp["pooler"]["w"],
                                      pm.wp["pooler"]["b"], x[:, 0, :])
            t = nonlinear.pp_tanh(pooled, pm.ks())
            out = protocols.linear(pm.wp["classifier"]["w"],
                                   pm.wp["classifier"]["b"], t)
            return ring.decode(reconstruct(out), dtype=P32)
        x = _c_norm(pm, pm.wp["final_norm"], x, tag="adaptation")
        logits_p = protocols.linear(pm.wp["head"]["w"], None, x)
    yv = ring.decode(reconstruct(logits_p), dtype=P32)
    return permute.apply_inv_perm(yv, pm.perms["v"], -1)


# =============================================================================
# forward passes
# =============================================================================

def _c_embed(pm: PrivateModel, x_shared_onehot: ShareTensor,
             positions=None):
    """Pi_PPEmbedding: one-hot ScalMul + (BERT) Pi_PPLN."""
    cfg = pm.cfg
    with comm.tag("embedding"):
        x = protocols.scal_mul(jnp.swapaxes(pm.wp["embed"]["tok"], 0, 1),
                               x_shared_onehot, rescale=False)
        if "pos" in pm.wp["embed"] and positions is not None:
            pos_emb = jnp.take(pm.wp["embed"]["pos"], positions, axis=0)
            x = x + pos_emb
        if "embed_norm" in pm.wp:
            x = _c_norm(pm, pm.wp["embed_norm"], x, tag="embedding")
    return x


def encrypt_tokens(pm: PrivateModel, tokens):
    """Client side: one-hot (raw ring ints, no scale) and share."""
    onehot = jax.nn.one_hot(tokens, pm.cfg.vocab_size,
                            dtype=ring.RING_DTYPE)
    return share(pm.ks(), onehot)


def centaur_forward(pm: PrivateModel, tokens):
    """Full private forward; returns plaintext logits after the client
    reconstructs [Y pi_v] and removes pi_v (or class logits for BERT)."""
    cfg = pm.cfg
    B, S = tokens.shape
    xoh = encrypt_tokens(pm, tokens)
    positions = jnp.arange(S)
    x = _c_embed(pm, xoh, positions)
    # first permuted-state reveal P1 observes (embedding output)
    pm.expose("XM", ring.decode(reconstruct(x), dtype=P32))

    for i in range(cfg.num_layers):
        p = pm.wp["layers"][i]
        if cfg.family == "hybrid":
            # shared attention block every attn_every mamba layers
            if i % cfg.attn_every == 0 and \
                    i < (cfg.num_layers // cfg.attn_every) \
                    * cfg.attn_every:
                shp = pm.wp["shared"]
                h = _c_norm(pm, shp["ln1"], x)
                x = x + _c_attention(pm, shp["attn"], h, i)
                h = _c_norm(pm, shp["ln2"], x)
                x = x + _c_ffn(pm, shp["ffn"], h, i)
            h = _c_norm(pm, p["ln1"], x)
            x = x + _c_mamba_block(pm, p["mamba"], h, i)
            continue
        if cfg.family == "ssm":
            h = _c_norm(pm, p["ln1"], x)
            x = x + _c_mamba_block(pm, p["mamba"], h, i)
            continue
        x = _c_layer(pm, p, x, i)

    return _c_head(pm, x)


# =============================================================================
# smpc / mpcformer baseline forward (weights shared; PUMA-like protocols)
# =============================================================================

def _s_linear(pm, w_sh: ShareTensor, b_sh, x: ShareTensor):
    wt = ShareTensor(jnp.swapaxes(w_sh.s0, -1, -2),
                     jnp.swapaxes(w_sh.s1, -1, -2))
    y = beaver.matmul(x, wt, pm.dealer)
    if b_sh is not None:
        y = y + b_sh
    return y


def _s_norm(pm, p_norm, x: ShareTensor):
    cfg = pm.cfg
    with comm.tag("layernorm"):
        if cfg.norm_type == "layernorm":
            return smpc_nl.smpc_layernorm(x, p_norm["g"], p_norm["b"],
                                          pm.dealer, eps=cfg.norm_eps)
        # RMSNorm: reuse LN machinery without mean subtraction
        sq = beaver.square(x, pm.dealer)
        ms = ShareTensor(jnp.sum(sq.s0, -1, keepdims=True),
                         jnp.sum(sq.s1, -1, keepdims=True)).mul_public(
                             ring.encode(1.0 / x.shape[-1])) \
            + ring.encode(cfg.norm_eps)
        inv = smpc_nl.smpc_inv_sqrt(ms, pm.dealer)
        invb = ShareTensor(jnp.broadcast_to(inv.s0, x.shape),
                           jnp.broadcast_to(inv.s1, x.shape))
        y = beaver.mul(x, invb, pm.dealer)
        gb = ShareTensor(jnp.broadcast_to(p_norm["g"].s0, x.shape),
                         jnp.broadcast_to(p_norm["g"].s1, x.shape))
        return beaver.mul(y, gb, pm.dealer)


def _s_softmax(pm, x: ShareTensor):
    with comm.tag("softmax"):
        if pm.mode in ("mpcformer", "secformer"):
            return smpc_nl.quad_softmax(x, pm.dealer)
        return smpc_nl.smpc_softmax(x, pm.dealer)


def _s_act(pm, x: ShareTensor):
    with comm.tag("gelu"):
        if pm.mode == "mpcformer":
            return smpc_nl.quad_gelu(x, pm.dealer)
        return smpc_nl.smpc_gelu(x, pm.dealer)


def _s_layer(pm: PrivateModel, p, x: ShareTensor) -> ShareTensor:
    """One smpc-baseline transformer layer (shared weights)."""
    cfg = pm.cfg
    B, S, _ = x.shape
    h, dh = cfg.num_heads, cfg.dh
    a = p["attn"]
    hin = _s_norm(pm, p["ln1"], x) if cfg.prenorm else x
    with comm.tag("linear"):
        q = _s_linear(pm, a["wq"], None, hin).reshape(B, S, h, dh)
        k = _s_linear(pm, a["wk"], None, hin).reshape(B, S, h, dh)
        v = _s_linear(pm, a["wv"], None, hin).reshape(B, S, h, dh)
    q = q.transpose(0, 2, 1, 3)
    kt = ShareTensor(k.s0.transpose(0, 2, 3, 1), k.s1.transpose(0, 2, 3, 1))
    with comm.tag("linear"):
        o1 = beaver.matmul(q, kt, pm.dealer).mul_public(
            ring.encode(dh ** -0.5))
    if cfg.causal:
        mask = jnp.tril(jnp.ones((S, S))) - 1.0
        o1 = o1 + ring.encode(mask * 1e4)
    o2 = _s_softmax(pm, o1)
    vt = ShareTensor(v.s0.transpose(0, 2, 1, 3), v.s1.transpose(0, 2, 1, 3))
    with comm.tag("linear"):
        o3 = beaver.matmul(o2, vt, pm.dealer)
    o3 = o3.transpose(0, 2, 1, 3).reshape(B, S, h * dh)
    with comm.tag("linear"):
        attn_out = _s_linear(pm, a["wo"], None, o3)
    x = x + attn_out
    if not cfg.prenorm:
        x = _s_norm(pm, p["ln1"], x)
    hin = _s_norm(pm, p["ln2"], x) if cfg.prenorm else x
    f = p["ffn"]
    with comm.tag("linear"):
        o5 = _s_linear(pm, f["w_up"], f["b_up"], hin)
    g = _s_act(pm, o5)
    with comm.tag("linear"):
        o6 = _s_linear(pm, f["w_down"], f["b_down"], g)
    x = x + o6
    if not cfg.prenorm:
        x = _s_norm(pm, p["ln2"], x)
    return x


def _s_head(pm: PrivateModel, x: ShareTensor):
    cfg = pm.cfg
    with comm.tag("adaptation"):
        if cfg.family == "encoder":
            pooled = _s_linear(pm, pm.wp["pooler"]["w"],
                               pm.wp["pooler"]["b"], x[:, 0, :])
            t = smpc_nl.smpc_tanh(pooled, pm.dealer)
            out = _s_linear(pm, pm.wp["classifier"]["w"],
                            pm.wp["classifier"]["b"], t)
            return ring.decode(reconstruct(out), dtype=P32)
        x = _s_norm(pm, pm.wp["final_norm"], x)
        head_w = (pm.wp["embed"]["tok"] if cfg.tie_embeddings
                  else pm.wp["head"]["w"])
        logits = beaver.matmul(x, ShareTensor(
            jnp.swapaxes(head_w.s0, 0, 1), jnp.swapaxes(head_w.s1, 0, 1)),
            pm.dealer)
    return ring.decode(reconstruct(logits), dtype=P32)


def _s_embed(pm: PrivateModel, tokens) -> ShareTensor:
    cfg = pm.cfg
    _, S = tokens.shape
    onehot = jax.nn.one_hot(tokens, cfg.vocab_size, dtype=ring.RING_DTYPE)
    x_oh = share(pm.ks(), onehot)
    with comm.tag("embedding"):
        emb_t = pm.wp["embed"]["tok"]
        y = beaver.matmul(x_oh, emb_t, pm.dealer, rescale=False)
        if "pos" in pm.wp["embed"]:
            pos = pm.wp["embed"]["pos"]
            y = y + ShareTensor(pos.s0[:S][None], pos.s1[:S][None])
        if "embed_norm" in pm.wp:
            y = _s_norm(pm, pm.wp["embed_norm"], y)
    return y


def smpc_forward(pm: PrivateModel, tokens):
    """PUMA/MPCFormer-style baseline (encoder/dense MLP families)."""
    cfg = pm.cfg
    assert cfg.family in ("encoder", "dense") and cfg.ffn_type == "mlp", \
        "smpc baseline implemented for the paper's BERT/GPT-2 shapes"
    x = _s_embed(pm, tokens)
    for i in range(cfg.num_layers):
        p = jax.tree.map(lambda a: a[i], pm.wp["layers"])
        x = _s_layer(pm, p, x)
    return _s_head(pm, x)


# =============================================================================
# permute-only baseline (Yuan et al. STI): plaintext compute, O1 exposed
# =============================================================================

def permute_forward(pm: PrivateModel, tokens):
    cfg = pm.cfg
    assert cfg.family in ("encoder", "dense") and cfg.ffn_type == "mlp"
    B, S = tokens.shape
    h, dh = cfg.num_heads, cfg.dh
    dec = lambda t: ring.decode(t, dtype=P32)  # noqa: E731
    wp = pm.wp
    x = jnp.take(dec(wp["embed"]["tok"]), tokens, axis=0)
    if "pos" in wp["embed"]:
        x = x + dec(wp["embed"]["pos"])[:S][None]

    def ln(p_norm, v):
        mu = v.mean(-1, keepdims=True) if cfg.norm_type == "layernorm" \
            else 0.0
        var = ((v - mu) ** 2).mean(-1, keepdims=True)
        y = (v - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        return p_norm["g"] * y + p_norm.get("b", 0.0)

    pm.expose("XM", x)
    if "embed_norm" in wp:
        x = ln(wp["embed_norm"], x)

    for i in range(cfg.num_layers):
        p = wp["layers"][i]
        hin = ln(p["ln1"], x) if cfg.prenorm else x
        q = (hin @ dec(p["attn"]["wq"]["w"]).T).reshape(B, S, h, dh)
        k = (hin @ dec(p["attn"]["wk"]["w"]).T).reshape(B, S, h, dh)
        v = (hin @ dec(p["attn"]["wv"]["w"]).T).reshape(B, S, h, dh)
        o1 = jnp.einsum("bshd,bthd->bhst", q, k) / jnp.sqrt(
            jnp.asarray(dh, P32))
        if cfg.causal:
            mask = jnp.tril(jnp.ones((S, S), bool))
            o1 = jnp.where(mask[None, None], o1, -1e4)
        if i == 0:
            # THE leak: pi cancels in QK^T (paper §3 Motivation 2)
            pm.expose("O1", o1)
        o2 = jax.nn.softmax(o1, -1)
        if i == 0:
            pm.expose("O2", o2)
        o3 = jnp.einsum("bhst,bthd->bshd", o2, v).reshape(B, S, h * dh)
        x = x + o3 @ dec(p["attn"]["wo"]["w"]).T
        if not cfg.prenorm:
            x = ln(p["ln1"], x)
        if i == 0:
            pm.expose("O4", x)
        hin = ln(p["ln2"], x) if cfg.prenorm else x
        o5 = hin @ dec(p["ffn"]["up"]["w"]).T + dec(p["ffn"]["up"]["b"])
        if i == 0:
            pm.expose("O5", o5)
        g = jax.nn.gelu(o5, approximate=False)
        x = x + g @ dec(p["ffn"]["down"]["w"]).T + dec(p["ffn"]["down"]["b"])
        if not cfg.prenorm:
            x = ln(p["ln2"], x)
        if i == 0:
            pm.expose("O6", x)

    if cfg.family == "encoder":
        pooled = jnp.tanh(x[:, 0, :] @ dec(wp["pooler"]["w"]).T
                          + dec(wp["pooler"]["b"]))
        return pooled @ dec(wp["classifier"]["w"]).T \
            + dec(wp["classifier"]["b"])
    x = ln(wp["final_norm"], x)
    logits = x @ dec(wp["head"]["w"]).T
    return permute.apply_inv_perm(logits, pm.perms["v"], -1)


# =============================================================================
# jitted per-layer forward (hot path: fused online phase + triple pool +
# static comm schedule — see DESIGN.md §6)
# =============================================================================

@dataclass
class _JitLayer:
    fn: Any           # jitted (p, x, key, triples) -> x'
    specs: list       # per-layer triple demand, in request order
    events: list      # captured per-layer comm schedule (CommEvents)


def _shadow(pm: PrivateModel, key, dealer) -> PrivateModel:
    """pm clone with a traced key stream/dealer and inert exposure."""
    return PrivateModel(pm.cfg, pm.mode, pm.perms, pm.wp,
                        KeyStream(key), dealer)


def _build_jit_layer(pm: PrivateModel, name: str, body, p, x) -> _JitLayer:
    """Compile one layer into a jitted function plus its static cost
    schedule and triple demand.

    1. An abstract trace (jax.eval_shape — zero FLOPs) under a
       `comm.capture()` discovers the layer's exact (rounds, bits)
       schedule and, via a RecordingDealer, the ordered multiset of
       Beaver triples it consumes.
    2. The online function is jitted with triples as *inputs* (a
       ReplayDealer hands them out in recorded order), so the offline
       phase runs ahead of time through the vectorized TriplePool and
       the jitted online program contains no dealer work.
    3. `comm.record` is Python-side and would fire once at trace time
       only; the traced body runs muted and the captured schedule is
       `comm.replay`ed per call instead, keeping the ledger exact.
    """
    key = pm.ks()

    recorders = []

    def record_run(p_, x_, key_):
        kd, ku = jax.random.split(key_)
        rec = beaver.RecordingDealer(kd)
        recorders.append(rec)
        return body(_shadow(pm, ku, rec), p_, x_)

    with comm.capture() as sched:
        jax.eval_shape(record_run, p, x, key)
    specs = recorders[-1].specs

    def online_run(p_, x_, key_, triples):
        _, ku = jax.random.split(key_)
        with comm.muted():
            return body(_shadow(pm, ku, beaver.ReplayDealer(triples)),
                        p_, x_)

    return _JitLayer(jax.jit(online_run), specs, list(sched.events))


def _jit_layer_for(pm: PrivateModel, name: str, body, p, x) -> _JitLayer:
    # x may be any pytree of arrays/ShareTensors (the slotted decode
    # threads (x, k_cache, v_cache, pos) through one body)
    cache_key = (name, jax.tree.structure((p, x)),
                 tuple(jnp.shape(le) for le in jax.tree.leaves((p, x))))
    if cache_key not in pm.jit_cache:
        pm.jit_cache[cache_key] = _build_jit_layer(pm, name, body, p, x)
    return pm.jit_cache[cache_key]


def _run_jit_layers(pm: PrivateModel, layer_ps, body, name: str,
                    x: ShareTensor) -> ShareTensor:
    """Offline: prefetch every layer's triples in one vectorized batch
    per spec.  Online: run the jitted layer per depth, replaying the
    captured schedule (online events; offline was billed by the pool)."""
    jl = _jit_layer_for(pm, name, body, layer_ps[0], x)
    pool = pm.triple_pool()
    pool.prefetch(jl.specs * len(layer_ps))
    for p in layer_ps:
        triples = [pool.take(s) for s in jl.specs]
        comm.replay(jl.events, online_only=True)
        x = jl.fn(p, x, pm.ks(), triples)
    return x


def _jittable(pm: PrivateModel) -> bool:
    cfg = pm.cfg
    if pm.mode == "centaur":
        return cfg.family in ("dense", "encoder")
    if pm.mode in ("smpc", "mpcformer", "secformer"):
        return cfg.family in ("encoder", "dense") and cfg.ffn_type == "mlp"
    return False


def centaur_forward_jit(pm: PrivateModel, tokens):
    """Jit-compiled per-layer centaur forward.  Embedding and head run
    eagerly (they bill normally); the layer stack runs as one compiled
    program per depth with pool-fed triples.  Unlike the eager path it
    does not populate pm.exposed (no intermediates leave the trace)."""
    _, S = tokens.shape
    xoh = encrypt_tokens(pm, tokens)
    x = _c_embed(pm, xoh, jnp.arange(S))
    x = _run_jit_layers(pm, pm.wp["layers"],
                        lambda sh, p, xin: _c_layer(sh, p, xin, _NO_EXPOSE),
                        "centaur_layer", x)
    return _c_head(pm, x)


def smpc_forward_jit(pm: PrivateModel, tokens):
    """Jit-compiled per-layer smpc/mpcformer baseline forward."""
    cfg = pm.cfg
    assert cfg.family in ("encoder", "dense") and cfg.ffn_type == "mlp", \
        "smpc baseline implemented for the paper's BERT/GPT-2 shapes"
    x = _s_embed(pm, tokens)
    layer_ps = [jax.tree.map(lambda a: a[i], pm.wp["layers"])
                for i in range(cfg.num_layers)]
    x = _run_jit_layers(pm, layer_ps, _s_layer, "smpc_layer", x)
    return _s_head(pm, x)


def private_forward(pm: PrivateModel, tokens, jit: bool = False):
    if jit and _jittable(pm):
        if pm.mode == "centaur":
            return centaur_forward_jit(pm, tokens)
        return smpc_forward_jit(pm, tokens)
    if pm.mode == "centaur":
        return centaur_forward(pm, tokens)
    if pm.mode in ("smpc", "mpcformer", "secformer"):
        return smpc_forward(pm, tokens)
    if pm.mode == "permute":
        return permute_forward(pm, tokens)
    raise ValueError(pm.mode)


# =============================================================================
# private serving: slot-stacked padded KV-cache decode (centaur mode,
# dense family) — the continuous-batching hot path.  DESIGN.md §7.
# =============================================================================

def init_slot_caches(pm: PrivateModel, n_slots: int, max_len: int):
    """Zeroed slot-stacked share KV caches: per layer {"k","v"} of shape
    (n_slots, max_len, hk, dh).  Zero shares reconstruct to zero, and
    the additive validity mask keeps unwritten rows at exactly zero
    softmax mass, so slots can be filled/evicted independently."""
    cfg = pm.cfg
    z = jnp.zeros((n_slots, max_len, cfg.num_kv_heads, cfg.dh),
                  ring.RING_DTYPE)
    return [{"k": ShareTensor(z, z), "v": ShareTensor(z, z)}
            for _ in range(cfg.num_layers)]


def _slot_write(cache: ShareTensor, new: ShareTensor, pos):
    """Write new K/V rows (B,S,hk,dh) into the padded cache (B,L,hk,dh)
    at per-slot offsets pos (B,) — applied to each share separately."""
    def upd(c, nw):
        return jax.vmap(lambda cb, nb, pb:
                        jax.lax.dynamic_update_slice_in_dim(cb, nb, pb,
                                                            axis=0)
                        )(c, nw, pos)
    return ShareTensor(upd(cache.s0, new.s0), upd(cache.s1, new.s1))


def _pad_cache_to(c: ShareTensor, max_len: int) -> ShareTensor:
    pad = [(0, 0)] * c.ndim
    pad[1] = (0, max_len - c.shape[1])
    return ShareTensor(jnp.pad(c.s0, pad), jnp.pad(c.s1, pad))


def _c_attention_prefill(pm: PrivateModel, p, x: ShareTensor):
    """Prefill attention: the paper's Pi_MatMul -> Pi_PPP -> Pi_PPSM flow
    over the prompt; K/V shares are returned so the caller can splice
    them into a padded slot cache (appending shares is free)."""
    cfg = pm.cfg
    B, S, _ = x.shape
    h, hk, dh, g = cfg.num_heads, cfg.num_kv_heads, cfg.dh, cfg.q_groups
    with comm.tag("linear"):
        q = _linear(pm, p["wq"], x).reshape(B, S, hk, g, dh)
        k = _linear(pm, p["wk"], x).reshape(B, S, hk, dh)
        v = _linear(pm, p["wv"], x).reshape(B, S, hk, dh)
    if cfg.pos_embed == "rope":
        from repro.models.layers import rope_freqs
        posv = jnp.arange(S)[None, :].repeat(B, 0)
        cos, sin = rope_freqs(cfg, posv, dh)
        q = rope_on_shares(q.reshape(B, S, hk * g, dh), cos, sin
                           ).reshape(B, S, hk, g, dh)
        k = rope_on_shares(k, cos, sin)
    new_cache = {"k": k, "v": v}

    qh = q.transpose(0, 2, 3, 1, 4)                   # (B,hk,g,S,dh)
    kt = ShareTensor(k.s0.transpose(0, 2, 3, 1), k.s1.transpose(0, 2, 3, 1))
    kt = ShareTensor(jnp.broadcast_to(kt.s0[:, :, None],
                                      (B, hk, g, dh, S)),
                     jnp.broadcast_to(kt.s1[:, :, None],
                                      (B, hk, g, dh, S)))
    with comm.tag("linear"):
        o1 = beaver.matmul(qh, kt, pm.dealer)
    o1 = o1.mul_public(ring.encode(dh ** -0.5))
    mask = (jnp.arange(S)[None, :]
            <= jnp.arange(S)[:, None]).astype(jnp.float64)
    o1 = o1 + ring.encode((mask - 1.0) * 1e4)
    pi1 = permute.gen_perm(pm.ks(), S)
    with comm.tag("softmax"):
        o1p = protocols.pp_permute(o1, pi1, axis=-1)
        o2p = nonlinear.pp_softmax(o1p, pm.ks())
        vp = protocols.pp_permute(
            ShareTensor(v.s0.transpose(0, 2, 1, 3),
                        v.s1.transpose(0, 2, 1, 3)), pi1, axis=-2)
    vp = ShareTensor(jnp.broadcast_to(vp.s0[:, :, None], (B, hk, g, S, dh)),
                     jnp.broadcast_to(vp.s1[:, :, None], (B, hk, g, S, dh)))
    with comm.tag("linear"):
        o3 = beaver.matmul(o2p, vp, pm.dealer)
    o3 = o3.transpose(0, 3, 1, 2, 4).reshape(B, S, h * dh)
    with comm.tag("linear"):
        return _linear(pm, p["wo"], o3), new_cache


def _c_attention_slotted(pm: PrivateModel, p, x: ShareTensor,
                         cache: dict, pos):
    """Batched single-token private attention against padded slot caches.

    x: (B,1,d) shares for B independent slots; cache {"k","v"}: padded
    (B,L,hk,dh) share tensors; pos (B,): the row the new K/V shares land
    in (== the token's absolute position).  Queries attend to the whole
    padded axis with an additive validity mask applied *on shares*
    (columns t > pos[b] get -1e4 before the softmax reveal): unwritten
    rows hold zero shares, so their revealed scores are exactly -1e4
    relative to any live score and exp underflows to exact float32 zero
    — the batched softmax is the sequential softmax plus zero-mass
    entries.  P1's reveal shows only *which* permuted columns are dead,
    i.e. the slot's occupancy count, which the sequential protocol
    reveals anyway through its growing shapes."""
    cfg = pm.cfg
    B, S, _ = x.shape
    h, hk, dh, g = cfg.num_heads, cfg.num_kv_heads, cfg.dh, cfg.q_groups
    with comm.tag("linear"):
        q = _linear(pm, p["wq"], x).reshape(B, S, hk, g, dh)
        k = _linear(pm, p["wk"], x).reshape(B, S, hk, dh)
        v = _linear(pm, p["wv"], x).reshape(B, S, hk, dh)
    q_pos = pos[:, None] + jnp.arange(S)[None, :]     # (B,S)
    if cfg.pos_embed == "rope":
        from repro.models.layers import rope_freqs
        cos, sin = rope_freqs(cfg, q_pos, dh)
        q = rope_on_shares(q.reshape(B, S, hk * g, dh), cos, sin
                           ).reshape(B, S, hk, g, dh)
        k = rope_on_shares(k, cos, sin)
    k_cache = _slot_write(cache["k"], k, pos)
    v_cache = _slot_write(cache["v"], v, pos)
    new_cache = {"k": k_cache, "v": v_cache}
    L = k_cache.shape[1]

    qh = q.transpose(0, 2, 3, 1, 4)                   # (B,hk,g,S,dh)
    kt = ShareTensor(k_cache.s0.transpose(0, 2, 3, 1),
                     k_cache.s1.transpose(0, 2, 3, 1))
    kt = ShareTensor(jnp.broadcast_to(kt.s0[:, :, None],
                                      (B, hk, g, dh, L)),
                     jnp.broadcast_to(kt.s1[:, :, None],
                                      (B, hk, g, dh, L)))
    with comm.tag("linear"):
        o1 = beaver.matmul(qh, kt, pm.dealer)         # (B,hk,g,S,L)
    o1 = o1.mul_public(ring.encode(dh ** -0.5))
    mask = (jnp.arange(L)[None, None, :]
            <= q_pos[:, :, None]).astype(jnp.float64)  # (B,S,L)
    o1 = o1 + ring.encode((mask - 1.0) * 1e4)[:, None, None]
    # one INDEPENDENT fresh pi1 per slot: a shared permutation would
    # let P1 align revealed score columns across tenants' requests
    pi1 = jax.vmap(lambda k: permute.gen_perm(k, L))(
        jax.random.split(pm.ks(), B))                  # (B,L)
    with comm.tag("softmax"):
        o1p = protocols.pp_permute_batched(o1, pi1, axis=-1)
        o2p = nonlinear.pp_softmax(o1p, pm.ks())
        vp = protocols.pp_permute_batched(
            ShareTensor(v_cache.s0.transpose(0, 2, 1, 3),
                        v_cache.s1.transpose(0, 2, 1, 3)), pi1, axis=-2)
    vp = ShareTensor(jnp.broadcast_to(vp.s0[:, :, None], (B, hk, g, L, dh)),
                     jnp.broadcast_to(vp.s1[:, :, None], (B, hk, g, L, dh)))
    with comm.tag("linear"):
        o3 = beaver.matmul(o2p, vp, pm.dealer)        # (B,hk,g,S,dh)
    o3 = o3.transpose(0, 3, 1, 2, 4).reshape(B, S, h * dh)
    with comm.tag("linear"):
        return _linear(pm, p["wo"], o3), new_cache


def _c_slot_layer(pm: PrivateModel, p, x: ShareTensor, cache: dict, pos):
    """One centaur transformer layer over a slot batch (serving hot
    path, also traced into the jitted tick: never exposes)."""
    return _c_block(pm, p, x, _NO_EXPOSE,
                    lambda h: _c_attention_slotted(pm, p["attn"], h,
                                                   cache, pos))


def _centaur_logits(pm: PrivateModel, x_last: ShareTensor):
    with comm.tag("adaptation"):
        if pm.cfg.prenorm:
            x_last = _c_norm(pm, pm.wp["final_norm"], x_last,
                             tag="adaptation")
        logits_p = protocols.linear(pm.wp["head"]["w"], None, x_last)
    yv = ring.decode(reconstruct(logits_p), dtype=P32)
    return permute.apply_inv_perm(yv, pm.perms["v"], -1)


def _c_prefill_layer(pm: PrivateModel, p, x: ShareTensor):
    """One centaur transformer layer at prompt length, returning the
    K/V shares for the slot cache (serving hot path: never exposes)."""
    return _c_block(pm, p, x, _NO_EXPOSE,
                    lambda h: _c_attention_prefill(pm, p["attn"], h))


def centaur_prefill(pm: PrivateModel, tokens, max_len: int | None = None,
                    jit: bool = False):
    """Private prefill: returns (last-token logits, per-layer K/V share
    caches padded to `max_len`), ready for `centaur_decode_step` or to
    be spliced into a slot of a stacked serving cache.  Attention runs
    at prompt length (comm ∝ S^2, as the sequential protocol bills);
    only the returned cache is padded — padding shares are zeros.
    jit=True compiles the layer stack per (B, S) like the decode path."""
    assert pm.cfg.family == "dense" and not pm.cfg.use_mla
    cfg = pm.cfg
    B, S = tokens.shape
    if max_len is None:
        max_len = S + 1
    assert max_len >= S, (max_len, S)
    if jit:
        def body(sh, p, tok):
            xoh = encrypt_tokens(sh, tok)
            x = _c_embed(sh, xoh, jnp.arange(S))
            ks_, vs_ = [], []
            for i in range(cfg.num_layers):
                x, nc = _c_prefill_layer(sh, p[i], x)
                ks_.append(_pad_cache_to(nc["k"], max_len))
                vs_.append(_pad_cache_to(nc["v"], max_len))
            return _centaur_logits(sh, x[:, -1:, :]), ks_, vs_

        # max_len shapes the padded outputs but not the traced inputs,
        # so it must be part of the program cache key
        jl = _jit_layer_for(pm, f"centaur_prefill:{max_len}", body,
                            pm.wp["layers"], tokens)
        pool = pm.triple_pool()
        pool.prefetch(jl.specs)
        triples = [pool.take(s) for s in jl.specs]
        comm.replay(jl.events, online_only=True)
        logits, ks_, vs_ = jl.fn(pm.wp["layers"], tokens, pm.ks(),
                                 triples)
        return logits, [{"k": k, "v": v} for k, v in zip(ks_, vs_)]

    xoh = encrypt_tokens(pm, tokens)
    x = _c_embed(pm, xoh, jnp.arange(S))
    caches = []
    for i in range(cfg.num_layers):
        x, nc = _c_prefill_layer(pm, pm.wp["layers"][i], x)
        caches.append({"k": _pad_cache_to(nc["k"], max_len),
                       "v": _pad_cache_to(nc["v"], max_len)})
    return _centaur_logits(pm, x[:, -1:, :]), caches


def _run_jit_decode_step(pm: PrivateModel, caches, token, pos,
                         lookahead: int = 4):
    """ONE jitted batched decode step: embedding, the whole layer
    stack against the slot caches, and the adaptation head compile
    into a single program per (batch, max_len) shape — a tick is one
    dispatch plus pool takes.  The shapes are padding-static, so one
    eval_shape trace under comm.capture() prices every future tick
    (replayed per tick, ledger bit-exact vs eager), and the triple
    demand is the same multiset every tick: TriplePool.reserve keeps
    `lookahead` ticks in stock with one constant-size vectorized
    generator per spec (DESIGN.md §7)."""
    nl = pm.cfg.num_layers

    def body(sh, p, state):
        tok, ps, cks, cvs = state
        xoh = encrypt_tokens(sh, tok)
        x = _c_embed(sh, xoh, ps[:, None])
        ks_, vs_ = [], []
        for i in range(nl):
            x, nc = _c_slot_layer(sh, p[i], x,
                                  {"k": cks[i], "v": cvs[i]}, ps)
            ks_.append(nc["k"])
            vs_.append(nc["v"])
        return _centaur_logits(sh, x), ks_, vs_

    state0 = (token, pos, [c["k"] for c in caches],
              [c["v"] for c in caches])
    jl = _jit_layer_for(pm, "centaur_decode_tick", body,
                        pm.wp["layers"], state0)
    pool = pm.triple_pool()
    pool.reserve(jl.specs, steps=lookahead)
    triples = [pool.take(s) for s in jl.specs]
    comm.replay(jl.events, online_only=True)
    logits, ks_, vs_ = jl.fn(pm.wp["layers"], state0, pm.ks(), triples)
    return logits, [{"k": k, "v": v} for k, v in zip(ks_, vs_)]


def centaur_decode_step(pm: PrivateModel, caches, token, pos,
                        jit: bool = False, lookahead: int = 4):
    """One batched private decode step: token (B,1) next-token ids for B
    independent slots, pos int or (B,) per-slot absolute positions,
    caches as returned by centaur_prefill / init_slot_caches (padded,
    slot-stacked).  Returns (logits (B,1,V), updated caches)."""
    B, S = token.shape
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    L = int(caches[0]["k"].shape[1])
    # dynamic_update_slice would silently clamp an out-of-range write
    # onto the previous token's K/V row — fail loudly instead
    assert int(jnp.max(pos)) + S <= L, \
        f"decode past padded cache: pos={pos}, S={S}, max_len={L}"
    if jit:
        return _run_jit_decode_step(pm, caches, token, pos,
                                    lookahead=lookahead)
    xoh = encrypt_tokens(pm, token)
    x = _c_embed(pm, xoh, pos[:, None])
    new_caches = []
    for i in range(pm.cfg.num_layers):
        x, nc = _c_slot_layer(pm, pm.wp["layers"][i], x, caches[i], pos)
        new_caches.append(nc)
    return _centaur_logits(pm, x), new_caches


# =============================================================================
# private Whisper (enc-dec): completes private coverage of the pool
# =============================================================================

def prepare_whisper_private(cfg, params, key):
    """Permuted Theta' for the whisper backbone.  Frontend embeddings
    are client data: they enter the permuted feature space via Pi_PPP
    (the shared permutation matrix keeps pi hidden from both parties)."""
    ks = KeyStream(key)
    dealer = beaver.TripleDealer(ks())
    pd = permute.gen_perm(ks(), cfg.d_model)
    pf = permute.gen_perm(ks(), cfg.d_ff)
    perms = {"d": pd, "ff": pf,
             "v": permute.gen_perm(ks(), cfg.vocab_size)}
    h, dh = cfg.num_heads, cfg.dh
    iq = jnp.arange(h * dh)

    def attn(a):
        return {"wq": _enc_linear(a["wq"], None, pd, iq),
                "wk": _enc_linear(a["wk"], None, pd, iq),
                "wv": _enc_linear(a["wv"], None, pd, iq),
                "wo": _enc_linear(a["wo"], None, iq, pd)}

    def mlp(f):
        return {"up": _enc_linear(f["w_up"], f["b_up"], pd, pf),
                "down": _enc_linear(f["w_down"], f["b_down"], pf, pd)}

    wp = {"enc_layers": [], "dec_layers": []}
    for i in range(cfg.encoder_layers):
        p_l = jax.tree.map(lambda a: a[i], params["enc_layers"])
        wp["enc_layers"].append({
            "ln1": _norm_perm(p_l["ln1"], pd), "attn": attn(p_l["attn"]),
            "ln2": _norm_perm(p_l["ln2"], pd), "ffn": mlp(p_l["ffn"])})
    for i in range(cfg.num_layers):
        p_l = jax.tree.map(lambda a: a[i], params["dec_layers"])
        wp["dec_layers"].append({
            "ln1": _norm_perm(p_l["ln1"], pd), "attn": attn(p_l["attn"]),
            "lnx": _norm_perm(p_l["lnx"], pd),
            "xattn": attn(p_l["xattn"]),
            "ln2": _norm_perm(p_l["ln2"], pd), "ffn": mlp(p_l["ffn"])})
    wp["embed"] = {"tok": ring.encode(permute.apply_perm(
        jnp.asarray(params["embed"]["tok"], P32), pd, 1))}
    wp["enc_pos"] = ring.encode(permute.apply_perm(
        jnp.asarray(params["enc_pos"], P32), pd, 1))
    wp["dec_pos"] = ring.encode(permute.apply_perm(
        jnp.asarray(params["dec_pos"], P32), pd, 1))
    wp["enc_norm"] = _norm_perm(params["enc_norm"], pd)
    wp["dec_norm"] = _norm_perm(params["dec_norm"], pd)
    wp["head"] = _enc_linear(params["embed"]["tok"], None, pd, perms["v"])
    pm = PrivateModel(cfg, "centaur", perms, wp, ks, dealer)
    return pm


def whisper_private_forward(pm: PrivateModel, embeds, tokens):
    """Private enc-dec inference: client shares frame embeddings and
    decoder tokens; returns de-permuted decoder logits."""
    cfg = pm.cfg
    B, Se, _ = embeds.shape
    _, Sd = tokens.shape
    wp = pm.wp
    # encoder: client embeds -> shares -> Pi_PPP into pi-space
    x = share(pm.ks(), ring.encode(jnp.asarray(embeds, P32)))
    with comm.tag("embedding"):
        x = protocols.pp_permute(x, pm.perms["d"], axis=-1)
        x = x + wp["enc_pos"][:Se][None]
    for p in wp["enc_layers"]:
        hx = _c_norm(pm, p["ln1"], x)
        x = x + _c_attention(pm, p["attn"], hx, _NO_EXPOSE, causal=False)
        hx = _c_norm(pm, p["ln2"], x)
        x = x + _c_ffn(pm, p["ffn"], hx, _NO_EXPOSE)
    enc = _c_norm(pm, wp["enc_norm"], x)

    # decoder
    xoh = encrypt_tokens(pm, tokens)
    with comm.tag("embedding"):
        y = protocols.scal_mul(jnp.swapaxes(wp["embed"]["tok"], 0, 1),
                               xoh, rescale=False)
        y = y + wp["dec_pos"][:Sd][None]
    for p in wp["dec_layers"]:
        hy = _c_norm(pm, p["ln1"], y)
        y = y + _c_attention(pm, p["attn"], hy, _NO_EXPOSE, causal=True)
        hy = _c_norm(pm, p["lnx"], y)
        y = y + _c_attention(pm, p["xattn"], hy, _NO_EXPOSE, kv=enc, causal=False)
        hy = _c_norm(pm, p["ln2"], y)
        y = y + _c_ffn(pm, p["ffn"], hy, _NO_EXPOSE)
    y = _c_norm(pm, wp["dec_norm"], y)
    with comm.tag("adaptation"):
        logits_p = protocols.linear(wp["head"]["w"], None, y)
    yv = ring.decode(reconstruct(logits_p), dtype=P32)
    return permute.apply_inv_perm(yv, pm.perms["v"], -1)
