"""Trace-time communication accounting for the Centaur protocols.

Every protocol op records (rounds, bits) at Python call time using the
*static shapes* of its operands, reproducing the closed-form costs of
paper Table 1.  Because shapes are static under jit, tracing a step once
yields the exact per-step ledger; nothing dynamic crosses into the jitted
computation.

Events are tagged with the enclosing layer kind ("linear", "softmax",
"gelu", "layernorm", "embedding", "adaptation", ...) via the `tag`
context manager so benchmarks can reproduce the paper's per-layer
breakdowns (Fig. 3 / Fig. 7 / Fig. 8).
"""
from __future__ import annotations

import contextlib
from collections import defaultdict
from dataclasses import dataclass, field

from repro.runtime import faults

RING_BITS = 64


@dataclass
class CommEvent:
    protocol: str       # e.g. "matmul", "scalmul", "ppsm"
    rounds: int
    bits: int
    tag: str            # layer kind
    online: bool = True  # False for dealer/offline traffic


@dataclass
class CommLedger:
    events: list = field(default_factory=list)

    def record(self, protocol: str, rounds: int, bits: int,
               online: bool = True):
        self.events.append(
            CommEvent(protocol, rounds, int(bits), current_tag(), online))

    # ---- aggregation -----------------------------------------------------
    def total_bits(self, online_only: bool = True) -> int:
        return sum(e.bits for e in self.events
                   if e.online or not online_only)

    def total_rounds(self, online_only: bool = True) -> int:
        return sum(e.rounds for e in self.events
                   if e.online or not online_only)

    def total_bytes(self, online_only: bool = True) -> float:
        return self.total_bits(online_only) / 8

    def by_tag(self):
        out = defaultdict(lambda: {"rounds": 0, "bits": 0})
        for e in self.events:
            if not e.online:
                continue
            out[e.tag]["rounds"] += e.rounds
            out[e.tag]["bits"] += e.bits
        return dict(out)

    def by_protocol(self):
        out = defaultdict(lambda: {"rounds": 0, "bits": 0, "calls": 0})
        for e in self.events:
            if not e.online:
                continue
            out[e.protocol]["rounds"] += e.rounds
            out[e.protocol]["bits"] += e.bits
            out[e.protocol]["calls"] += 1
        return dict(out)

    def simulate_time(self, bandwidth_bps: float, rtt_s: float) -> float:
        """Network time under the paper's analytic model:
        bits/bandwidth + rounds * RTT (LAN 3Gbps/0.8ms, WAN 200/40,
        WAN 100/80)."""
        return (self.total_bits() / bandwidth_bps
                + self.total_rounds() * rtt_s)


# ---- ambient ledger / tag / transport stacks -----------------------------
_LEDGERS: list[CommLedger] = []
_TAGS: list[str] = []
_CAPTURES: list[CommLedger] = []
_TRANSPORTS: list = [None]


@contextlib.contextmanager
def transported(transport):
    """Make a `runtime.transport.Transport` ambient for the enclosed
    block: every recorded open's payload seam (`exchange`) and every
    replayed schedule event (`replay`) route through it.  None (the
    module default) and non-``real`` transports (loopback) keep the
    legacy behavior bit-exactly; a ``real`` transport moves actual
    bytes and owns the transport-fault seam.  Re-entrant."""
    _TRANSPORTS.append(transport)
    try:
        yield transport
    finally:
        _TRANSPORTS.pop()


def active_transport():
    return _TRANSPORTS[-1]


def exchange(protocol: str, arrays, reply: bool = True):
    """Payload seam of a recorded open: move one party's share arrays
    through the ambient transport and return them AS RECEIVED (identity
    for no/loopback transport — the SPMD simulation already holds both
    shares).  Skipped under `muted`/`capture` (abstract traces move
    nothing) exactly where `record` skips billing."""
    t = _TRANSPORTS[-1]
    if t is None or _MUTED[-1] or _CAPTURES:
        return arrays
    return t.exchange(protocol, arrays, reply=reply)


@contextlib.contextmanager
def ledger():
    led = CommLedger()
    _LEDGERS.append(led)
    try:
        yield led
    finally:
        _LEDGERS.pop()


@contextlib.contextmanager
def capture():
    """Record *exclusively* into the yielded ledger.

    Used to build static cost schedules: an abstract trace of a protocol
    block (jax.eval_shape) records its events here without leaking them
    into the caller's ambient ledgers, so the schedule can later be
    `replay`ed exactly once per real execution of the jitted block."""
    led = CommLedger()
    _CAPTURES.append(led)
    try:
        yield led
    finally:
        _CAPTURES.pop()


def replay(events, online_only: bool = False):
    """Bill a pre-captured schedule into every active ledger.

    Events keep their capture-time protocol/tag so per-layer breakdowns
    are identical to eager execution.  `online_only` skips offline
    (dealer) events — used when the triple pool bills the offline phase
    itself at generation time."""
    if _MUTED[-1] or _CAPTURES:
        return
    # per-event outer loop so an injected transport fault (jit path:
    # the schedule replays where eager would record) bills every ledger
    # the events up to the failed message, exactly like eager — partial
    # ticks stay sum-conserving across ledgers.  Per-ledger event order
    # is unchanged.
    t = _TRANSPORTS[-1]
    for e in events:
        if online_only and not e.online:
            continue
        for led in _LEDGERS:
            led.events.append(CommEvent(e.protocol, e.rounds, e.bits,
                                        e.tag, e.online))
        # payload seam: online events of the replayed schedule move real
        # bytes / inject round latency through the ambient transport.
        # Offline events never push — the dealer stream owns those
        # bytes.  A real transport owns the drop seam (a fired
        # transport_drop is a genuine wire timeout raised from push);
        # otherwise the legacy synthetic raise fires here, after
        # billing, as before.
        if t is not None and e.online:
            t.push(e.protocol, e.rounds, e.bits)
        if (t is None or not t.real) and faults._INJECTORS:
            faults.on_record(e.protocol, e.rounds, e.bits, e.online)


@contextlib.contextmanager
def tag(name: str):
    _TAGS.append(name)
    try:
        yield
    finally:
        _TAGS.pop()


def current_tag() -> str:
    return _TAGS[-1] if _TAGS else "untagged"


_MUTED = [False]


@contextlib.contextmanager
def muted():
    """Suppress recording (e.g. simulation computes all MoE experts for
    simplicity but bills only the dispatched tokens)."""
    _MUTED.append(True)
    try:
        yield
    finally:
        _MUTED.pop()


def record(protocol: str, rounds: int, bits: int, online: bool = True):
    """Record into every active ledger (no-op when none is active).

    While a `capture()` is open, events go only to the innermost capture
    ledger (they will be billed to real ledgers later via `replay`)."""
    if _MUTED[-1]:
        return
    if _CAPTURES:
        _CAPTURES[-1].record(protocol, rounds, bits, online)
        return
    for led in _LEDGERS:
        led.record(protocol, rounds, bits, online)
    # chaos seam, AFTER billing: the bytes crossed, then the failure
    # surfaced — an injected TransportFault leaves every ledger with
    # the partial event so accounting stays sum-conserving.  With a
    # REAL transport ambient the drop seam lives in the transport
    # itself (`exchange`/`push` raise genuine wire timeouts), so the
    # synthetic raise is skipped.
    t = _TRANSPORTS[-1]
    if t is not None and t.real:
        return
    if faults._INJECTORS:
        faults.on_record(protocol, rounds, bits, online)


def capturing() -> bool:
    """True while a `capture()` trace is open — seams use this to keep
    chaos hooks out of abstract cost-schedule traces."""
    return bool(_CAPTURES)


def numel(shape) -> int:
    n = 1
    for s in shape:
        n *= int(s)
    return n


# ---- per-request attribution (continuous-batching serving) -----------------

def attribute(events, keys):
    """Split a batched step's events across the requests it served.

    One batched decode tick bills the ambient ledger once for the whole
    slot batch; each of the `keys` (request ids) did an equal 1/n share
    of that step's work (every active slot contributes identically-shaped
    rows to every protocol message).  Each event's rounds/bits are split
    by integer division with the remainder dealt round-robin, the start
    offset rotating with the event index so no key is systematically
    favored.  The split is *exact*: for every event,
    sum over keys == original, so per-request totals always sum to the
    ledger totals, and with a single key the events are returned intact
    (single-slot batched == sequential billing).

    Semantics: bits are genuinely partitioned (each slot's rows cross
    the wire once), while rounds are shared latency — every active slot
    experiences each round concurrently.  The 1/n rounds share is a
    *cost attribution* that keeps sums conserving (amortization is the
    point of batching); to estimate one request's wall-clock latency,
    use the global ledger's rounds over the ticks it was active, not
    its attributed share.
    """
    n = len(keys)
    out = {k: CommLedger() for k in keys}
    if n == 0:
        return out
    for j, e in enumerate(events):
        qb, rb = divmod(e.bits, n)
        qr, rr = divmod(e.rounds, n)
        for i, k in enumerate(keys):
            off = (i + j) % n
            out[k].events.append(CommEvent(
                e.protocol,
                qr + (1 if off < rr else 0),
                qb + (1 if off < rb else 0),
                e.tag, e.online))
    return out
