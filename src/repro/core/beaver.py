"""Beaver-triple multiplication between secret shares (Pi_MatMul).

Triples are produced by a PRG-seeded dealer (the CrypTen "trusted third
party" model, paper §2.2).  Dealer traffic is billed as offline; the
online cost of one share x share matmul is 1 round and
2*(numel(E) + numel(F))*64 bits — for square n x n operands that is the
paper's 256 n^2 bits (Table 1).

Online-phase structure (DESIGN.md §4): the textbook combine

    Z_i = E @ B_i + A_i @ F (+ C_i, + E @ F for party 1)

issues five independent ring GEMMs per multiplication.  The fused path
collapses each party's cross terms into one block-stacked GEMM along
the contraction axis — party 1's E @ F folds into its B-block by
distributivity —

    party 0: [E | A_0] @ [B_0     ; F]
    party 1: [E | A_1] @ [B_1 + F ; F]

and batches both parties' stacks into a single leading-dim-2 GEMM: ONE
dispatch and 4/5 of the reference MACs instead of 5 GEMMs.  Ring
addition is exact mod 2^64, so the fused result is *bit-identical* to
the unfused reference given the same triple — see
tests/test_beaver_fusion.py.

Offline phase (DESIGN.md §5): `TripleDealer` generates triples lazily
per call (reference semantics); `TriplePool` pre-generates a batch of
triples per (kind, shape) spec in one jit-compiled vectorized pass, so
the offline phase is actually offline as the paper bills it.
"""
from __future__ import annotations

from collections import deque

import jax
import jax.numpy as jnp

from repro.runtime import faults

from . import comm, ring
from .sharing import ShareTensor, share

# Flip to False to restore the unfused 5-GEMM reference combine globally
# (benchmarks toggle per call via the `fused=` kwarg instead).
FUSE_ONLINE = True


def _fault_dealer(kind: str):
    """Chaos seam on offline-material generation.  Guarded against
    capture() traces: a RecordingDealer discovering a layer's triple
    demand under eval_shape must never trip a plan counter (eager and
    jit paths would diverge on when plans fire)."""
    if faults._INJECTORS and not comm.capturing():
        faults.on_dealer(kind)


def _fault_take(spec):
    if faults._INJECTORS and not comm.capturing():
        faults.on_take(spec)


def _matmul_triple_bits(a_shape, b_shape, c_shape) -> int:
    return (comm.numel(a_shape) + comm.numel(b_shape)
            + comm.numel(c_shape)) * comm.RING_BITS * 2


class TripleDealer:
    """Deterministic PRG dealer handing out multiplication triples."""

    def __init__(self, key):
        self._key = key

    def _split(self, n=3):
        keys = jax.random.split(self._key, n + 1)
        self._key = keys[0]
        return keys[1:]

    def matmul_triple(self, a_shape, b_shape):
        _fault_dealer("matmul")
        ka, kb, ks = self._split()
        a = ring.rand_ring(ka, a_shape)
        b = ring.rand_ring(kb, b_shape)
        c = ring.ring_matmul(a, b)
        ks0, ks1, ks2 = jax.random.split(ks, 3)
        comm.record("dealer_triple", rounds=1,
                    bits=_matmul_triple_bits(a_shape, b_shape, c.shape),
                    online=False)
        return share(ks0, a), share(ks1, b), share(ks2, c)

    def mul_triple(self, shape):
        _fault_dealer("mul")
        ka, kb, ks = self._split()
        a = ring.rand_ring(ka, shape)
        b = ring.rand_ring(kb, shape)
        c = a * b
        ks0, ks1, ks2 = jax.random.split(ks, 3)
        comm.record("dealer_triple", rounds=1,
                    bits=comm.numel(shape) * comm.RING_BITS * 6,
                    online=False)
        return share(ks0, a), share(ks1, b), share(ks2, c)

    def square_triple(self, shape):
        """(A, A^2) pair for the square protocol (half a mul triple)."""
        _fault_dealer("square")
        ka, ks1, ks2 = self._split()
        a = ring.rand_ring(ka, shape)
        c = a * a
        comm.record("dealer_triple", rounds=1,
                    bits=comm.numel(shape) * comm.RING_BITS * 4,
                    online=False)
        return share(ks1, a), share(ks2, c)

    def mask_pair(self, shape):
        """Shares of a fresh uniform mask A (no product attached).

        Persistent-mask opens (chunk cache rows, weight opens —
        DESIGN.md §10/§12) draw their one-time mask B here; products
        against an already-open side draw `maskmul_pair` instead."""
        _fault_dealer("mask")
        ka, ks1, _ = self._split()
        a = ring.rand_ring(ka, shape)
        comm.record("dealer_triple", rounds=1,
                    bits=comm.numel(shape) * comm.RING_BITS * 2,
                    online=False)
        return share(ks1, a)

    def maskmul_pair(self, a_shape, b_shape):
        """Fresh mask A for a product whose other side is already open
        against a PERSISTENT mask B (chunk caches, weight opens).

        The dealer delivers the A shares AND the matching C = A @ B
        shares (it dealt B, so it can form the product offline); both
        deliveries are billed HERE, at the dealer seam, so the lazy
        dealer and `TriplePool` generation-time billing are bit-exact
        per triple — eager and jit offline ledgers agree (DESIGN.md
        §12).  C itself is derived against the caller's B inside
        `matmul_masked_f` (simulation shortcut)."""
        _fault_dealer("maskmul")
        ka, ks1, _ = self._split()
        a = ring.rand_ring(ka, a_shape)
        comm.record("dealer_triple", rounds=1,
                    bits=_spec_offline_bits(("maskmul", tuple(a_shape),
                                             tuple(b_shape))),
                    online=False)
        return share(ks1, a)


# =============================================================================
# triple pool: vectorized, jit-compiled offline phase (DESIGN.md §5)
# =============================================================================

def _gen_matmul_triple(key, a_shape, b_shape):
    ka, kb, ks = jax.random.split(key, 3)
    a = ring.rand_ring(ka, a_shape)
    b = ring.rand_ring(kb, b_shape)
    c = ring.ring_matmul(a, b)
    ks0, ks1, ks2 = jax.random.split(ks, 3)
    return share(ks0, a), share(ks1, b), share(ks2, c)


def _gen_mul_triple(key, shape):
    ka, kb, ks = jax.random.split(key, 3)
    a = ring.rand_ring(ka, shape)
    b = ring.rand_ring(kb, shape)
    ks0, ks1, ks2 = jax.random.split(ks, 3)
    return share(ks0, a), share(ks1, b), share(ks2, a * b)


def _gen_square_triple(key, shape):
    ka, ks1, ks2 = jax.random.split(key, 3)
    a = ring.rand_ring(ka, shape)
    return share(ks1, a), share(ks2, a * a)


def _gen_mask_pair(key, shape):
    ka, ks1 = jax.random.split(key)
    return share(ks1, ring.rand_ring(ka, shape))


def _gen_maskmul_pair(key, a_shape, b_shape):
    # only the A shares are generated: C = A @ B is derived against the
    # caller's persistent B inside matmul_masked_f (its delivery is
    # still billed by the spec — see _spec_offline_bits)
    del b_shape
    return _gen_mask_pair(key, a_shape)


_GEN = {"matmul": _gen_matmul_triple, "mul": _gen_mul_triple,
        "square": _gen_square_triple, "mask": _gen_mask_pair,
        "maskmul": _gen_maskmul_pair}


#: process-wide (spec, n) -> compiled generation program.  The program
#: is a pure function of (spec, n), so every pool in the process shares
#: one compile — a fresh engine's pool reuses the programs of every
#: engine before it instead of re-jitting its own closures (which
#: defeated jax's pjit cache and dominated engine start-up).
_GEN_PROGRAMS: dict = {}


def gen_batch(spec, key, n: int, jit_cache: dict | None = None) -> list:
    """The n triples `TriplePool.generate(spec, n)` appends, given the
    pool's next PRG key: n == 1 generates eagerly (no per-spec program
    compile), n > 1 runs one split+vmap program, jitted through the
    process-wide `_GEN_PROGRAMS` cache (or a caller-supplied
    `jit_cache` dict), keyed by ``(spec, n)``.

    Factored out of the pool so the in-process pool and the
    dealer-service process (`runtime.dealer_service`) run the SAME
    generation code path: identical (spec, key, n) requests yield
    bit-identical offline material on both sides of the wire (and jit
    vs eager generation is bit-identical too — integer ops on a
    counter-based PRG)."""
    spec = _canon_spec(spec)
    kind, shapes = spec[0], spec[1:]
    if n == 1:
        return [_GEN[kind](key, *shapes)]
    cache = _GEN_PROGRAMS if jit_cache is None else jit_cache
    fn = cache.get((spec, n))
    if fn is None:
        def gen(k):
            keys = jax.random.split(k, n)
            return jax.vmap(lambda kk: _GEN[kind](kk, *shapes))(keys)
        fn = cache[(spec, n)] = jax.jit(gen)
    stacked = fn(key)
    return [jax.tree.map(lambda t, i=i: t[i], stacked) for i in range(n)]


def _mm_out_shape(a_shape, b_shape):
    return jax.eval_shape(
        lambda a, b: jnp.matmul(a, b),
        jax.ShapeDtypeStruct(a_shape, ring.RING_DTYPE),
        jax.ShapeDtypeStruct(b_shape, ring.RING_DTYPE)).shape


def _spec_offline_bits(spec) -> int:
    kind = spec[0]
    if kind == "matmul":
        _, a_shape, b_shape = spec
        return _matmul_triple_bits(a_shape, b_shape,
                                   _mm_out_shape(a_shape, b_shape))
    if kind == "maskmul":
        # A shares + C = A @ B shares (B is the caller's persistent
        # mask, delivered once elsewhere)
        _, a_shape, b_shape = spec
        return (comm.numel(a_shape)
                + comm.numel(_mm_out_shape(a_shape, b_shape))) \
            * comm.RING_BITS * 2
    n = comm.numel(spec[1])
    return n * comm.RING_BITS * {"mul": 6, "square": 4, "mask": 2}[kind]


class TriplePool:
    """Shape-keyed pool of pre-generated multiplication triples.

    Specs are `("matmul", a_shape, b_shape)`, `("mul", shape)` or
    `("square", shape)`.  Generation for a spec batch runs as ONE
    jit-compiled vectorized program (vmap over PRG subkeys), so a
    layer's worth of triples costs a single dispatch — this is the
    protocol's offline phase, billed as offline dealer traffic at
    generation time.  The pool quacks like `TripleDealer`, so every
    beaver op accepts either.
    """

    def __init__(self, key, batch: int = 8):
        self._key = key
        self.batch = batch
        self._pools: dict[tuple, deque] = {}
        self._taken: dict[tuple, int] = {}
        # per-spec telemetry for health()["pool"]: a take served from
        # stock is a hit, a take that had to generate (or block on the
        # async dealer stream) is a miss; low/high water track the
        # stock level seen at takes / after refills.
        self._hits: dict[tuple, int] = {}
        self._misses: dict[tuple, int] = {}
        self._low_water: dict[tuple, int] = {}
        self._high_water: dict[tuple, int] = {}

    def _next_key(self):
        self._key, k = jax.random.split(self._key)
        return k

    def generate(self, spec, n: int):
        """Vectorized offline generation of n triples for one spec.
        n == 1 generates eagerly (no per-spec program compile) — the
        right shape for one-shot specs like growing KV-decode GEMMs."""
        spec = _canon_spec(spec)
        _fault_dealer(spec[0])
        pool = self._pools.setdefault(spec, deque())
        pool.extend(gen_batch(spec, self._next_key(), n))
        self._high_water[spec] = max(self._high_water.get(spec, 0),
                                     len(pool))
        comm.record("dealer_triple", rounds=1,
                    bits=n * _spec_offline_bits(spec), online=False)

    def prefetch(self, specs):
        """Pre-generate exactly the given multiset of specs (e.g. one
        forward layer's trace), one vectorized dispatch per unique
        spec."""
        counts: dict[tuple, int] = {}
        for s in specs:
            s = _canon_spec(s)
            counts[s] = counts.get(s, 0) + 1
        for spec, n in counts.items():
            have = len(self._pools.get(spec, ()))
            if have < n:
                self.generate(spec, n - have)

    def reserve(self, specs, steps: int = 1):
        """Keep `steps` repetitions of a *recurring* spec multiset in
        stock, refilling in whole-horizon quanta.

        Continuous-batching decode consumes the same triple shapes every
        tick (the padded slot batch is shape-static).  When a spec's
        stock drops below one tick's demand, a full `steps * demand`
        batch is regenerated in ONE vectorized dispatch — the refill
        size is constant, so exactly one generator program is compiled
        per spec and the offline phase runs once every `steps` ticks
        instead of dribbling n=1 generations (the cost profile the
        growing per-request KV shapes used to force)."""
        steps = max(int(steps), 1)
        counts: dict[tuple, int] = {}
        for s in specs:
            s = _canon_spec(s)
            counts[s] = counts.get(s, 0) + 1
        for spec, c in counts.items():
            if len(self._pools.get(spec, ())) < c:
                self.generate(spec, steps * c)

    def take(self, spec):
        """Pop a triple, generating demand-proportionally on a miss:
        min(batch, takes-so-far, >= 1).  One-shot shapes (e.g. the
        per-step growing GEMMs of KV-cache decode) generate exactly
        what they use — no inflated offline billing, no vectorized
        generators compiled for shapes never seen again — while hot
        recurring shapes ramp up to `batch`-ahead generation."""
        spec = _canon_spec(spec)
        _fault_take(spec)
        pool = self._pools.setdefault(spec, deque())
        self._note_take(spec, len(pool))
        if not pool:
            n = min(self.batch, max(1, self._taken.get(spec, 0)))
            self.generate(spec, n)
        self._taken[spec] = self._taken.get(spec, 0) + 1
        return pool.popleft()

    def _note_take(self, spec, avail: int):
        self._low_water[spec] = min(self._low_water.get(spec, avail),
                                    avail)
        book = self._hits if avail else self._misses
        book[spec] = book.get(spec, 0) + 1

    def size(self, spec) -> int:
        return len(self._pools.get(_canon_spec(spec), ()))

    def stock(self) -> dict:
        """Pool census for engine.health(): triples in stock and taken
        so far per spec kind (aggregated over shapes), aggregate
        prefetch hit/miss counts, and a per-spec breakdown with
        low/high watermarks so the async dealer's lookahead is
        observable (a rising miss count or a low water of 0 on a hot
        spec means takes are outrunning delivery)."""
        in_stock: dict[str, int] = {}
        taken: dict[str, int] = {}
        for spec, pool in self._pools.items():
            in_stock[spec[0]] = in_stock.get(spec[0], 0) + len(pool)
        for spec, n in self._taken.items():
            taken[spec[0]] = taken.get(spec[0], 0) + n
        per_spec: dict[str, dict] = {}
        for spec in (set(self._pools) | set(self._taken)
                     | set(self._hits) | set(self._misses)):
            per_spec[_spec_name(spec)] = {
                "in_stock": len(self._pools.get(spec, ())),
                "taken": self._taken.get(spec, 0),
                "hits": self._hits.get(spec, 0),
                "misses": self._misses.get(spec, 0),
                "low_water": self._low_water.get(spec, 0),
                "high_water": self._high_water.get(spec, 0)}
        return {"in_stock": in_stock, "taken": taken,
                "specs": len(self._pools),
                "prefetch": {"hits": sum(self._hits.values()),
                             "misses": sum(self._misses.values())},
                "per_spec": per_spec}

    # ---- TripleDealer interface -------------------------------------------
    def matmul_triple(self, a_shape, b_shape):
        return self.take(("matmul", a_shape, b_shape))

    def mul_triple(self, shape):
        return self.take(("mul", shape))

    def square_triple(self, shape):
        return self.take(("square", shape))

    def mask_pair(self, shape):
        return self.take(("mask", shape))

    def maskmul_pair(self, a_shape, b_shape):
        return self.take(("maskmul", a_shape, b_shape))


def _canon_spec(spec) -> tuple:
    return tuple((spec[0],) + tuple(tuple(int(d) for d in s)
                                    for s in spec[1:]))


def _spec_name(spec) -> str:
    """JSON-able census key, e.g. ``matmul[4x8,8x8]``."""
    return spec[0] + "[" + ",".join(
        "x".join(str(d) for d in s) for s in spec[1:]) + "]"


class ReplayDealer:
    """Hands out pre-generated triples in recorded order (the online
    side of the pooled offline phase; see private_model's jitted
    forward).  Records nothing — offline traffic was billed by the pool
    at generation time."""

    def __init__(self, triples):
        self._triples = iter(triples)

    def matmul_triple(self, a_shape, b_shape):
        return next(self._triples)

    def mul_triple(self, shape):
        return next(self._triples)

    def square_triple(self, shape):
        return next(self._triples)

    def mask_pair(self, shape):
        return next(self._triples)

    def maskmul_pair(self, a_shape, b_shape):
        return next(self._triples)


class RecordingDealer(TripleDealer):
    """TripleDealer that also logs the (kind, shapes) request sequence —
    used under an abstract trace to discover a layer's triple demand
    so the pool can prefetch it."""

    def __init__(self, key):
        super().__init__(key)
        self.specs: list[tuple] = []

    def matmul_triple(self, a_shape, b_shape):
        self.specs.append(_canon_spec(("matmul", a_shape, b_shape)))
        return super().matmul_triple(a_shape, b_shape)

    def mul_triple(self, shape):
        self.specs.append(_canon_spec(("mul", shape)))
        return super().mul_triple(shape)

    def square_triple(self, shape):
        self.specs.append(_canon_spec(("square", shape)))
        return super().square_triple(shape)

    def mask_pair(self, shape):
        self.specs.append(_canon_spec(("mask", shape)))
        return super().mask_pair(shape)

    def maskmul_pair(self, a_shape, b_shape):
        self.specs.append(_canon_spec(("maskmul", a_shape, b_shape)))
        return super().maskmul_pair(a_shape, b_shape)


# =============================================================================
# online phase
# =============================================================================

def _open_masked(x: ShareTensor, a: ShareTensor, protocol: str):
    """Open x - a (both parties exchange their shares)."""
    d = x - a
    # each party sends numel elements; 2x crosses the wire
    comm.record(protocol, rounds=0,
                bits=2 * comm.numel(d.shape) * comm.RING_BITS)
    # payload seam: party 1's share of X - A crosses the ambient
    # transport (party 0's mirror send is the echo leg, so total wire
    # bytes equal the billed bits), and the reconstruction uses the
    # bytes that actually arrived.  Identity under loopback/no
    # transport — bit-exact with the pre-transport runtime.
    (s1,) = comm.exchange(protocol, (d.s1,))
    e = d.s0 + s1
    # chaos seam: a corrupt_open/ring_wrap plan lands on the value a
    # party received here (concrete values only — see runtime.faults).
    # No envelope guard is possible at this seam: E = X - A is uniform
    # on the ring by construction.
    if faults._INJECTORS:
        e = faults.on_open(protocol, e)
    return e


def matmul_online(e, f, a: ShareTensor, b: ShareTensor, c: ShareTensor,
                  fused=None) -> ShareTensor:
    """Online combine Z = E@F + E@B + A@F + C from opened E, F.

    fused=True (default): one leading-dim-2 block GEMM

        party 0:  [E | A_0] @ [B_0     ; F]  = E@B_0 + A_0@F
        party 1:  [E | A_1] @ [B_1 + F ; F]  = E@B_1 + E@F + A_1@F

    — E@F is *folded* into party 1's block by distributivity (ring adds
    are exact mod 2^64), so the whole online phase is ONE batched GEMM
    dispatch and 4n^3 MACs instead of the reference's 5 GEMMs / 5n^3.

    fused="stack": the intermediate form — the same leading-dim-2 block
    GEMM with a separate E@F (2 dispatches) — kept for benchmarking.

    All variants are bit-identical given the same triple."""
    if fused is None:
        fused = FUSE_ONLINE
    can_fuse = (fused and e.ndim >= 2 and f.ndim >= 2
                and (e.shape[:-2] == f.shape[:-2] or f.ndim == 2))
    if not can_fuse:
        ef = ring.ring_matmul(e, f)
        z0 = ring.ring_matmul(e, b.s0) + ring.ring_matmul(a.s0, f) + c.s0
        z1 = (ef + ring.ring_matmul(e, b.s1) + ring.ring_matmul(a.s1, f)
              + c.s1)
        return ShareTensor(z0, z1)

    # [E | A_i] along the contraction axis of the lhs (last), and
    # [B_i ; F] along the contraction axis of the rhs (second-last);
    # parties stacked on a fresh leading batch axis.  A rank-2 rhs
    # against a batched lhs (e.g. one-hot @ embedding) is fused by
    # flattening the lhs batch dims into rows.
    stack_ef = fused == "stack"
    if f.ndim == 2 and e.ndim > 2:
        e2 = e.reshape(-1, e.shape[-1])
        a0, a1 = (a.s0.reshape(e2.shape), a.s1.reshape(e2.shape))
    else:
        e2, a0, a1 = e, a.s0, a.s1
    lhs = jnp.stack([jnp.concatenate([e2, a0], axis=-1),
                     jnp.concatenate([e2, a1], axis=-1)])
    rhs1_top = b.s1 if stack_ef else b.s1 + f
    rhs = jnp.stack([jnp.concatenate([b.s0, f], axis=-2),
                     jnp.concatenate([rhs1_top, f], axis=-2)])
    cross = ring.ring_matmul(lhs, rhs)
    out_shape = c.shape
    z0 = cross[0].reshape(out_shape) + c.s0
    z1 = cross[1].reshape(out_shape) + c.s1
    if stack_ef:
        z1 = z1 + ring.ring_matmul(e, f)
    return ShareTensor(z0, z1)


def mul_online(e, f, a: ShareTensor, b: ShareTensor, c: ShareTensor,
               fused=None) -> ShareTensor:
    """Element-wise online combine (one stacked multiply when fused;
    e*f folds into party 1's term as e*(b_1 + f))."""
    if fused is None:
        fused = FUSE_ONLINE
    if fused:
        prod = (jnp.stack([e, a.s0, e, a.s1])
                * jnp.stack([b.s0, f, b.s1 + f, f]))
        z0 = prod[0] + prod[1] + c.s0
        z1 = prod[2] + prod[3] + c.s1
    else:
        z0 = e * b.s0 + a.s0 * f + c.s0
        z1 = e * f + e * b.s1 + a.s1 * f + c.s1
    return ShareTensor(z0, z1)


def matmul(x: ShareTensor, y: ShareTensor, dealer,
           frac_bits: int = ring.FRAC_BITS, rescale: bool = True,
           protocol: str = "matmul",
           fused: bool | None = None) -> ShareTensor:
    """[X @ Y] from [X], [Y].  Batched shapes supported (jnp.matmul rules).

    Z = E@F + E@B + A@F + C with E = X-A, F = Y-B opened in one round.
    """
    a, b, c = dealer.matmul_triple(x.shape, y.shape)
    e = _open_masked(x, a, protocol)
    f = _open_masked(y, b, protocol)
    comm.record(protocol, rounds=1, bits=0)  # E,F open concurrently: 1 round
    z = matmul_online(e, f, a, b, c, fused)
    return z.truncate(frac_bits) if rescale else z


def open_rows(x: ShareTensor, mask: ShareTensor,
              protocol: str = "matmul"):
    """Open x against a fresh mask: both parties exchange their shares
    of x - mask and reconstruct the public value (2*numel*64 bits, no
    extra round — concurrent with the enclosing matmul's open).

    The chunked-prefill cache protocol (DESIGN.md §10) opens each newly
    written K/V row exactly once this way; every later chunk's matmul
    reuses the already-open value instead of re-opening the whole padded
    cache."""
    return _open_masked(x, mask, protocol)


def matmul_masked_f(x: ShareTensor, f_open, b: ShareTensor, dealer,
                    frac_bits: int = ring.FRAC_BITS, rescale: bool = True,
                    protocol: str = "matmul",
                    fused: bool | None = None) -> ShareTensor:
    """[X @ Y] where Y was already opened against a persistent mask:
    ``f_open`` = Y - B public, ``b`` = [B] (DESIGN.md §10, §12).

    Only E = X - A crosses the wire (2*numel(X)*64 bits, 1 round): the
    F side was opened once — incrementally by `open_rows` as cache rows
    were written, or at param-prep time by `open_weight` — and reusing
    the same opened value in later products reveals nothing new.  The
    dealer supplies the fresh A *and* the product C = A @ B against the
    caller's persistent B via `maskmul_pair`, which bills both A and
    C's delivery as offline dealer traffic at the dealer seam (so
    eager, pooled, and replayed ledgers agree bit-for-bit).  C is
    simulated here from the reconstructed plaintexts.  The combine is
    the standard Beaver identity Z = E@F + E@B + A@F + C, so the result
    is exactly X @ Y mod 2^64 before truncation — bit-compatible with
    `matmul`."""
    a = dealer.maskmul_pair(x.shape, b.shape)
    e = _open_masked(x, a, protocol)
    comm.record(protocol, rounds=1, bits=0)  # E opens in its own round
    c_plain = ring.ring_matmul(a.s0 + a.s1, b.s0 + b.s1)
    c = ShareTensor(c_plain, jnp.zeros_like(c_plain))
    z = matmul_online(e, f_open, a, b, c, fused)
    return z.truncate(frac_bits) if rescale else z


def open_weight(w: ShareTensor, dealer, protocol: str = "weight_open"):
    """Open a *static* weight tensor once against a persistent dealer
    mask B_w (DESIGN.md §12): returns ``(f, b_w)`` with
    ``f = W - B_w`` public and ``b_w`` = [B_w] shares.

    Called once per weight per engine lifetime at param-prep time; all
    subsequent GEMMs against W route through `matmul_masked_f(x, f,
    b_w, dealer)` so only the activation side E = X - A crosses the
    wire per call.  The one-time open costs 2*numel(W)*64 bits, billed
    under the ``weight_open`` protocol bucket so serving ledgers can
    attribute it separately from per-tick online traffic.

    Leakage: the public value F = W - B_w is uniform on the ring
    because B_w is a fresh uniform mask — the same argument as chunk
    cache-row opens (`open_rows`), and re-using F across ticks reveals
    nothing beyond the first open."""
    b_w = dealer.mask_pair(w.shape)
    f = _open_masked(w, b_w, protocol)
    comm.record(protocol, rounds=1, bits=0)  # the open's round
    return f, b_w


def mul(x: ShareTensor, y: ShareTensor, dealer,
        frac_bits: int = ring.FRAC_BITS, rescale: bool = True,
        protocol: str = "mul", fused: bool | None = None) -> ShareTensor:
    """Element-wise [X * Y] (broadcasting not supported: shapes must match)."""
    assert x.shape == y.shape, (x.shape, y.shape)
    a, b, c = dealer.mul_triple(x.shape)
    e = _open_masked(x, a, protocol)
    f = _open_masked(y, b, protocol)
    comm.record(protocol, rounds=1, bits=0)
    z = mul_online(e, f, a, b, c, fused)
    return z.truncate(frac_bits) if rescale else z


def square(x: ShareTensor, dealer,
           frac_bits: int = ring.FRAC_BITS,
           fused: bool | None = None) -> ShareTensor:
    """[X^2] with a square triple (A, A^2): only E = X-A is opened, so the
    cost is half a mul — 1 round, 128 * numel bits (CrypTen semantics;
    this is what makes exp cost the paper's 1024 bits/scalar)."""
    if fused is None:
        fused = FUSE_ONLINE
    a_sh, c_sh = dealer.square_triple(x.shape)
    e = _open_masked(x, a_sh, "square")
    comm.record("square", rounds=1, bits=0)
    if fused:
        # z0 = e*(2 a_0); z1 = e*(e + 2 a_1)  (e*e folded, one stacked mul)
        prod = jnp.stack([2 * a_sh.s0, e + 2 * a_sh.s1]) * e
        z0 = prod[0] + c_sh.s0
        z1 = prod[1] + c_sh.s1
    else:
        z0 = 2 * e * a_sh.s0 + c_sh.s0
        z1 = e * e + 2 * e * a_sh.s1 + c_sh.s1
    return ShareTensor(z0, z1).truncate(frac_bits)
