"""Beaver-triple multiplication between secret shares (Pi_MatMul).

Triples are produced by a PRG-seeded dealer (the CrypTen "trusted third
party" model, paper §2.2).  Dealer traffic is billed as offline; the
online cost of one share x share matmul is 1 round and
2*(numel(E) + numel(F))*64 bits — for square n x n operands that is the
paper's 256 n^2 bits (Table 1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import comm, ring
from .sharing import ShareTensor, reconstruct, share


class TripleDealer:
    """Deterministic PRG dealer handing out multiplication triples."""

    def __init__(self, key):
        self._key = key

    def _split(self, n=3):
        keys = jax.random.split(self._key, n + 1)
        self._key = keys[0]
        return keys[1:]

    def matmul_triple(self, a_shape, b_shape):
        ka, kb, ks = self._split()
        a = ring.rand_ring(ka, a_shape)
        b = ring.rand_ring(kb, b_shape)
        c = ring.ring_matmul(a, b)
        ks0, ks1, ks2 = jax.random.split(ks, 3)
        bits = (comm.numel(a_shape) + comm.numel(b_shape)
                + comm.numel(c.shape)) * comm.RING_BITS * 2
        comm.record("dealer_triple", rounds=1, bits=bits, online=False)
        return share(ks0, a), share(ks1, b), share(ks2, c)

    def mul_triple(self, shape):
        ka, kb, ks = self._split()
        a = ring.rand_ring(ka, shape)
        b = ring.rand_ring(kb, shape)
        c = a * b
        ks0, ks1, ks2 = jax.random.split(ks, 3)
        comm.record("dealer_triple", rounds=1,
                    bits=comm.numel(shape) * comm.RING_BITS * 6,
                    online=False)
        return share(ks0, a), share(ks1, b), share(ks2, c)


def _open_masked(x: ShareTensor, a: ShareTensor, protocol: str):
    """Open x - a (both parties exchange their shares)."""
    e = reconstruct(x - a)
    # each party sends numel elements; 2x crosses the wire
    comm.record(protocol, rounds=0,
                bits=2 * comm.numel(e.shape) * comm.RING_BITS)
    return e


def matmul(x: ShareTensor, y: ShareTensor, dealer: TripleDealer,
           frac_bits: int = ring.FRAC_BITS, rescale: bool = True,
           protocol: str = "matmul") -> ShareTensor:
    """[X @ Y] from [X], [Y].  Batched shapes supported (jnp.matmul rules).

    Z = E@F + E@B + A@F + C with E = X-A, F = Y-B opened in one round.
    """
    a, b, c = dealer.matmul_triple(x.shape, y.shape)
    e = _open_masked(x, a, protocol)
    f = _open_masked(y, b, protocol)
    comm.record(protocol, rounds=1, bits=0)  # E,F open concurrently: 1 round
    ef = ring.ring_matmul(e, f)
    z0 = ring.ring_matmul(e, b.s0) + ring.ring_matmul(a.s0, f) + c.s0
    z1 = (ef + ring.ring_matmul(e, b.s1) + ring.ring_matmul(a.s1, f)
          + c.s1)
    z = ShareTensor(z0, z1)
    return z.truncate(frac_bits) if rescale else z


def mul(x: ShareTensor, y: ShareTensor, dealer: TripleDealer,
        frac_bits: int = ring.FRAC_BITS, rescale: bool = True,
        protocol: str = "mul") -> ShareTensor:
    """Element-wise [X * Y] (broadcasting not supported: shapes must match)."""
    assert x.shape == y.shape, (x.shape, y.shape)
    a, b, c = dealer.mul_triple(x.shape)
    e = _open_masked(x, a, protocol)
    f = _open_masked(y, b, protocol)
    comm.record(protocol, rounds=1, bits=0)
    z0 = e * b.s0 + a.s0 * f + c.s0
    z1 = e * f + e * b.s1 + a.s1 * f + c.s1
    z = ShareTensor(z0, z1)
    return z.truncate(frac_bits) if rescale else z


def square(x: ShareTensor, dealer: TripleDealer,
           frac_bits: int = ring.FRAC_BITS) -> ShareTensor:
    """[X^2] with a square triple (A, A^2): only E = X-A is opened, so the
    cost is half a mul — 1 round, 128 * numel bits (CrypTen semantics;
    this is what makes exp cost the paper's 1024 bits/scalar)."""
    ka, ks1, ks2 = dealer._split()
    a = ring.rand_ring(ka, x.shape)
    c = a * a
    comm.record("dealer_triple", rounds=1,
                bits=comm.numel(x.shape) * comm.RING_BITS * 4, online=False)
    a_sh = share(ks1, a)
    c_sh = share(ks2, c)
    e = _open_masked(x, a_sh, "square")
    comm.record("square", rounds=1, bits=0)
    z0 = 2 * e * a_sh.s0 + c_sh.s0
    z1 = e * e + 2 * e * a_sh.s1 + c_sh.s1
    return ShareTensor(z0, z1).truncate(frac_bits)
