"""Protocol-suite package: per-mode PPTI protocols behind one executor.

``base``      — ProtocolSuite interface, PrivateModel state, get_suite.
``executor``  — the shared layer/block executor (residual skeleton,
                attention shapes, masking, KV-cache serving, jit).
``centaur``   — the paper's protocol (+ parameter preparation).
``smpc``      — PUMA/CrypTen baselines (smpc / mpcformer / secformer).
``permute_suite`` — the permutation-only STI baseline.
``masking``   — the shared causal/slot mask constants and caches.
"""
from .base import (MODES, KeyStream, PrivateModel, ProtocolSuite,
                   encrypt_tokens, get_suite)
from .centaur import CentaurSuite
from .executor import (attention, block, decode_step, ffn,
                       init_slot_caches, mla_attention, model_forward,
                       prefill)
from .permute_suite import PermuteSuite
from .smpc import SmpcSuite

__all__ = [
    "MODES", "KeyStream", "PrivateModel", "ProtocolSuite",
    "encrypt_tokens", "get_suite", "CentaurSuite", "SmpcSuite",
    "PermuteSuite", "attention", "block", "decode_step", "ffn",
    "init_slot_caches", "mla_attention", "model_forward", "prefill",
]
