"""SmpcSuite: PUMA/CrypTen-style SMPC baselines (smpc / mpcformer /
secformer nonlinear variants).

Weights AND activations are secret-shared; every linear is a Beaver
Pi_MatMul and every nonlinearity an iterative fixed-point approximation
(core.smpc_nl).  The mode string picks the nonlinear variant:

  smpc       — CrypTen limit-approx exp/NR softmax + piecewise GeLU
  mpcformer  — Quad GeLU + 2Quad softmax substitutions (paper Eq. 8)
  secformer  — 2Quad softmax, exact-structure GeLU/SiLU approximations

Parameter preparation shares the raw weights but reshapes them into the
same canonical per-layer layout the centaur suite uses, so ONE executor
drives both protocol families (and the SMPC baselines inherit the
jitted, slot-batched KV-cache decode path the paper's protocol got in
PRs 1–2 — the refactor that makes the centaur-vs-smpc serving ratio
measurable end-to-end)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import beaver, comm, ring, smpc_nl
from ..sharing import ShareTensor, reconstruct, share
from .base import ShareSuite, encrypt_tokens

P32 = jnp.float32


def norm_stat_bound(cfg) -> float | None:
    """Public per-config upper bound on norm-input statistics
    (variance / mean-squares) passed to smpc_inv_sqrt's power-of-two
    pre-scale.  Architecture knowledge, not data: squared-ReLU MLPs
    (nemotron/minitron) square the residual stream, pushing norm
    statistics into the thousands where the bare fixed-range NR
    diverges; every other activation family stays well inside the
    default [1e-2, 64] window, so None keeps the baseline-faithful
    unscaled iteration (and its exact historical ledger)."""
    return 4096.0 if cfg.act == "relu2" else None


def prepare_shared(cfg, params, ks, dealer):
    """Secret-share every parameter, arranged in the executor's
    canonical layout (same keys as the centaur preparation) — and open
    every *static* weight matrix ONCE against a persistent dealer mask
    (DESIGN.md §12).

    Each GEMM weight is stored pre-transposed into the orientation
    `matmul` consumes and opened via `beaver.open_weight`, yielding
    ``{"f": W^T - B_w (public), "m": [B_w], "b": [bias] | None}``.
    F = W^T - B_w is uniform on the ring (B_w is a fresh uniform mask),
    so publishing it once per engine lifetime leaks nothing — the same
    argument as the chunked-prefill cache-row opens.  Every later
    linear routes through `matmul_masked_f`, so only the activation
    side E = X - A crosses the wire per call; the one-time opens are
    billed under the ``weight_open`` ledger bucket.  Norm/bias
    parameters stay plain shares (they enter via mul/add, not GEMMs)."""
    assert cfg.family in ("encoder", "dense") and not cfg.use_mla, \
        "smpc baselines cover the paper's encoder/dense shapes"

    def enc_share(a):
        return share(ks(), ring.encode(jnp.asarray(a, P32)))

    def share_tree(t):
        return jax.tree.map(enc_share, t)

    def open_w(a, transpose=True):
        if transpose:
            a = jnp.swapaxes(jnp.asarray(a, P32), -1, -2)
        f, m = beaver.open_weight(enc_share(a), dealer)
        return {"f": f, "m": m}

    def lin(w, b=None):
        d = open_w(w)
        d["b"] = None if b is None else enc_share(b)
        return d

    # embed table stays in natural (vocab, d) orientation — the one-hot
    # GEMM consumes it untransposed
    wp = {"embed": {"tok": open_w(params["embed"]["tok"],
                                  transpose=False)}}
    if "pos" in params["embed"]:
        wp["embed"]["pos"] = enc_share(params["embed"]["pos"])
    if "embed_norm" in params:
        wp["embed_norm"] = share_tree(params["embed_norm"])

    wp["layers"] = []
    for i in range(cfg.num_layers):
        p_l = jax.tree.map(lambda a: a[i], params["layers"])
        a = p_l["attn"]
        f = p_l["ffn"]
        if cfg.ffn_type == "swiglu":
            ffn = {"w_gate": lin(f["w_gate"]), "w_up": lin(f["w_up"]),
                   "w_down": lin(f["w_down"])}
        else:
            ffn = {"up": lin(f["w_up"], f["b_up"]),
                   "down": lin(f["w_down"], f["b_down"])}
        wp["layers"].append({
            "ln1": share_tree(p_l["ln1"]),
            "ln2": share_tree(p_l["ln2"]),
            "attn": {k: lin(a[k]) for k in ("wq", "wk", "wv", "wo")},
            "ffn": ffn,
        })

    wp["final_norm"] = share_tree(params["final_norm"])
    if cfg.family == "encoder":
        wp["pooler"] = lin(params["pooler"]["w"], params["pooler"]["b"])
        wp["classifier"] = lin(params["classifier"]["w"],
                               params["classifier"]["b"])
    else:
        if cfg.tie_embeddings:
            # tied embeddings reuse the very same one-time open: the
            # head's (d, vocab) public F and mask are free transposed
            # views of the embed table's — one sharing, one bill
            tok = wp["embed"]["tok"]
            wp["head"] = {
                "f": jnp.swapaxes(tok["f"], -1, -2),
                "m": ShareTensor(jnp.swapaxes(tok["m"].s0, -1, -2),
                                 jnp.swapaxes(tok["m"].s1, -1, -2)),
                "b": None}
        else:
            wp["head"] = lin(params["head"]["w"])
    return wp


class SmpcSuite(ShareSuite):
    exposes = False
    families = ("dense", "encoder")
    serves = True

    def __init__(self, pm):
        super().__init__(pm)
        self.mode = pm.mode

    def jittable(self) -> bool:
        return self.cfg.family in ("dense", "encoder")

    # ---- protocol surface --------------------------------------------------
    def embed(self, tokens, positions, expose: bool = False):
        pm = self.pm
        x_oh = encrypt_tokens(pm, tokens)
        tok = pm.wp["embed"]["tok"]
        with comm.tag("embedding"):
            y = beaver.matmul_masked_f(x_oh, tok["f"], tok["m"],
                                       self.dealer, rescale=False)
            if "pos" in pm.wp["embed"] and positions is not None:
                pos = pm.wp["embed"]["pos"]
                y = y + ShareTensor(jnp.take(pos.s0, positions, axis=0),
                                    jnp.take(pos.s1, positions, axis=0))
            if "embed_norm" in pm.wp:
                y = self.norm(pm.wp["embed_norm"], y, tag="embedding")
        return y

    def linear(self, p, x):
        # weights were opened once at prep (pre-transposed); only the
        # activation side E = X - A crosses the wire here
        y = beaver.matmul_masked_f(x, p["f"], p["m"], self.dealer)
        if p.get("b") is not None:
            y = y + p["b"]
        return y

    def softmax_pair(self, scores, values, *, per_slot: bool,
                     expose: bool = False):
        if self.mode in ("mpcformer", "secformer"):
            probs = smpc_nl.quad_softmax(scores, self.dealer)
        else:
            probs = smpc_nl.smpc_softmax(scores, self.dealer)
        return probs, values

    def softmax_chunk(self, scores, pst):
        """Share-domain softmax over the rectangular chunk scores: the
        approximations are axis-generic and reveal nothing, so no
        permutation state is needed (pst is None) and the output is
        already in natural key-column order."""
        probs, _ = self.softmax_pair(scores, None, per_slot=False)
        return probs

    def act(self, x, expose: bool = False):
        if self.mode == "mpcformer":
            return smpc_nl.quad_gelu(x, self.dealer)
        if self.cfg.act == "silu":
            return smpc_nl.smpc_silu(x, self.dealer)
        if self.cfg.act == "relu2":
            return smpc_nl.smpc_relu2(x, self.dealer)
        return smpc_nl.smpc_gelu(x, self.dealer)

    def glu(self, gate, up, expose: bool = False):
        return beaver.mul(self.act(gate), up, self.dealer)

    def tanh(self, x):
        return smpc_nl.smpc_tanh(x, self.dealer)

    def norm(self, p, x, tag: str = "layernorm", expose_as=None):
        cfg = self.cfg
        with comm.tag(tag):
            if cfg.norm_type == "layernorm":
                return smpc_nl.smpc_layernorm(
                    x, p["g"], p["b"], self.dealer, eps=cfg.norm_eps,
                    var_bound=norm_stat_bound(cfg))
            # RMSNorm: reuse LN machinery without mean subtraction
            sq = beaver.square(x, self.dealer)
            ms = ShareTensor(jnp.sum(sq.s0, -1, keepdims=True),
                             jnp.sum(sq.s1, -1, keepdims=True)
                             ).mul_public(
                ring.encode(1.0 / x.shape[-1])) \
                + ring.encode(cfg.norm_eps)
            inv = smpc_nl.smpc_inv_sqrt(ms, self.dealer,
                                        bound=norm_stat_bound(cfg))
            invb = ShareTensor(jnp.broadcast_to(inv.s0, x.shape),
                               jnp.broadcast_to(inv.s1, x.shape))
            y = beaver.mul(x, invb, self.dealer)
            gb = ShareTensor(jnp.broadcast_to(p["g"].s0, x.shape),
                             jnp.broadcast_to(p["g"].s1, x.shape))
            return beaver.mul(y, gb, self.dealer)

    def head(self, x):
        cfg, pm = self.cfg, self.pm
        with comm.tag("adaptation"):
            if cfg.family == "encoder":
                pooled = self.linear(pm.wp["pooler"], x[:, 0, :])
                t = self.tanh(pooled)
                out = self.linear(pm.wp["classifier"], t)
                return ring.decode(reconstruct(out), dtype=P32)
            # final_norm applies unconditionally for decoders, exactly
            # like the plaintext reference (models/layers.lm_head path)
            x = self.norm(pm.wp["final_norm"], x, tag="adaptation")
            logits = self.linear(pm.wp["head"], x)
        return ring.decode(reconstruct(logits), dtype=P32)
