"""Shared attention masking for every PPTI suite (causal + slot padding).

All suites agree on one mask contract: a dead key column (future token
under the causal mask, or an unwritten row of a padded slot cache) is
pushed ``MASK_MAGNITUDE`` below any live score *before* the softmax.
That single constant is what makes dead columns carry exactly zero
softmax mass in every mode:

* centaur — the masked, π1-permuted scores are revealed to P1 and
  softmaxed in float32; ``exp(-MASK_MAGNITUDE)`` underflows to exact
  float32 zero relative to any live score.
* smpc / mpcformer / secformer — the CrypTen limit-approx exp clamps its
  input to ``-2^k + 1`` and ``(1/2^k)^{2^k}`` collapses to exact
  fixed-point zero within two squarings, and 2Quad maps masked scores to
  its ``-c`` zero point; dead columns contribute nothing to the sum.
* permute — plaintext scores are substituted with ``-MASK_MAGNITUDE``
  (the STI baseline masks in the clear).

The helpers below are the only place the magnitude and the
``jnp.tril``-style index math live; suites never rebuild per-layer mask
tensors — the causal validity pattern and its ring encoding are each
built once per shape and shared across layers and calls.
"""
from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

from .. import ring

#: Depth of the additive mask in logit units.  Must stay large enough
#: that exp underflows (see module docstring) yet small enough that the
#: fixed-point encoding ``MASK_MAGNITUDE * 2^FRAC_BITS`` stays far from
#: the ring's wrap point.
MASK_MAGNITUDE = 1e4


@functools.lru_cache(maxsize=None)
def causal_valid(S: int, T: int):
    """(S, T) bool: query row i may attend key column j iff j <= i.

    A *numpy* constant on purpose: the executor calls this inside
    ``jax.eval_shape`` / ``jax.jit`` traces, and a cached jnp value
    would be a leaked tracer on the next trace.  numpy constants fold
    into any trace safely and the cache replaces the per-layer
    ``jnp.tril`` rebuild of the old monolith.
    """
    return np.arange(T)[None, :] <= np.arange(S)[:, None]


_STATIC_RING_MASKS: dict = {}


def ring_mask(valid):
    """Additive ring-encoded mask from a bool validity tensor.

    numpy inputs (the cached static causal masks) are encoded with
    numpy and memoized, so the result is a trace-safe constant built
    once per (shape, contents); traced inputs (the per-slot decode
    validity) go through the normal ring encode.
    """
    if isinstance(valid, np.ndarray):
        key = (valid.shape, valid.tobytes())
        if key not in _STATIC_RING_MASKS:
            scaled = ((valid.astype(np.float64) - 1.0) * MASK_MAGNITUDE
                      * (1 << ring.FRAC_BITS))
            _STATIC_RING_MASKS[key] = np.round(scaled).astype(np.int64)
        return _STATIC_RING_MASKS[key]
    return ring.encode((valid.astype(jnp.float64) - 1.0) * MASK_MAGNITUDE)


def slot_valid(q_pos, L: int):
    """(B, S, L) validity for padded slot decode.

    Key column t is live for the query of slot b at absolute position
    ``q_pos[b, s]`` iff ``t <= q_pos[b, s]`` — unwritten cache rows
    (t > pos) and rows past the slot's occupancy are dead.
    """
    return jnp.arange(L)[None, None, :] <= q_pos[:, :, None]


def chunk_valid(q_pos, lens, L: int):
    """(B, C, L) validity for one chunked-prefill tick against the
    padded slot cache (DESIGN.md §10).

    Key column t is live for the chunk query row of request b at
    absolute position ``q_pos[b, s]`` iff it is causal against the
    cache (``t <= q_pos[b, s]`` — the rectangular slice of the full
    tril that this chunk's rows occupy) AND a real prompt token
    (``t < lens[b]``): the tail chunk is padded up to the chunk size,
    and its padded rows' garbage K/V columns must stay dead for every
    query.  Padded query rows (q_pos >= lens) keep their live real
    columns so their softmax stays well-defined; the garbage rows they
    write above ``lens`` are the §7 unwritten-row case — decode's
    ``slot_valid`` keeps them dead until overwritten.

    ``q_pos`` and ``lens`` are traced inputs, NOT static shapes: ONE
    compiled chunk program per (chunk size, max_len) serves every
    chunk of every prompt length.
    """
    t = jnp.arange(L)
    return ((t[None, None, :] <= q_pos[:, :, None])
            & (t[None, None, :] < lens[:, None, None]))


def prefill_valid(lens, S: int):
    """(B, S, S) validity for bucket-padded prefill.

    Key column t is live for query row s of request b iff it is causal
    (``t <= s``) AND a real prompt token (``t < lens[b]``), so padded
    prompt columns carry exactly zero softmax mass in every suite.
    Padded *query* rows (s >= lens[b]) keep their live real columns:
    their softmax stays well-defined (no all-dead row), and the garbage
    K/V they write into cache rows >= lens[b] stays invisible — decode's
    ``slot_valid`` masks t > pos until the row is overwritten by the
    token actually decoded at that position.

    ``lens`` is a traced (B,) input, NOT a static shape: one compiled
    prefill program per bucket serves every real length inside it.
    """
    t = jnp.arange(S)
    causal = t[None, None, :] <= t[None, :, None]
    return causal & (t[None, None, :] < lens[:, None, None])
