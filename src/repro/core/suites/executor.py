"""The shared layer/block executor every PPTI suite runs on.

ONE implementation of everything that is protocol-independent:

* the transformer residual skeleton (pre/post-norm, exposure points),
* attention shapes incl. GQA head grouping and MLA latent projections,
* causal masking and padded-slot validity masking (core.suites.masking),
* the full-sequence forward for every model family,
* the slot-stacked padded KV-cache prefill/decode loop (DESIGN.md §7),
* the `_JitLayer`/`comm.capture` machinery of DESIGN.md §6 and the
  `TriplePool` offline phases.

Because the executor only touches values through suite methods and
shape-preserving ops both value domains support (reshape / transpose /
`+`), a suite written against ``core.suites.base.ProtocolSuite`` gains
the jitted, continuous-batched serving path for free — this is what
makes the SMPC baselines servable under the identical conditions the
paper's speedup claim requires.

Executor contract (DESIGN.md §8): a suite may capture only its
PrivateModel; every call the executor makes must be traceable under
``jax.eval_shape`` (billing is Python-side and captured/replayed), and
the eager and jitted paths must bill identical ledgers.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.runtime.faults import EngineConfigError, ProtocolIntegrityError

from .. import beaver, comm, ring
from ..sharing import ShareTensor
from . import masking
from .base import KeyStream, PrivateModel, get_suite


# =============================================================================
# value-domain-generic tensor helpers (ShareTensor | plain array)
# =============================================================================

def bcast(x, shape):
    if isinstance(x, ShareTensor):
        return ShareTensor(jnp.broadcast_to(x.s0, shape),
                           jnp.broadcast_to(x.s1, shape))
    return jnp.broadcast_to(x, shape)


def swap(x, a: int, b: int):
    if isinstance(x, ShareTensor):
        return ShareTensor(jnp.swapaxes(x.s0, a, b),
                           jnp.swapaxes(x.s1, a, b))
    return jnp.swapaxes(x, a, b)


def concat(xs, axis: int):
    if isinstance(xs[0], ShareTensor):
        return ShareTensor(jnp.concatenate([x.s0 for x in xs], axis),
                           jnp.concatenate([x.s1 for x in xs], axis))
    return jnp.concatenate(xs, axis)


def slot_write(cache, new, pos):
    """Write new K/V rows (B,S,...) into the padded cache (B,L,...) at
    per-slot offsets pos (B,) — applied to each share separately."""
    def upd(c, nw):
        return jax.vmap(lambda cb, nb, pb:
                        jax.lax.dynamic_update_slice_in_dim(cb, nb, pb,
                                                            axis=0)
                        )(c, nw, pos)
    if isinstance(cache, ShareTensor):
        return ShareTensor(upd(cache.s0, new.s0), upd(cache.s1, new.s1))
    return upd(cache, new)


def rows_at(x, idx):
    """Gather one sequence row per batch element: x (B, S, ...) at
    per-row positions idx (B,) -> (B, 1, ...).  Bucketed prefill uses
    this to read the last-REAL-token hidden state at the true prompt
    length instead of the padded position -1."""
    ix = idx.reshape((-1,) + (1,) * (x.ndim - 1))

    def g(a):
        return jnp.take_along_axis(a, ix, axis=1)
    if isinstance(x, ShareTensor):
        return ShareTensor(g(x.s0), g(x.s1))
    return g(x)


def pad_cache_to(c, max_len: int):
    pad = [(0, 0)] * c.ndim
    pad[1] = (0, max_len - c.shape[1])
    if isinstance(c, ShareTensor):
        return ShareTensor(jnp.pad(c.s0, pad), jnp.pad(c.s1, pad))
    return jnp.pad(c, pad)


# =============================================================================
# attention (standard multi-head incl. GQA; full / prefill / slot-decode)
# =============================================================================

def project_qkv(suite, p, x, kv_in, rope_pos):
    """Shared attention prologue for every call shape (full sequence,
    prefill, slot decode, chunked prefill): Q/K/V projections, optional
    RoPE rotation at absolute positions ``rope_pos`` (B, S) — pass None
    to skip rotation (cross-attention) — and GQA head grouping.
    Returns (q (B,S,hk,g,dh), k, v (B,T,hk,dh))."""
    cfg = suite.cfg
    B, S, _ = x.shape
    T = kv_in.shape[1]
    h, hk, dh, g = cfg.num_heads, cfg.num_kv_heads, cfg.dh, cfg.q_groups
    with comm.tag("linear"):
        q = suite.linear(p["wq"], x)
        k = suite.linear(p["wk"], kv_in).reshape(B, T, hk, dh)
        v = suite.linear(p["wv"], kv_in).reshape(B, T, hk, dh)
    if cfg.pos_embed == "rope" and rope_pos is not None:
        from repro.models.layers import rope_freqs
        cos, sin = rope_freqs(cfg, rope_pos, dh)
        q = suite.rope(q.reshape(B, S, h, dh), cos, sin)
        k = suite.rope(k, cos, sin)
    return q.reshape(B, S, hk, g, dh), k, v


def attn_output(suite, p, o3):
    """Shared attention epilogue: (B,hk,g,S,dh) head outputs back to
    (B, S, h*dh) rows through the output projection."""
    B, hk, g, S, dh = o3.shape
    o3 = o3.transpose(0, 3, 1, 2, 4).reshape(B, S, hk * g * dh)
    with comm.tag("linear"):
        return suite.linear(p["wo"], o3)


def attention(suite, p, x, *, kv=None, causal=None, cache=None, pos=None,
              want_cache: bool = False, expose: bool = False, valid=None):
    """The paper's attention flow in any mode.

    Three call shapes share this body:
      * full sequence (``cache is None``): self- or cross-attention
        (``kv`` = encoder output) over the whole prompt;
      * prefill (``want_cache=True``): same, returning the K/V state for
        the caller to pad into a slot cache; a bucket-padded prefill
        passes ``valid`` — an explicit (B, S, T) per-request validity
        (``masking.prefill_valid``) that overrides the static causal
        pattern so dead padded prompt columns get zero softmax mass;
      * slot decode (``cache``+``pos``): new K/V rows are written at
        per-slot offsets and queries attend over the whole padded axis
        under the shared validity mask.

    (The fourth call shape, chunked prefill, lives in
    `_chunk_attention`: its amortized opened-cache state replaces the
    share-cache middle section, but it shares this prologue/epilogue
    via `project_qkv`/`attn_output`.)
    """
    cfg = suite.cfg
    B, S, _ = x.shape
    kv_in = x if kv is None else kv
    hk, dh, g = cfg.num_kv_heads, cfg.dh, cfg.q_groups
    causal = cfg.causal if causal is None else causal
    q_pos = (pos[:, None] + jnp.arange(S)[None, :]
             if cache is not None else None)              # (B,S)
    rope_pos = None
    if kv is None:
        rope_pos = (q_pos if q_pos is not None
                    else jnp.arange(S)[None, :].repeat(B, 0))
    q, k, v = project_qkv(suite, p, x, kv_in, rope_pos)

    new_cache = None
    if cache is not None:
        k_all = slot_write(cache["k"], k, pos)
        v_all = slot_write(cache["v"], v, pos)
        new_cache = {"k": k_all, "v": v_all}
    else:
        k_all, v_all = k, v
        if want_cache:
            new_cache = {"k": k, "v": v}
    L = k_all.shape[1]

    qh = q.transpose(0, 2, 3, 1, 4)                       # (B,hk,g,S,dh)
    kt = swap(k_all.transpose(0, 2, 1, 3), -1, -2)        # (B,hk,dh,L)
    kt = bcast(kt[:, :, None], (B, hk, g, dh, L))
    with comm.tag("linear"):
        o1 = suite.matmul(qh, kt)                         # (B,hk,g,S,L)
    o1 = suite.scale(o1, dh ** -0.5)
    if cache is not None:
        o1 = suite.mask(o1, masking.slot_valid(q_pos, L)[:, None, None])
    elif valid is not None:
        o1 = suite.mask(o1, valid[:, None, None])
    elif causal:
        o1 = suite.mask(o1, masking.causal_valid(S, L))
    vt = v_all.transpose(0, 2, 1, 3)                      # (B,hk,L,dh)
    with comm.tag("softmax"):
        probs, vp = suite.softmax_pair(o1, vt,
                                       per_slot=cache is not None,
                                       expose=expose)
    vp = bcast(vp[:, :, None], (B, hk, g, L, dh))
    with comm.tag("linear"):
        o3 = suite.matmul(probs, vp)                      # (B,hk,g,S,dh)
    return attn_output(suite, p, o3), new_cache


def mla_attention(suite, p, x, expose: bool = False):
    """MLA (deepseek-v2): latent down-projections with their own norms;
    per-head scores follow the same Pi_MatMul -> softmax_pair flow with
    [q_nope|q_pe] / [k_nope|k_pe] concatenated heads."""
    cfg = suite.cfg
    B, S, _ = x.shape
    h = cfg.num_heads
    qn, qr, vd = (cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                  cfg.v_head_dim)
    with comm.tag("linear"):
        q_lat = suite.linear(p["wq_a"], x)
    q_lat = suite.norm(p["q_norm"], q_lat)
    with comm.tag("linear"):
        q = suite.linear(p["wq_b"], q_lat).reshape(B, S, h, qn + qr)
        kv_a = suite.linear(p["wkv_a"], x)
    ckv = kv_a[..., :cfg.kv_lora_rank]
    k_pe = kv_a[..., cfg.kv_lora_rank:]
    ckv = suite.norm(p["kv_norm"], ckv)
    with comm.tag("linear"):
        kv = suite.linear(p["wkv_b"], ckv).reshape(B, S, h, qn + vd)

    from repro.models.layers import rope_freqs
    pos = jnp.arange(S)[None, :].repeat(B, 0)
    cos, sin = rope_freqs(cfg, pos, qr)
    q_pe = suite.rope(q[..., qn:], cos, sin)
    k_pe = suite.rope(k_pe.reshape(B, S, 1, qr), cos, sin)

    # concat heads: q_cat (B,h,S,qn+qr); k_cat (B,h,qn+qr,S)
    q_cat = concat([q[..., :qn], q_pe], -1).transpose(0, 2, 1, 3)
    k_pe_b = bcast(k_pe, (B, S, h, qr))
    k_cat = concat([kv[..., :qn], k_pe_b], -1).transpose(0, 2, 3, 1)
    v = kv[..., qn:].transpose(0, 2, 1, 3)                # (B,h,S,vd)

    with comm.tag("linear"):
        o1 = suite.matmul(q_cat, k_cat)
    o1 = suite.scale(o1, (qn + qr) ** -0.5)
    o1 = suite.mask(o1, masking.causal_valid(S, S))
    with comm.tag("softmax"):
        o2p, vp = suite.softmax_pair(o1, v, per_slot=False,
                                     expose=expose)
    with comm.tag("linear"):
        o3 = suite.matmul(o2p, vp)                        # (B,h,S,vd)
    o3 = o3.transpose(0, 2, 1, 3).reshape(B, S, h * vd)
    with comm.tag("linear"):
        return suite.linear(p["wo"], o3)


# =============================================================================
# FFN + residual block
# =============================================================================

def ffn(suite, p, x, expose: bool = False):
    cfg = suite.cfg
    if cfg.family == "moe":
        return suite.moe_ffn(p, x, expose=expose)
    if cfg.ffn_type == "swiglu":
        with comm.tag("linear"):
            gt = suite.linear(p["w_gate"], x)
            up = suite.linear(p["w_up"], x)
        with comm.tag("gelu"):
            hidden = suite.glu(gt, up, expose=expose)
        with comm.tag("linear"):
            return suite.linear(p["w_down"], hidden)
    with comm.tag("linear"):
        o5 = suite.linear(p["up"], x)
    with comm.tag("gelu"):
        a = suite.act(o5, expose=expose)
    with comm.tag("linear"):
        return suite.linear(p["down"], a)


def block(suite, p, x, attn_fn, expose: bool = False):
    """The transformer residual skeleton shared by the full forward,
    prefill and slotted decode (pre/post-norm handling, exposure hooks
    only for the eager layer 0).  attn_fn(h) -> (attn_out, extra);
    `extra` carries a KV cache for the serving paths, None otherwise."""
    cfg = suite.cfg
    h = suite.norm(p["ln1"], x) if cfg.prenorm else x
    attn, extra = attn_fn(h)
    x = x + attn
    if not cfg.prenorm:
        x = suite.norm(p["ln1"], x,
                       expose_as="O4" if expose else None)
    elif expose:
        suite.expose_value("O4", x)
    h = suite.norm(p["ln2"], x) if cfg.prenorm else x
    f = ffn(suite, p["ffn"], h, expose=expose)
    x = x + f
    if not cfg.prenorm:
        x = suite.norm(p["ln2"], x,
                       expose_as="O6" if expose else None)
    elif expose:
        suite.expose_value("O6", x)
    return x, extra


def _std_layer(suite, p, x, expose: bool = False):
    """One standard transformer layer (dense/encoder/moe families)."""
    if suite.cfg.use_mla:
        def attn_fn(h):
            return mla_attention(suite, p["attn"], h, expose=expose), None
    else:
        def attn_fn(h):
            return attention(suite, p["attn"], h, expose=expose)[0], None
    return block(suite, p, x, attn_fn, expose=expose)[0]


def _family_layer(suite, i: int, x, expose: bool = False):
    """Layer i of the full-sequence forward, any model family."""
    cfg, pm = suite.cfg, suite.pm
    p = pm.wp["layers"][i]
    if cfg.family == "hybrid":
        # shared attention block every attn_every mamba layers
        ae = cfg.attn_every
        if i % ae == 0 and i < (cfg.num_layers // ae) * ae:
            shp = pm.wp["shared"]
            h = suite.norm(shp["ln1"], x)
            a, _ = attention(suite, shp["attn"], h, expose=expose)
            x = x + a
            h = suite.norm(shp["ln2"], x)
            x = x + ffn(suite, shp["ffn"], h, expose=expose)
        h = suite.norm(p["ln1"], x)
        return x + suite.mamba_block(p["mamba"], h, expose=expose)
    if cfg.family == "ssm":
        h = suite.norm(p["ln1"], x)
        return x + suite.mamba_block(p["mamba"], h, expose=expose)
    return _std_layer(suite, p, x, expose=expose)


# =============================================================================
# jitted per-layer machinery (hot path: fused online phase + triple pool
# + static comm schedule — DESIGN.md §6)
# =============================================================================

@dataclass
class _JitLayer:
    fn: Any           # jitted (p, x, key, triples) -> x'
    specs: list       # per-layer triple demand, in request order
    events: list      # captured per-layer comm schedule (CommEvents)


def _shadow(pm: PrivateModel, key, dealer) -> PrivateModel:
    """pm clone with a traced key stream/dealer and inert exposure."""
    return PrivateModel(pm.cfg, pm.mode, pm.perms, pm.wp,
                        KeyStream(key), dealer)


def _build_jit_layer(pm: PrivateModel, name: str, body, p, x) -> _JitLayer:
    """Compile one layer into a jitted function plus its static cost
    schedule and triple demand.

    1. An abstract trace (jax.eval_shape — zero FLOPs) under a
       `comm.capture()` discovers the layer's exact (rounds, bits)
       schedule and, via a RecordingDealer, the ordered multiset of
       Beaver triples it consumes.
    2. The online function is jitted with triples as *inputs* (a
       ReplayDealer hands them out in recorded order), so the offline
       phase runs ahead of time through the vectorized TriplePool and
       the jitted online program contains no dealer work.
    3. `comm.record` is Python-side and would fire once at trace time
       only; the traced body runs muted and the captured schedule is
       `comm.replay`ed per call instead, keeping the ledger exact.
    """
    key = pm.ks()

    recorders = []

    def record_run(p_, x_, key_):
        kd, ku = jax.random.split(key_)
        rec = beaver.RecordingDealer(kd)
        recorders.append(rec)
        return body(_shadow(pm, ku, rec), p_, x_)

    with comm.capture() as sched:
        jax.eval_shape(record_run, p, x, key)
    specs = recorders[-1].specs

    def online_run(p_, x_, key_, triples):
        _, ku = jax.random.split(key_)
        with comm.muted():
            return body(_shadow(pm, ku, beaver.ReplayDealer(triples)),
                        p_, x_)

    return _JitLayer(jax.jit(online_run), specs, list(sched.events))


def jit_layer_for(pm: PrivateModel, name: str, body, p, x) -> _JitLayer:
    # x may be any pytree of arrays/ShareTensors (the slotted decode
    # threads (x, k_cache, v_cache, pos) through one body)
    cache_key = (name, jax.tree.structure((p, x)),
                 tuple(jnp.shape(le) for le in jax.tree.leaves((p, x))))
    if cache_key not in pm.jit_cache:
        pm.jit_cache[cache_key] = _build_jit_layer(pm, name, body, p, x)
    return pm.jit_cache[cache_key]


def run_jit_layers(pm: PrivateModel, layer_ps, body, name: str, x):
    """Offline: prefetch every layer's triples in one vectorized batch
    per spec.  Online: run the jitted layer per depth, replaying the
    captured schedule (online events; offline was billed by the pool)."""
    jl = jit_layer_for(pm, name, body, layer_ps[0], x)
    pool = pm.triple_pool()
    pool.prefetch(jl.specs * len(layer_ps))
    for p in layer_ps:
        triples = [pool.take(s) for s in jl.specs]
        comm.replay(jl.events, online_only=True)
        x = jl.fn(p, x, pm.ks(), triples)
    return x


# =============================================================================
# full-sequence forward (all modes, all families)
# =============================================================================

def model_forward(pm: PrivateModel, tokens, jit: bool = False):
    """Full private forward; returns plaintext logits after the client
    reconstructs the output (and removes pi_v where the mode permutes
    the vocab axis).  The jit path compiles the uniform layer stack per
    depth and never populates pm.exposed (no traced intermediate
    escapes); the eager path records the mode's P1-observable surface.
    """
    suite = get_suite(pm)
    cfg = pm.cfg
    if cfg.family not in suite.families:
        raise EngineConfigError(
            f"{pm.mode} does not cover family {cfg.family!r}")
    if jit and suite.jittable():
        S = tokens.shape[1]
        x = suite.embed(tokens, jnp.arange(S))

        def body(shadow, p, xin):
            return _std_layer(get_suite(shadow), p, xin)

        x = run_jit_layers(pm, pm.wp["layers"], body,
                           f"{pm.mode}_layer", x)
        return suite.head(x)

    S = tokens.shape[1]
    x = suite.embed(tokens, jnp.arange(S), expose=suite.exposes)
    for i in range(cfg.num_layers):
        x = _family_layer(suite, i, x,
                          expose=suite.exposes and i == 0)
    return suite.head(x)


# =============================================================================
# serving: slot-stacked padded KV-cache prefill/decode (DESIGN.md §7)
# =============================================================================

def _assert_servable(suite):
    # explicit raises (not asserts): config validation must survive -O
    if not suite.serves:
        raise EngineConfigError(
            f"{suite.mode} mode has no share-domain KV-cache decode path")
    if suite.cfg.family != "dense" or suite.cfg.use_mla:
        raise EngineConfigError(
            "private serving covers the dense KV-cache decode path")


def init_slot_caches(pm: PrivateModel, n_slots: int, max_len: int):
    """Zeroed slot-stacked share KV caches: per layer {"k","v"} of shape
    (n_slots, max_len, hk, dh).  Zero shares reconstruct to zero, and
    the additive validity mask keeps unwritten rows at exactly zero
    softmax mass, so slots can be filled/evicted independently —
    identical in every share-domain mode."""
    cfg = pm.cfg
    z = jnp.zeros((n_slots, max_len, cfg.num_kv_heads, cfg.dh),
                  ring.RING_DTYPE)
    return [{"k": ShareTensor(z, z), "v": ShareTensor(z, z)}
            for _ in range(cfg.num_layers)]


def _prefill_layer(suite, p, x, valid=None):
    """One transformer layer at prompt length, returning the K/V state
    for the slot cache (serving hot path: never exposes)."""
    return block(suite, p, x,
                 lambda h: attention(suite, p["attn"], h, causal=True,
                                     want_cache=True, valid=valid))


def _decode_layer(suite, p, x, cache, pos):
    """One transformer layer over a slot batch (serving hot path, also
    traced into the jitted tick: never exposes)."""
    return block(suite, p, x,
                 lambda h: attention(suite, p["attn"], h, cache=cache,
                                     pos=pos))


def prefill(pm: PrivateModel, tokens, max_len: int | None = None,
            jit: bool = False, lens=None):
    """Private prefill in any servable mode: returns (last-token logits,
    per-layer K/V share caches padded to `max_len`), ready for
    `decode_step` or to be spliced into a slot of a stacked serving
    cache.

    ``lens=None`` (exact-length): attention runs at prompt length
    (comm ∝ S^2, as the sequential protocol bills) under the static
    causal mask and the last-position logits are returned; jit=True
    compiles one program per (B, S) like the decode path.

    ``lens`` = (B,) true prompt lengths (bucketed padded prefill):
    `tokens` is the bucket-padded batch, ``masking.prefill_valid``
    kills padded prompt columns in every layer's attention, and logits
    are gathered at the last REAL token (``lens - 1``).  `lens` is a
    traced input, so ONE compiled program per (B, bucket, max_len)
    serves every length mix inside the bucket — the comm bill is the
    padded bucket's S^2 (the bucketing overhead the serving bench
    reports).  Padded rows write garbage K/V above ``lens``; decode's
    slot-validity mask keeps those rows dead until they are overwritten
    at their true position.
    """
    suite = get_suite(pm)
    _assert_servable(suite)
    cfg = pm.cfg
    B, S = tokens.shape
    if max_len is None:
        max_len = S + 1
    if max_len < S:
        raise EngineConfigError(
            f"prompt length {S} exceeds max_len {max_len}")
    if lens is not None:
        lens = jnp.asarray(lens, jnp.int32)

    def run_layers(sh, p, tok, ln):
        x = sh.embed(tok, jnp.arange(S))
        valid = None if ln is None else masking.prefill_valid(ln, S)
        ks_, vs_ = [], []
        for i in range(cfg.num_layers):
            x, nc = _prefill_layer(sh, p[i], x, valid)
            ks_.append(pad_cache_to(nc["k"], max_len))
            vs_.append(pad_cache_to(nc["v"], max_len))
        last = x[:, -1:, :] if ln is None else rows_at(x, ln - 1)
        return sh.head(last), ks_, vs_

    if jit:
        def body(shadow, p, state):
            tok, ln = state if lens is not None else (state, None)
            return run_layers(get_suite(shadow), p, tok, ln)

        # max_len shapes the padded outputs but not the traced inputs,
        # so it must be part of the program cache key (the padded path
        # differs from exact-length by its (tokens, lens) pytree)
        state = tokens if lens is None else (tokens, lens)
        jl = jit_layer_for(pm, f"{pm.mode}_prefill:{max_len}", body,
                           pm.wp["layers"], state)
        pool = pm.triple_pool()
        pool.prefetch(jl.specs)
        triples = [pool.take(s) for s in jl.specs]
        comm.replay(jl.events, online_only=True)
        logits, ks_, vs_ = jl.fn(pm.wp["layers"], state, pm.ks(),
                                 triples)
        return logits, [{"k": k, "v": v} for k, v in zip(ks_, vs_)]

    logits, ks_, vs_ = run_layers(suite, pm.wp["layers"], tokens, lens)
    return logits, [{"k": k, "v": v} for k, v in zip(ks_, vs_)]


# =============================================================================
# chunked prefill (DESIGN.md §10): long prompts as fixed-size chunks
# against the slot cache, ONE compiled program per (chunk, max_len)
# =============================================================================

def init_chunk_state(pm: PrivateModel, n_slots: int, max_len: int):
    """Per-layer chunked-prefill state for a request batch:

    * ``ek``/``ev`` — the K/V cache *opened against persistent masks*
      (public ring tensors, (B, max_len, hk, dh)); each written row is
      opened exactly once by the chunk that writes it, so later chunks'
      score/value products never re-open the cache.
    * ``bk``/``bv`` — the persistent mask shares.  Rows start at zero
      (an unwritten zero-share row opened against a zero mask is 0 =
      0 - 0, keeping the Beaver identity exact over the whole padded
      axis) and receive a fresh dealer mask when written.
    * ``pi`` — the suite's per-request permutation state (centaur: one
      π1 per layer reused by every chunk, matrix material billed here
      at init; None for share-softmax suites).

    The decode-ready share cache is recovered by `chunk_state_caches`
    once the last chunk ran: K = ek + bk row-wise.
    """
    suite = get_suite(pm)
    _assert_servable(suite)
    cfg = pm.cfg
    z = jnp.zeros((n_slots, max_len, cfg.num_kv_heads, cfg.dh),
                  ring.RING_DTYPE)
    return [{"ek": z, "ev": z,
             "bk": ShareTensor(z, z), "bv": ShareTensor(z, z),
             "pi": suite.chunk_perm_state(n_slots, max_len)}
            for _ in range(cfg.num_layers)]


def chunk_state_caches(state):
    """Reconstruct the per-layer share KV caches from a finished chunk
    state (ready to splice into a serving slot for decode)."""
    return [{"k": ShareTensor(lst["ek"] + lst["bk"].s0, lst["bk"].s1),
             "v": ShareTensor(lst["ev"] + lst["bv"].s0, lst["bv"].s1)}
            for lst in state]


def _chunk_attention(suite, p, x, lst, pos, valid):
    """One chunk of queries (B, C, d) against the padded opened cache.

    Same flow as `attention`'s slot-decode path generalized from T=1
    to T=C (same `project_qkv` prologue and `attn_output` epilogue),
    but over the amortized cache state: fresh K/V rows get a dealer
    mask and are opened once; both attention products run
    `matmul_opened` against the public cache (only the share-side mask
    opens cross the wire), and the suite's `softmax_chunk` returns
    natural-order probabilities for the opened value cache."""
    cfg = suite.cfg
    B, C, _ = x.shape
    hk, dh, g = cfg.num_kv_heads, cfg.dh, cfg.q_groups
    q_pos = pos[:, None] + jnp.arange(C)                  # (B, C)
    q, k, v = project_qkv(suite, p, x, x, q_pos)

    with comm.tag("linear"):
        bk_new = suite.rand_mask((B, C, hk, dh))
        bv_new = suite.rand_mask((B, C, hk, dh))
        ek = slot_write(lst["ek"], suite.open_rows(k, bk_new), pos)
        ev = slot_write(lst["ev"], suite.open_rows(v, bv_new), pos)
    bk = slot_write(lst["bk"], bk_new, pos)
    bv = slot_write(lst["bv"], bv_new, pos)
    L = ek.shape[1]

    qh = q.transpose(0, 2, 3, 1, 4)                       # (B,hk,g,C,dh)
    fkt = jnp.broadcast_to(
        jnp.swapaxes(ek.transpose(0, 2, 1, 3), -1, -2)[:, :, None],
        (B, hk, g, dh, L))
    bkt = bcast(swap(bk.transpose(0, 2, 1, 3), -1, -2)[:, :, None],
                (B, hk, g, dh, L))
    with comm.tag("linear"):
        o1 = suite.matmul_opened(qh, fkt, bkt)            # (B,hk,g,C,L)
    o1 = suite.scale(o1, dh ** -0.5)
    o1 = suite.mask(o1, valid[:, None, None])
    with comm.tag("softmax"):
        probs = suite.softmax_chunk(o1, lst["pi"])
    fv = jnp.broadcast_to(ev.transpose(0, 2, 1, 3)[:, :, None],
                          (B, hk, g, L, dh))
    bvt = bcast(bv.transpose(0, 2, 1, 3)[:, :, None], (B, hk, g, L, dh))
    with comm.tag("linear"):
        o3 = suite.matmul_opened(probs, fv, bvt)          # (B,hk,g,C,dh)
    new_lst = {"ek": ek, "ev": ev, "bk": bk, "bv": bv, "pi": lst["pi"]}
    return attn_output(suite, p, o3), new_lst


def _chunk_layer(suite, p, x, lst, pos, valid):
    """One transformer layer over a prefill chunk (serving hot path,
    also traced into the jitted chunk tick: never exposes)."""
    return block(suite, p, x,
                 lambda h: _chunk_attention(suite, p["attn"], h, lst,
                                            pos, valid))


def chunk_head(pm: PrivateModel, last, jit: bool = False):
    """The adaptation head as its own tiny program over the final
    chunk's gathered last-token rows (B, 1, d) -> plaintext logits.

    Splitting the head out of the chunk program means non-final chunks
    neither run nor bill the (d, vocab) head GEMM whose output they
    discard — the head is opened/billed exactly once per request, while
    the chunk program stays shape-static (it returns the gathered
    hidden rows every tick; only the final tick feeds them here)."""
    if not jit:
        return get_suite(pm).head(last)

    def body(shadow, p_, x_):
        return get_suite(shadow).head(x_)

    # the name deliberately does NOT extend f"{pm.mode}_prefill" — it
    # is not a prefill variant and must not count against the
    # 1-prefill/1-chunk program budget (engine.compile_stats)
    jl = jit_layer_for(pm, f"{pm.mode}_chunk_head", body, None, last)
    pool = pm.triple_pool()
    pool.prefetch(jl.specs)
    triples = [pool.take(s) for s in jl.specs]
    comm.replay(jl.events, online_only=True)
    return jl.fn(None, last, pm.ks(), triples)


def prefill_chunk(pm: PrivateModel, state, token, pos, lens,
                  jit: bool = False, lookahead: int = 4,
                  final: bool | None = None):
    """One chunked-prefill tick: token (B, C) — the next C prompt
    tokens per request (tail chunk padded with dead tokens), pos int or
    (B,) absolute chunk offsets, lens (B,) true prompt lengths, state
    from `init_chunk_state`.  Returns (logits (B, 1, V), new state) on
    the FINAL chunk and (None, new state) otherwise: the chunk program
    itself ends at the gathered last-token hidden rows, and the
    adaptation head runs as its own tiny program (`chunk_head`) exactly
    once per request — non-final chunks no longer run or bill a head
    whose output they would discard.  ``final`` defaults to
    auto-detection (this chunk covers the last real token).

    The program is jit-keyed on (C, max_len) only — pos and lens are
    traced — so an engine serving arbitrary prompt lengths compiles
    exactly one chunk program (plus the head + §7 decode programs), and
    the per-chunk triple demand is the same multiset every tick, so
    `TriplePool.reserve` keeps `lookahead` chunks in stock."""
    suite = get_suite(pm)
    _assert_servable(suite)
    nl = pm.cfg.num_layers
    B, C = token.shape
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    lens = jnp.broadcast_to(jnp.asarray(lens, jnp.int32), (B,))
    L = int(state[0]["ek"].shape[1])
    if int(jnp.max(pos)) + C > L:
        raise ProtocolIntegrityError(
            f"chunk past padded cache: pos={pos}, C={C}, max_len={L}")
    if final is None:
        final = int(jnp.max(pos)) + C >= int(jnp.max(lens))

    def run_layers(sh, p, tok, ps, ln, lsts):
        q_pos = ps[:, None] + jnp.arange(C)
        x = sh.embed(tok, q_pos)
        valid = masking.chunk_valid(q_pos, ln, L)
        new_lsts = []
        for i in range(nl):
            x, nlst = _chunk_layer(sh, p[i], x, lsts[i], ps, valid)
            new_lsts.append(nlst)
        last = rows_at(x, jnp.clip(ln - 1 - ps, 0, C - 1))
        return last, new_lsts

    if jit:
        def body(shadow, p, st):
            tok, ps, ln, lsts = st
            return run_layers(get_suite(shadow), p, tok, ps, ln, lsts)

        state0 = (token, pos, lens, state)
        jl = jit_layer_for(pm, f"{pm.mode}_prefill_chunk", body,
                           pm.wp["layers"], state0)
        pool = pm.triple_pool()
        pool.reserve(jl.specs, steps=lookahead)
        triples = [pool.take(s) for s in jl.specs]
        comm.replay(jl.events, online_only=True)
        last, new_state = jl.fn(pm.wp["layers"], state0, pm.ks(),
                                triples)
    else:
        last, new_state = run_layers(suite, pm.wp["layers"], token, pos,
                                     lens, state)
    logits = chunk_head(pm, last, jit=jit) if final else None
    return logits, new_state


# =============================================================================
# paged share-domain KV cache (DESIGN.md §13): fixed-size pages of the
# amortized chunk state (opened values + persistent masks) owned by an
# engine-side free-list allocator; the jitted programs stay shape-static
# by gathering a per-slot page table into a dense view inside the tick
# =============================================================================

def init_page_pool(pm: PrivateModel, n_pages: int, page_size: int):
    """Per-layer paged chunk-state pools: ``ek``/``ev`` (public opened
    values) and ``bk``/``bv`` (persistent mask shares) of shape
    (n_pages, page_size, hk, dh).

    Physical page 0 is the SCRATCH page: it is never allocated, every
    unallocated page-table entry points at it, and every paged program
    re-zeroes it after its scatter — so a dense gather through a padded
    page table reads exact zeros wherever a slot owns no page, which is
    bit-identical to the dense chunk state's unwritten rows (zero share
    opened against zero mask)."""
    suite = get_suite(pm)
    _assert_servable(suite)
    if n_pages < 2:
        raise EngineConfigError(
            f"page pool needs the scratch page plus at least one "
            f"allocatable page, got n_pages={n_pages}")
    cfg = pm.cfg
    z = jnp.zeros((n_pages, page_size, cfg.num_kv_heads, cfg.dh),
                  ring.RING_DTYPE)
    return [{"ek": z, "ev": z,
             "bk": ShareTensor(z, z), "bv": ShareTensor(z, z)}
            for _ in range(cfg.num_layers)]


def _gather_pages(pool_l, pt):
    """Dense (B, nb*page, hk, dh) chunk-state view of one layer's page
    pool through the padded page table pt (B, nb) — a pure gather, so
    it traces into the jitted tick with pt as a data input (ONE program
    per (B, nb) regardless of which pages are live)."""
    B, nb = pt.shape

    def g(a):
        return a[pt].reshape(B, nb * a.shape[1], *a.shape[2:])
    return {"ek": g(pool_l["ek"]), "ev": g(pool_l["ev"]),
            "bk": ShareTensor(g(pool_l["bk"].s0), g(pool_l["bk"].s1)),
            "bv": ShareTensor(g(pool_l["bv"].s0), g(pool_l["bv"].s1))}


def _scatter_pages(pool_l, lst, pt):
    """Write a dense chunk-state view back through the page table and
    re-zero the scratch page.

    Duplicate page ids across slots (copy-on-write shared prefix pages,
    and every slot's scratch entries) receive IDENTICAL values — a
    chunk tick only rewrites rows at its own positions, and sharers by
    construction hold the same prefix rows — so the undefined winner of
    an XLA duplicate-index scatter is irrelevant.  The scratch page
    collects the dummy/padding slots' garbage writes and is zeroed
    last, restoring the all-zeros invariant the gather relies on."""
    B, nb = pt.shape
    P = pool_l["ek"].shape[1]

    def s(a, d):
        upd = d.reshape(B, nb, P, *d.shape[2:])
        return a.at[pt].set(upd).at[0].set(0)
    return {"ek": s(pool_l["ek"], lst["ek"]),
            "ev": s(pool_l["ev"], lst["ev"]),
            "bk": ShareTensor(s(pool_l["bk"].s0, lst["bk"].s0),
                              s(pool_l["bk"].s1, lst["bk"].s1)),
            "bv": ShareTensor(s(pool_l["bv"].s0, lst["bv"].s0),
                              s(pool_l["bv"].s1, lst["bv"].s1))}


def prefill_chunk_paged(pm: PrivateModel, pools, pt, pst, token, pos,
                        lens, jit: bool = False, lookahead: int = 4):
    """One BATCHED paged chunked-prefill tick: token (B, C) — the next
    C prompt tokens of every slot being prefilled (B is the full slot
    width; non-prefilling slots carry dummy tokens, pos 0, lens 1 and
    an all-scratch page-table row, so their garbage lands in the
    scratch page), pt (B, nb) page table, pst the per-layer per-slot
    π1 state (None entries for share-softmax suites), pos/lens (B,).

    Returns (last, new_pools): the gathered last-real-token hidden rows
    (every tick — only a slot's final tick feeds them to `chunk_head`)
    and the updated page pools.  The program is jit-keyed on
    (B, C, nb) only — pt, pos and lens are traced — so one compiled
    program serves every admission batch, prefix-hit offset and length
    mix; per-tick triple demand is the same multiset every tick
    (`TriplePool.reserve` keeps `lookahead` ticks in stock)."""
    suite = get_suite(pm)
    _assert_servable(suite)
    nl = pm.cfg.num_layers
    B, C = token.shape
    pt = jnp.asarray(pt, jnp.int32)
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    lens = jnp.broadcast_to(jnp.asarray(lens, jnp.int32), (B,))
    L = int(pt.shape[1]) * int(pools[0]["ek"].shape[1])
    if int(jnp.max(pos)) + C > L:
        raise ProtocolIntegrityError(
            f"chunk past paged cache: pos={pos}, C={C}, L={L}")

    def run_layers(sh, p, tok, pt_, ps, ln, pls, psts):
        q_pos = ps[:, None] + jnp.arange(C)
        x = sh.embed(tok, q_pos)
        valid = masking.chunk_valid(q_pos, ln, L)
        new_pls = []
        for i in range(nl):
            lst = dict(_gather_pages(pls[i], pt_), pi=psts[i])
            x, nlst = _chunk_layer(sh, p[i], x, lst, ps, valid)
            new_pls.append(_scatter_pages(pls[i], nlst, pt_))
        last = rows_at(x, jnp.clip(ln - 1 - ps, 0, C - 1))
        return last, new_pls

    if jit:
        def body(shadow, p, st):
            tok, pt_, ps, ln, pls, psts = st
            return run_layers(get_suite(shadow), p, tok, pt_, ps, ln,
                              pls, psts)

        state0 = (token, pt, pos, lens, pools, pst)
        jl = jit_layer_for(pm, f"{pm.mode}_prefill_paged", body,
                           pm.wp["layers"], state0)
        pool = pm.triple_pool()
        pool.reserve(jl.specs, steps=lookahead)
        triples = [pool.take(s) for s in jl.specs]
        comm.replay(jl.events, online_only=True)
        return jl.fn(pm.wp["layers"], state0, pm.ks(), triples)
    return run_layers(suite, pm.wp["layers"], token, pt, pos, lens,
                      pools, pst)


def decode_step_paged(pm: PrivateModel, pools, pt, pst, token, pos,
                      jit: bool = False, lookahead: int = 4):
    """One batched paged decode tick: the slot-decode flow run as a
    C=1 chunk against the paged amortized cache — the new K/V row gets
    a dealer mask and is opened once into its slot's page, both
    attention products run `matmul_opened` against the opened pages,
    and the softmax reuses the request's CACHED π1 (`softmax_chunk`) —
    the same per-request reveal surface as its chunked prefill
    (DESIGN.md §13), instead of the dense tick's fresh per-tick π1.
    Embedding, all layers and the adaptation head compile into ONE
    program per (B, nb); returns (logits (B,1,V), new_pools)."""
    suite = get_suite(pm)
    _assert_servable(suite)
    nl = pm.cfg.num_layers
    B, S = token.shape
    pt = jnp.asarray(pt, jnp.int32)
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    L = int(pt.shape[1]) * int(pools[0]["ek"].shape[1])
    if int(jnp.max(pos)) + S > L:
        raise ProtocolIntegrityError(
            f"decode past paged cache: pos={pos}, S={S}, L={L}")

    def run_layers(sh, p, tok, pt_, ps, pls, psts):
        q_pos = ps[:, None]
        x = sh.embed(tok, q_pos)
        valid = masking.slot_valid(q_pos, L)
        new_pls = []
        for i in range(nl):
            lst = dict(_gather_pages(pls[i], pt_), pi=psts[i])
            x, nlst = _chunk_layer(sh, p[i], x, lst, ps, valid)
            new_pls.append(_scatter_pages(pls[i], nlst, pt_))
        return sh.head(x), new_pls

    if jit:
        def body(shadow, p, st):
            tok, pt_, ps, pls, psts = st
            return run_layers(get_suite(shadow), p, tok, pt_, ps, pls,
                              psts)

        state0 = (token, pt, pos, pools, pst)
        jl = jit_layer_for(pm, f"{pm.mode}_decode_paged", body,
                           pm.wp["layers"], state0)
        pool = pm.triple_pool()
        pool.reserve(jl.specs, steps=lookahead)
        triples = [pool.take(s) for s in jl.specs]
        comm.replay(jl.events, online_only=True)
        return jl.fn(pm.wp["layers"], state0, pm.ks(), triples)
    return run_layers(suite, pm.wp["layers"], token, pt, pos, pools,
                      pst)


def _run_jit_decode_step(pm: PrivateModel, caches, token, pos,
                         lookahead: int = 4):
    """ONE jitted batched decode step: embedding, the whole layer
    stack against the slot caches, and the adaptation head compile
    into a single program per (batch, max_len) shape — a tick is one
    dispatch plus pool takes.  The shapes are padding-static, so one
    eval_shape trace under comm.capture() prices every future tick
    (replayed per tick, ledger bit-exact vs eager), and the triple
    demand is the same multiset every tick: TriplePool.reserve keeps
    `lookahead` ticks in stock with one constant-size vectorized
    generator per spec (DESIGN.md §7)."""
    nl = pm.cfg.num_layers

    def body(shadow, p, state):
        sh = get_suite(shadow)
        tok, ps, cks, cvs = state
        x = sh.embed(tok, ps[:, None])
        ks_, vs_ = [], []
        for i in range(nl):
            x, nc = _decode_layer(sh, p[i], x,
                                  {"k": cks[i], "v": cvs[i]}, ps)
            ks_.append(nc["k"])
            vs_.append(nc["v"])
        return sh.head(x), ks_, vs_

    state0 = (token, pos, [c["k"] for c in caches],
              [c["v"] for c in caches])
    jl = jit_layer_for(pm, f"{pm.mode}_decode_tick", body,
                       pm.wp["layers"], state0)
    pool = pm.triple_pool()
    pool.reserve(jl.specs, steps=lookahead)
    triples = [pool.take(s) for s in jl.specs]
    comm.replay(jl.events, online_only=True)
    logits, ks_, vs_ = jl.fn(pm.wp["layers"], state0, pm.ks(), triples)
    return logits, [{"k": k, "v": v} for k, v in zip(ks_, vs_)]


def decode_step(pm: PrivateModel, caches, token, pos,
                jit: bool = False, lookahead: int = 4):
    """One batched private decode step: token (B,1) next-token ids for B
    independent slots, pos int or (B,) per-slot absolute positions,
    caches as returned by `prefill` / `init_slot_caches` (padded,
    slot-stacked).  Returns (logits (B,1,V), updated caches)."""
    suite = get_suite(pm)
    _assert_servable(suite)
    B, S = token.shape
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    L = int(caches[0]["k"].shape[1])
    # dynamic_update_slice would silently clamp an out-of-range write
    # onto the previous token's K/V row — fail loudly instead
    if int(jnp.max(pos)) + S > L:
        raise ProtocolIntegrityError(
            f"decode past padded cache: pos={pos}, S={S}, max_len={L}")
    if jit:
        return _run_jit_decode_step(pm, caches, token, pos,
                                    lookahead=lookahead)
    x = suite.embed(token, pos[:, None])
    new_caches = []
    for i in range(pm.cfg.num_layers):
        x, nc = _decode_layer(suite, pm.wp["layers"][i], x, caches[i],
                              pos)
        new_caches.append(nc)
    return suite.head(x), new_caches
