"""ProtocolSuite: the per-mode protocol surface of private inference.

A *suite* bundles everything that differs between the PPTI families the
paper compares — how parameters are prepared, how a linear layer is
evaluated, which softmax/activation/norm protocol runs, and what the
cloud party P1 gets to observe — behind one small interface.  The
*executor* (``core.suites.executor``) owns everything that is the same
in every mode: the transformer residual skeleton, attention shapes
(incl. GQA), causal masking, the slot-stacked padded KV-cache
prefill/decode loop, and the jit/capture machinery.  A new protocol
drops in as a new suite; it inherits batched jitted serving for free.

Value domain: suites operate either on ``ShareTensor`` (centaur, smpc
and its nonlinear variants) or on plain float arrays (the permute
baseline).  The executor only manipulates values through reshape /
transpose / ``+`` / suite methods, all of which both domains support.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp

from .. import beaver, ring
from ..sharing import ShareTensor, share
from . import masking


class KeyStream:
    """Split-on-demand PRNG stream (one per PrivateModel)."""

    def __init__(self, key):
        self._key = key

    def __call__(self):
        self._key, k = jax.random.split(self._key)
        return k


@dataclass
class PrivateModel:
    """Prepared private model: config + per-mode parameters + randomness.

    ``exposed`` records what the cloud platform P1 actually observes per
    mode — the attack surface evaluated by benchmarks/privacy_attack.py.
    """

    cfg: Any
    mode: str
    perms: dict                      # named index-permutations
    wp: dict                         # prepared parameters
    ks: KeyStream
    dealer: Any                      # TripleDealer or TriplePool
    exposed: dict = field(default_factory=dict)
    pool: Any = None                 # lazily-built beaver.TriplePool
    jit_cache: dict = field(default_factory=dict)

    def expose(self, name, value):
        """Record an intermediate as seen by the cloud platform P1."""
        if name not in self.exposed:
            self.exposed[name] = value

    def triple_pool(self):
        if self.pool is None:
            # a pool built with use_pool=True is the model's dealer;
            # reuse it so jitted paths and eager paths draw from (and
            # bill) one offline phase
            self.pool = (self.dealer
                         if isinstance(self.dealer, beaver.TriplePool)
                         else beaver.TriplePool(self.ks()))
        return self.pool

    def suite(self) -> "ProtocolSuite":
        return get_suite(self)


def encrypt_tokens(pm: PrivateModel, tokens):
    """Client side: one-hot (raw ring ints, no scale) and share."""
    onehot = jax.nn.one_hot(tokens, pm.cfg.vocab_size,
                            dtype=ring.RING_DTYPE)
    return share(pm.ks(), onehot)


class ProtocolSuite:
    """Per-mode protocol operations, driven by the shared executor.

    Implementations hold no state of their own beyond the PrivateModel
    they wrap — a suite may capture only ``pm`` (params, key stream,
    dealer, permutations); everything tensor-valued flows through the
    method arguments so the executor can trace a suite body under
    ``jax.eval_shape`` / ``jax.jit`` (DESIGN.md §8 executor contract).
    """

    mode: str = "?"
    #: whether the eager path records P1-observable intermediates
    exposes: bool = False
    #: families this suite's prepared parameters / ops cover
    families: tuple = ()
    #: whether the executor may serve this suite's KV-cache decode path
    serves: bool = False

    def __init__(self, pm: PrivateModel):
        self.pm = pm

    # ---- convenience -------------------------------------------------------
    @property
    def cfg(self):
        return self.pm.cfg

    @property
    def dealer(self):
        return self.pm.dealer

    def ks(self):
        return self.pm.ks()

    def jittable(self) -> bool:
        """Uniform-layer stacks the §6 per-layer jit machinery covers."""
        return False

    def expose_value(self, name: str, x):
        """Record a P1-observable residual-stream value (no-op for
        suites whose protocol reveals nothing there)."""

    # ---- protocol surface (implemented per suite) --------------------------
    def embed(self, tokens, positions, expose: bool = False):
        raise NotImplementedError

    def linear(self, p, x):
        """One linear layer from a prepared param dict {"w", "b"}."""
        raise NotImplementedError

    def matmul(self, a, b):
        """Activation x activation product (attention scores / mixing)."""
        raise NotImplementedError

    def scale(self, x, c: float):
        """Multiply by a public float constant."""
        raise NotImplementedError

    def mask(self, scores, valid):
        """Kill invalid key columns ahead of the softmax (broadcasts)."""
        raise NotImplementedError

    def softmax_pair(self, scores, values, *, per_slot: bool,
                     expose: bool = False):
        """Mode softmax + the value-side permutation hook.

        Returns ``(probs, values')`` where centaur applies its fresh
        per-request (or per-slot, when ``per_slot``) sequence
        permutation π1 to both the revealed scores and the value rows;
        baseline suites return ``values`` untouched.
        """
        raise NotImplementedError

    def softmax_chunk(self, scores, pst):
        """Chunked-prefill softmax over rectangular (B,hk,g,C,L)
        prefill-against-cache scores, returning probabilities in
        NATURAL key-column order (the value side of the chunk path is
        an already-opened cache in natural order — DESIGN.md §10).

        ``pst`` is the per-layer state minted by `chunk_perm_state`:
        centaur permutes the revealed scores under the request's cached
        π1 and un-permutes the re-shared probabilities; share-softmax
        suites ignore it and stay in the share domain."""
        raise NotImplementedError(
            f"{self.mode} suite has no chunked-prefill softmax")

    def chunk_perm_state(self, B: int, L: int):
        """Per-request, per-layer permutation state for chunked prefill
        (billed once at prefill start; None where the mode's softmax
        reveals nothing and needs no permutation)."""
        return None

    def chunk_perm_identity(self, B: int, L: int):
        """Slot-width π1 registry init for the PAGED serving path: an
        inert (identity) per-slot permutation state that bills nothing
        — empty/dummy slots run under it and their outputs are
        discarded; a real request's rows are spliced in at admission
        via `chunk_perm_insert`.  None where `chunk_perm_state` is
        None (share-softmax suites need no state at all)."""
        return None

    def chunk_perm_insert(self, pst, idx: int, sub):
        """Write one freshly drawn request's `chunk_perm_state(1, L)`
        rows into slot ``idx`` of a slot-width state from
        `chunk_perm_identity` (party-local bookkeeping over material
        already billed by `chunk_perm_state`; records no events)."""
        return pst

    def act(self, x, expose: bool = False):
        """The MLP activation (mode-approximated where applicable)."""
        raise NotImplementedError

    def glu(self, gate, up, expose: bool = False):
        """SwiGLU combine act(gate) * up."""
        raise NotImplementedError

    def tanh(self, x):
        raise NotImplementedError

    def norm(self, p, x, tag: str = "layernorm", expose_as=None):
        raise NotImplementedError

    def rope(self, x, cos, sin):
        """Public per-position rotation (share-local where shared)."""
        raise NotImplementedError

    def head(self, x):
        """Adaptation head -> plaintext logits (client-side view)."""
        raise NotImplementedError

    # ---- family extensions (centaur-only today; see README mode matrix) ----
    def moe_ffn(self, p, x, expose: bool = False):
        raise NotImplementedError(
            f"{self.mode} suite does not implement MoE FFNs")

    def mamba_block(self, p, x, expose: bool = False):
        raise NotImplementedError(
            f"{self.mode} suite does not implement Mamba blocks")


def rope_on_shares(x: ShareTensor, cos, sin):
    """Public per-position rotation applied locally to each share."""
    half = x.shape[-1] // 2
    c = ring.encode(cos)[..., None, :]
    s = ring.encode(sin)[..., None, :]

    def rot(t):
        t1, t2 = t[..., :half], t[..., half:]
        r1 = ring.truncate(t1 * c - t2 * s)
        r2 = ring.truncate(t2 * c + t1 * s)
        return jnp.concatenate([r1, r2], -1)

    return ShareTensor(rot(x.s0), rot(x.s1))


class ShareSuite(ProtocolSuite):
    """Common share-domain operations (centaur and the smpc family):
    Beaver products, public-constant scaling, additive ring masking,
    and share-local RoPE are protocol-identical across these suites —
    as is the chunked-prefill cache protocol (open-once row masks +
    Beaver products against the opened cache, DESIGN.md §10)."""

    def matmul(self, a, b):
        return beaver.matmul(a, b, self.dealer)

    def scale(self, x, c: float):
        return x.mul_public(ring.encode(c))

    def mask(self, scores, valid):
        return scores + masking.ring_mask(valid)

    def rope(self, x, cos, sin):
        return rope_on_shares(x, cos, sin)

    # ---- chunked-prefill cache protocol (DESIGN.md §10) --------------------
    def rand_mask(self, shape):
        """Fresh dealer mask shares for newly written cache rows."""
        return self.dealer.mask_pair(shape)

    def open_rows(self, x, mask):
        """Open x - mask (each fresh row of the chunk cache is opened
        exactly once; later chunks reuse the public value)."""
        return beaver.open_rows(x, mask)

    def matmul_opened(self, x, f_open, b_mask):
        """Share x cache product where the cache side is already open
        against the persistent mask: only x's mask open crosses the
        wire."""
        return beaver.matmul_masked_f(x, f_open, b_mask, self.dealer)


def get_suite(pm: PrivateModel) -> ProtocolSuite:
    """Suite for pm.mode (smpc/mpcformer/secformer share one suite)."""
    from . import centaur, permute_suite, smpc
    if pm.mode == "centaur":
        return centaur.CentaurSuite(pm)
    if pm.mode in ("smpc", "mpcformer", "secformer"):
        return smpc.SmpcSuite(pm)
    if pm.mode == "permute":
        return permute_suite.PermuteSuite(pm)
    raise ValueError(f"unknown PPTI mode: {pm.mode!r}")


MODES = ("centaur", "smpc", "mpcformer", "secformer", "permute")
