"""PermuteSuite: the permutation-only STI baseline (Yuan et al.).

Plaintext compute on permuted weights and data — no shares, no Beaver
triples, no communication.  The suite operates on plain float arrays;
the executor drives it through the exact same skeleton as the share
suites, which is what lets the privacy benchmarks compare *identical*
computations that differ only in protocol.

This mode exists to reproduce the paper's Fig. 4 privacy failure: the
permutation cancels in QK^T, so O1 (and everything downstream) is
exposed in the clear — recorded via the exposure hooks and attacked by
benchmarks/privacy_attack.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import permute, ring
from . import masking
from .base import ProtocolSuite

P32 = jnp.float32


def _dec(t):
    return ring.decode(t, dtype=P32)


class PermuteSuite(ProtocolSuite):
    mode = "permute"
    exposes = True
    families = ("dense", "encoder")
    serves = False

    def expose_value(self, name, x):
        self.pm.expose(name, x)

    # ---- protocol surface --------------------------------------------------
    def embed(self, tokens, positions, expose: bool = False):
        pm = self.pm
        x = jnp.take(_dec(pm.wp["embed"]["tok"]), tokens, axis=0)
        if "pos" in pm.wp["embed"] and positions is not None:
            x = x + jnp.take(_dec(pm.wp["embed"]["pos"]), positions,
                             axis=0)
        if expose:
            pm.expose("XM", x)
        if "embed_norm" in pm.wp:
            x = self.norm(pm.wp["embed_norm"], x)
        return x

    def linear(self, p, x):
        y = x @ _dec(p["w"]).swapaxes(-1, -2)
        if p.get("b") is not None:
            y = y + _dec(p["b"])
        return y

    def matmul(self, a, b):
        return jnp.matmul(a, b)

    def scale(self, x, c: float):
        return x * c

    def mask(self, scores, valid):
        return jnp.where(valid, scores, -masking.MASK_MAGNITUDE)

    def softmax_pair(self, scores, values, *, per_slot: bool,
                     expose: bool = False):
        if expose:
            B = scores.shape[0]
            S, T = scores.shape[-2], scores.shape[-1]
            # THE leak: pi cancels in QK^T (paper §3 Motivation 2)
            self.pm.expose("O1", scores.reshape(B, -1, S, T))
        probs = jax.nn.softmax(scores, -1)
        if expose:
            B = probs.shape[0]
            S, T = probs.shape[-2], probs.shape[-1]
            self.pm.expose("O2", probs.reshape(B, -1, S, T))
        return probs, values

    def act(self, x, expose: bool = False):
        if expose:
            self.pm.expose("O5", x)
        if self.cfg.act == "silu":
            return jax.nn.silu(x)
        return jax.nn.gelu(x, approximate=False)

    def glu(self, gate, up, expose: bool = False):
        if expose:
            self.pm.expose("O5", gate)
        return self.act(gate) * up

    def tanh(self, x):
        return jnp.tanh(x)

    def norm(self, p, x, tag: str = "layernorm", expose_as=None):
        cfg = self.cfg
        mu = (x.mean(-1, keepdims=True)
              if cfg.norm_type == "layernorm" else 0.0)
        var = ((x - mu) ** 2).mean(-1, keepdims=True)
        y = (x - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = p["g"] * y + p.get("b", 0.0)
        if expose_as:
            # the STI baseline computes in the clear: P1 sees the
            # *normalized* residual stream (post-LN), unlike centaur
            # where only the pre-norm permuted reveal crosses the wire
            self.pm.expose(expose_as, y)
        return y

    def rope(self, x, cos, sin):
        half = x.shape[-1] // 2
        c = cos[..., None, :]
        s = sin[..., None, :]
        x1, x2 = x[..., :half], x[..., half:]
        return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], -1)

    def head(self, x):
        cfg, pm = self.cfg, self.pm
        if cfg.family == "encoder":
            pooled = jnp.tanh(x[:, 0, :] @ _dec(pm.wp["pooler"]["w"]).T
                              + _dec(pm.wp["pooler"]["b"]))
            return pooled @ _dec(pm.wp["classifier"]["w"]).T \
                + _dec(pm.wp["classifier"]["b"])
        x = self.norm(pm.wp["final_norm"], x)
        logits = x @ _dec(pm.wp["head"]["w"]).T
        return permute.apply_inv_perm(logits, pm.perms["v"], -1)
