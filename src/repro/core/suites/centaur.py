"""CentaurSuite: the paper's protocol (permuted plaintext weights,
secret-shared activations, permuted-state exact nonlinearities).

Linears are communication-free Pi_ScalMul against ring-encoded permuted
weights; share x share products are Beaver Pi_MatMul; softmax / GeLU /
LayerNorm convert to permuted state (Pi_PPP + reveal at P1) and back.
The permutation hooks the executor calls through ``softmax_pair`` are
where the per-request sequence permutation π1 lives.

Parameter preparation (paper §5.1 initialization phase) also lives
here: ``prepare_permuted`` builds Theta' for centaur *and* the permute
baseline (same permuted floats, ring-encoded).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import comm, nonlinear, permute, protocols, ring
from ..sharing import ShareTensor, reconstruct, share
from .base import ShareSuite, encrypt_tokens, rope_on_shares  # noqa: F401
# (rope_on_shares re-exported here for the pre-suite import path)

P32 = jnp.float32


def _act_fn(cfg):
    if cfg.act == "silu":
        return jax.nn.silu
    if cfg.act == "relu2":
        return lambda v: jnp.square(jax.nn.relu(v))
    return lambda v: jax.nn.gelu(v, approximate=False)


# =============================================================================
# parameter preparation (initialization phase, paper §5.1)
# =============================================================================

def enc_linear(w, b, p_in, p_out):
    """Permute then ring-encode a linear layer (weights (out, in))."""
    wp, bp = permute.permute_linear(jnp.asarray(w, P32),
                                    None if b is None else jnp.asarray(
                                        b, P32), p_in, p_out)
    return {"w": ring.encode(wp),
            "b": None if bp is None else ring.encode(bp)}


def norm_perm(p_norm, p):
    out = {"g": permute.apply_perm(jnp.asarray(p_norm["g"], P32), p)}
    if "b" in p_norm:
        out["b"] = permute.apply_perm(jnp.asarray(p_norm["b"], P32), p)
    return out


def mamba_channel_perms(cfg, ks):
    """Structured permutations for Pi_PPSSD: heads x headdim x state."""
    H, Pd, N, G = (cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state,
                   cfg.ssm_ngroups)
    pH = permute.gen_perm(ks(), H)
    pP = permute.gen_perm(ks(), Pd)
    pN = permute.gen_perm(ks(), N)
    # channel perm for the x part (H x P flattened)
    pXP = (pH[:, None] * Pd + pP[None, :]).reshape(-1)
    # B/C parts (G x N flattened); groups left in place (G is tiny/public)
    pGN = (jnp.arange(G)[:, None] * N + pN[None, :]).reshape(-1)
    return {"H": pH, "P": pP, "N": pN, "XP": pXP, "GN": pGN}


def prepare_permuted(cfg, params, perms):
    """Theta' = permuted parameters (centaur: ring-encoded for ScalMul;
    permute-mode uses the same permuted floats)."""
    pd = perms["d"]
    if cfg.family == "hybrid":
        return _prepare_hybrid_permuted(cfg, params, perms)
    wp = {"layers": []}
    emb = jnp.asarray(params["embed"]["tok"], P32)
    wp["embed"] = {"tok": ring.encode(permute.apply_perm(emb, pd, 1))}
    if "pos" in params["embed"]:
        wp["embed"]["pos"] = ring.encode(permute.apply_perm(
            jnp.asarray(params["embed"]["pos"], P32), pd, 1))
    if "embed_norm" in params:
        wp["embed_norm"] = norm_perm(params["embed_norm"], pd)

    for i in range(cfg.num_layers):
        p_l = jax.tree.map(lambda a: a[i], params["layers"])
        wp["layers"].append(_prepare_layer_permuted(cfg, p_l, perms))

    wp["final_norm"] = norm_perm(params["final_norm"], pd)
    if cfg.family == "encoder":
        wp["pooler"] = enc_linear(params["pooler"]["w"],
                                  params["pooler"]["b"], pd, pd)
        wp["classifier"] = enc_linear(params["classifier"]["w"],
                                      params["classifier"]["b"], pd,
                                      jnp.arange(2))
    else:
        head_w = (params["embed"]["tok"] if cfg.tie_embeddings
                  else params["head"]["w"])
        wp["head"] = enc_linear(head_w, None, pd, perms["v"])
    return wp


def _prepare_hybrid_permuted(cfg, params, perms):
    """Zamba2: per-layer Pi_PPSSD mamba blocks + ONE shared attention
    block (permuted once, applied every attn_every layers)."""
    pd = perms["d"]
    wp = {"layers": [], "embed": {"tok": ring.encode(permute.apply_perm(
        jnp.asarray(params["embed"]["tok"], P32), pd, 1))}}
    for i in range(cfg.num_layers):
        p_l = jax.tree.map(lambda a: a[i], params["mamba_layers"])
        wp["layers"].append({
            "ln1": norm_perm(p_l["ln"], pd),
            "mamba": _prepare_mamba_permuted(cfg, p_l["mamba"], perms),
        })
    sh = params["shared"]
    h, hk, dh = cfg.num_heads, cfg.num_kv_heads, cfg.dh
    pf = perms["ff"]
    wp["shared"] = {
        "ln1": norm_perm(sh["ln1"], pd),
        "ln2": norm_perm(sh["ln2"], pd),
        "attn": {
            "wq": enc_linear(sh["attn"]["wq"], None, pd,
                             jnp.arange(h * dh)),
            "wk": enc_linear(sh["attn"]["wk"], None, pd,
                             jnp.arange(hk * dh)),
            "wv": enc_linear(sh["attn"]["wv"], None, pd,
                             jnp.arange(hk * dh)),
            "wo": enc_linear(sh["attn"]["wo"], None,
                             jnp.arange(h * dh), pd),
        },
        "ffn": {
            "w_gate": enc_linear(sh["ffn"]["w_gate"], None, pd, pf),
            "w_up": enc_linear(sh["ffn"]["w_up"], None, pd, pf),
            "w_down": enc_linear(sh["ffn"]["w_down"], None, pf, pd),
        },
    }
    wp["final_norm"] = norm_perm(params["final_norm"], pd)
    wp["head"] = enc_linear(params["head"]["w"], None, pd, perms["v"])
    return wp


def _prepare_layer_permuted(cfg, p_l, perms):
    pd = perms["d"]
    out = {"ln1": norm_perm(p_l["ln"] if cfg.family == "ssm"
                            else p_l["ln1"], pd)}
    if cfg.family == "ssm":
        out["mamba"] = _prepare_mamba_permuted(cfg, p_l["mamba"], perms)
        return out
    out["ln2"] = norm_perm(p_l["ln2"], pd)
    a = p_l["attn"]
    if cfg.use_mla:
        # MLA: latent projections get their own perms; per-head Q/K/V
        # stay unpermuted (share-state through Pi_MatMul); the k_pe rows
        # of wkv_a stay unpermuted so RoPE can act on shares.
        pq, pkv = perms["q_lora"], perms["kv_lora"]
        h = cfg.num_heads
        qn, qr, vd = (cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                      cfg.v_head_dim)
        kv_rows = jnp.concatenate([pkv, cfg.kv_lora_rank
                                   + jnp.arange(qr)])
        out["attn"] = {
            "wq_a": enc_linear(a["wq_a"], None, pd, pq),
            "q_norm": norm_perm(a["q_norm"], pq),
            "wq_b": enc_linear(a["wq_b"], None, pq,
                               jnp.arange(h * (qn + qr))),
            "wkv_a": enc_linear(a["wkv_a"], None, pd, kv_rows),
            "kv_norm": norm_perm(a["kv_norm"], pkv),
            "wkv_b": enc_linear(a["wkv_b"], None, pkv,
                                jnp.arange(h * (qn + vd))),
            "wo": enc_linear(a["wo"], None, jnp.arange(h * vd), pd),
        }
    else:
        h, hk, dh = cfg.num_heads, cfg.num_kv_heads, cfg.dh
        ident_q = jnp.arange(h * dh)
        ident_kv = jnp.arange(hk * dh)
        out["attn"] = {
            "wq": enc_linear(a["wq"], None, pd, ident_q),
            "wk": enc_linear(a["wk"], None, pd, ident_kv),
            "wv": enc_linear(a["wv"], None, pd, ident_kv),
            "wo": enc_linear(a["wo"], None, ident_q, pd),
        }
    f = p_l["ffn"]
    pf = perms["ff"]
    if cfg.family == "moe":
        pe = perms["e"]
        out["ffn"] = {
            # router: feature-permuted in, expert-permuted out
            "router": enc_linear(f["router"], None, pd, pe),
            # per-expert weights: stored in permuted-expert order and
            # pre-transposed to (E, out, in) — the ScalMul convention —
            # so the expert path never transposes per call
            "w_gate": ring.encode(jnp.swapaxes(permute.apply_perm(
                permute.apply_perm(permute.apply_perm(
                    jnp.asarray(f["w_gate"], P32), pe, 0),
                    pd, 1), pf, 2), 1, 2)),
            "w_up": ring.encode(jnp.swapaxes(permute.apply_perm(
                permute.apply_perm(permute.apply_perm(
                    jnp.asarray(f["w_up"], P32), pe, 0),
                    pd, 1), pf, 2), 1, 2)),
            "w_down": ring.encode(jnp.swapaxes(permute.apply_perm(
                permute.apply_perm(permute.apply_perm(
                    jnp.asarray(f["w_down"], P32), pe, 0),
                    pf, 1), pd, 2), 1, 2)),
        }
        if cfg.n_shared_experts:
            psf = perms["shared_ff"]
            out["ffn"]["shared"] = {
                "w_gate": enc_linear(f["shared"]["w_gate"], None, pd, psf),
                "w_up": enc_linear(f["shared"]["w_up"], None, pd, psf),
                "w_down": enc_linear(f["shared"]["w_down"], None, psf, pd),
            }
    elif cfg.ffn_type == "swiglu":
        out["ffn"] = {
            "w_gate": enc_linear(f["w_gate"], None, pd, pf),
            "w_up": enc_linear(f["w_up"], None, pd, pf),
            "w_down": enc_linear(f["w_down"], None, pf, pd),
        }
    else:
        out["ffn"] = {
            "up": enc_linear(f["w_up"], f["b_up"], pd, pf),
            "down": enc_linear(f["w_down"], f["b_down"], pf, pd),
        }
    return out


def _prepare_mamba_permuted(cfg, m, perms):
    """Permute a Mamba2 block for Pi_PPSSD: in_proj output channels get
    the structured perm [z:XP | x:XP | B,C:GN | dt:H]; conv is depthwise
    so its channel axis permutes identically; P1 holds the mid-block
    weights in *plaintext permuted* form (it evaluates conv+SSD+gate in
    the clear on permuted data)."""
    pd = perms["d"]
    di = cfg.d_inner
    gn = cfg.ssm_ngroups * cfg.ssm_state
    pXP, pGN, pH = perms["XP"], perms["GN"], perms["H"]
    # output-channel permutation of in_proj rows
    rows = jnp.concatenate([
        pXP,                                   # z
        di + pXP,                              # x (conv part)
        2 * di + pGN,                          # B
        2 * di + gn + pGN,                     # C
        2 * di + 2 * gn + pH,                  # dt
    ])
    w_in = jnp.take(jnp.take(jnp.asarray(m["in_proj"], P32), rows, 0),
                    pd, 1)
    conv_rows = jnp.concatenate([pXP, di + pGN, di + gn + pGN])
    return {
        "in_proj": {"w": ring.encode(w_in), "b": None},
        # P1-side plaintext (permuted) mid-block weights
        "conv_w": jnp.take(jnp.asarray(m["conv_w"], P32), conv_rows, 0),
        "conv_b": jnp.take(jnp.asarray(m["conv_b"], P32), conv_rows, 0),
        "A_log": jnp.take(jnp.asarray(m["A_log"], P32), pH, 0),
        "D": jnp.take(jnp.asarray(m["D"], P32), pH, 0),
        "dt_bias": jnp.take(jnp.asarray(m["dt_bias"], P32), pH, 0),
        "gate_norm": norm_perm(m["gate_norm"], pXP),
        "out_proj": enc_linear(m["out_proj"], None, pXP, pd),
    }


# =============================================================================
# the suite
# =============================================================================

class CentaurSuite(ShareSuite):
    mode = "centaur"
    exposes = True
    families = ("dense", "encoder", "moe", "ssm", "hybrid")
    serves = True

    def jittable(self) -> bool:
        return self.cfg.family in ("dense", "encoder")

    # ---- helpers -----------------------------------------------------------
    def reveal(self, x: ShareTensor):
        return ring.decode(reconstruct(x), dtype=P32)

    def _apply2(self, fn, x: ShareTensor, y: ShareTensor, protocol: str):
        """Joint reveal of two permuted-state tensors, plaintext combine
        at P1, single reshare (beyond-paper: cheaper than a Beaver
        product for SwiGLU's silu(g) * u)."""
        xv = ring.decode(reconstruct(x), dtype=P32)
        yv = ring.decode(reconstruct(y), dtype=P32)
        out = fn(xv, yv)
        comm.record(protocol, rounds=2,
                    bits=(comm.numel(x.shape) + comm.numel(y.shape)
                          + comm.numel(out.shape)) * comm.RING_BITS)
        return share(self.ks(), ring.encode(out))

    def expose_value(self, name, x):
        self.pm.expose(name, self.reveal(x))

    # ---- protocol surface --------------------------------------------------
    def embed(self, tokens, positions, expose: bool = False):
        """Pi_PPEmbedding: one-hot ScalMul + (BERT) Pi_PPLN."""
        pm = self.pm
        xoh = encrypt_tokens(pm, tokens)
        with comm.tag("embedding"):
            x = protocols.scal_mul(jnp.swapaxes(pm.wp["embed"]["tok"],
                                                0, 1),
                                   xoh, rescale=False)
            if "pos" in pm.wp["embed"] and positions is not None:
                pos_emb = jnp.take(pm.wp["embed"]["pos"], positions,
                                   axis=0)
                x = x + pos_emb
            if "embed_norm" in pm.wp:
                x = self.norm(pm.wp["embed_norm"], x, tag="embedding")
        if expose:
            # first permuted-state reveal P1 observes (embedding output)
            pm.expose("XM", self.reveal(x))
        return x

    def linear(self, p, x):
        return protocols.linear(p["w"], p["b"], x)

    def softmax_pair(self, scores, values, *, per_slot: bool,
                     expose: bool = False):
        """Pi_PPP -> Pi_PPSM on scores; π1-permute the value rows so the
        Pi_MatMul against the revealed probabilities stays aligned.

        ``per_slot`` draws one INDEPENDENT fresh π1 per leading-axis
        slot (continuous-batching decode): a shared permutation would
        let P1 align revealed score columns across tenants' requests.
        """
        pm = self.pm
        T = int(scores.shape[-1])
        if per_slot:
            B = int(scores.shape[0])
            pi1 = jax.vmap(lambda k: permute.gen_perm(k, T))(
                jax.random.split(pm.ks(), B))              # (B,T)
            o1p = protocols.pp_permute_batched(scores, pi1, axis=-1)
            o2p = nonlinear.pp_softmax(o1p, pm.ks())
            vp = protocols.pp_permute_batched(values, pi1, axis=-2)
            return o2p, vp
        pi1 = permute.gen_perm(pm.ks(), T)
        o1p = protocols.pp_permute(scores, pi1, axis=-1)
        if expose:
            pm.expose("O1", self.reveal(o1p))
        o2p = nonlinear.pp_softmax(o1p, pm.ks())
        vp = protocols.pp_permute(values, pi1, axis=-2)
        return o2p, vp

    def chunk_perm_state(self, B: int, L: int):
        """One independent π1 per slot over the padded key axis, drawn
        ONCE per request per layer and reused by every chunk — the same
        leakage as the full-sequence prefill, which reveals the whole
        permuted score matrix of a layer under a single π1 (DESIGN.md
        §10).  The shared permutation-matrix material is billed here
        once; per-chunk `pp_permute_cached` calls bill data only."""
        pi = jax.vmap(lambda k: permute.gen_perm(k, L))(
            jax.random.split(self.ks(), B))                # (B, L)
        inv = jax.vmap(permute.inv_perm)(pi)
        protocols.pp_permute_setup(B, L)
        return {"pi": pi, "inv": inv}

    def chunk_perm_identity(self, B: int, L: int):
        """Slot-width π1 registry for the paged serving path: identity
        rows (no permutation material, bills nothing) that only ever
        cover empty/dummy slots — every admitted request overwrites its
        slot's rows with a fresh `chunk_perm_state(1, L)` draw before
        its first chunk tick."""
        # dtype matches permute.gen_perm draws so admission splices
        # are cast-free scatters
        eye = jnp.tile(permute.identity_perm(L)[None], (B, 1))
        return {"pi": eye, "inv": eye}

    def chunk_perm_insert(self, pst, idx: int, sub):
        return {"pi": pst["pi"].at[idx].set(sub["pi"][0]),
                "inv": pst["inv"].at[idx].set(sub["inv"][0])}

    def softmax_chunk(self, scores, pst):
        """Pi_PPP (cached π1) -> Pi_PPSM reveal -> inverse Pi_PPP, so
        the returned probabilities line up with the natural-order
        opened value cache.  P1 observes the π1-permuted masked
        rectangular score rows — the same reveal surface as full
        prefill, sliced chunk by chunk under the same π1."""
        o1p = protocols.pp_permute_cached(scores, pst["pi"], axis=-1)
        o2p = nonlinear.pp_softmax(o1p, self.ks())
        return protocols.pp_permute_cached(o2p, pst["inv"], axis=-1)

    def act(self, x, expose: bool = False):
        if expose:
            self.pm.expose("O5", self.reveal(x))
        proto = {"gelu": "ppgelu", "silu": "ppsilu",
                 "relu2": "pprelu2"}[self.cfg.act]
        return nonlinear.pp_apply(_act_fn(self.cfg), x, self.ks(),
                                  proto)

    def glu(self, gate, up, expose: bool = False):
        if expose:
            self.pm.expose("O5", self.reveal(gate))
        act = _act_fn(self.cfg)
        return self._apply2(lambda a, b: act(a) * b, gate, up, "ppsilu")

    def tanh(self, x):
        return nonlinear.pp_tanh(x, self.ks())

    def norm(self, p, x, tag: str = "layernorm", expose_as=None):
        cfg = self.cfg
        with comm.tag(tag):
            if expose_as:
                self.pm.expose(expose_as, self.reveal(x))
            if cfg.norm_type == "layernorm":
                return nonlinear.pp_layernorm(x, p["g"], p["b"],
                                              self.ks(),
                                              eps=cfg.norm_eps)
            return nonlinear.pp_rmsnorm(x, p["g"], self.ks(),
                                        eps=cfg.norm_eps)

    def head(self, x):
        """Adaptation layer + de-permutation (client-side view)."""
        cfg, pm = self.cfg, self.pm
        with comm.tag("adaptation"):
            if cfg.family == "encoder":
                pooled = protocols.linear(pm.wp["pooler"]["w"],
                                          pm.wp["pooler"]["b"],
                                          x[:, 0, :])
                t = self.tanh(pooled)
                out = protocols.linear(pm.wp["classifier"]["w"],
                                       pm.wp["classifier"]["b"], t)
                return self.reveal(out)
            # final_norm applies unconditionally for decoders, exactly
            # like the plaintext reference (models/layers.lm_head path)
            x = self.norm(pm.wp["final_norm"], x, tag="adaptation")
            logits_p = protocols.linear(pm.wp["head"]["w"], None, x)
        yv = self.reveal(logits_p)
        return permute.apply_inv_perm(yv, pm.perms["v"], -1)

    # ---- family extensions -------------------------------------------------
    def moe_ffn(self, p, x, expose: bool = False):
        """Beyond-paper MoE: expert-permuted router reveal + dispatch of
        *shares* by plaintext assignments; per-expert ScalMul FFNs.

        Simulation computes all experts on all tokens (tiny test
        configs) but bills communication for the dispatched tokens
        only."""
        pm, cfg = self.pm, self.cfg
        B, S, d = x.shape
        T = B * S
        E, K = cfg.n_routed_experts, cfg.top_k
        xf = x.reshape(T, d)
        with comm.tag("linear"):
            logits = protocols.scal_mul(p["router"]["w"], xf)
        with comm.tag("softmax"):
            gates, idx = nonlinear.pp_topk_router(logits, K)

        f = cfg.moe_d_ff
        act = _act_fn(cfg)
        with comm.muted():
            # (E, T, f) gate/up for all tokens — simulation-only shortcut
            def expert_out(e):
                # stacked expert weights are pre-transposed to
                # (E, out, in) at prep — index straight into ScalMul
                we_g = {"w": p["w_gate"][e], "b": None}
                we_u = {"w": p["w_up"][e], "b": None}
                we_d = {"w": p["w_down"][e], "b": None}
                g_ = self.linear(we_g, xf)
                u_ = self.linear(we_u, xf)
                hidden = self._apply2(lambda a, b: act(a) * b,
                                      g_, u_, "ppsilu")
                return self.linear(we_d, hidden)

            outs = [expert_out(e) for e in range(E)]
        # true cost: dispatched rows = T*K through one expert FFN each
        comm.record("ppsilu", rounds=2,
                    bits=(3 * T * K * f) * comm.RING_BITS)

        y0 = jnp.zeros((T, d), ring.RING_DTYPE)
        y = ShareTensor(y0, y0)
        for j in range(K):
            gate_j = ring.encode(gates[:, j:j + 1])
            sel = idx[:, j]
            s0 = jnp.stack([o.s0 for o in outs])[sel, jnp.arange(T)]
            s1 = jnp.stack([o.s1 for o in outs])[sel, jnp.arange(T)]
            y = y + ShareTensor(s0, s1).mul_public(gate_j)
        if cfg.n_shared_experts:
            sh = p["shared"]
            with comm.tag("linear"):
                g_ = self.linear(sh["w_gate"], xf)
                u_ = self.linear(sh["w_up"], xf)
            with comm.tag("gelu"):
                hidden = self._apply2(lambda a, b: act(a) * b,
                                      g_, u_, "ppsilu")
            with comm.tag("linear"):
                y = y + self.linear(sh["w_down"], hidden)
        return y.reshape(B, S, d)

    def mamba_block(self, p, x, expose: bool = False):
        """Pi_PPSSD: ScalMul in_proj -> reveal permuted zxbcdt -> P1 runs
        conv+SiLU+SSD+gated-norm in plaintext (channel-permuted weights)
        -> reshare -> ScalMul out_proj."""
        pm, cfg = self.pm, self.cfg
        B, S, _ = x.shape
        with comm.tag("linear"):
            zxbcdt = self.linear(p["in_proj"], x)

        def p1_block(v):
            import repro.models.mamba2 as mm
            z, xBC, dt_raw = mm._split_proj(cfg, v)
            dt = jax.nn.softplus(dt_raw + p["dt_bias"])
            xBC = jax.nn.silu(mm.causal_conv(p["conv_w"], p["conv_b"],
                                             xBC))
            xs, Bv, Cv = mm._split_xbc(cfg, xBC)
            H, Pd = cfg.ssm_nheads, cfg.ssm_headdim
            xs = xs.reshape(B, S, H, Pd)
            Bv = Bv.reshape(B, S, cfg.ssm_ngroups, cfg.ssm_state)
            Cv = Cv.reshape(B, S, cfg.ssm_ngroups, cfg.ssm_state)
            A = -jnp.exp(p["A_log"])
            y = mm.ssd_chunked(xs, dt, A, Bv, Cv, min(cfg.ssm_chunk, S))
            y = y + p["D"][None, None, :, None] * xs
            y = y.reshape(B, S, cfg.d_inner)
            y = y * jax.nn.silu(z)
            from repro.models.layers import rmsnorm
            return rmsnorm(p["gate_norm"], y, cfg.norm_eps)

        with comm.tag("ssm"):
            if expose:
                pm.expose("SSD_in", self.reveal(zxbcdt))
            y = nonlinear.pp_block(p1_block, zxbcdt, self.ks(), "ppssd")
        with comm.tag("linear"):
            return self.linear(p["out_proj"], y)
