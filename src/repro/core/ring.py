"""Fixed-point arithmetic over the integer ring Z_{2^64}.

CrypTen-compatible semantics (paper §2.2): floating-point values are scaled
by 2^FRAC_BITS and embedded in a 64-bit two's-complement ring.  Signed int64
wraparound *is* arithmetic mod 2^64, so no explicit modular reduction is
ever needed.  Local truncation (arithmetic right shift of each share)
carries CrypTen's +-1 LSB error model; see tests/test_ring.py property
tests for the validated bound.

On TPU the ring matmul is served by kernels/ring_matmul (int8-limb MXU
decomposition); on host we use native int64 matmuls (which wrap).
"""
from __future__ import annotations

import jax

# The ring requires 64-bit integers.  This must run before any int64 array
# is created; repro.core re-exports this module first for that reason.
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

RING_BITS = 64
RING_DTYPE = jnp.int64
FRAC_BITS = 16  # CrypTen default 16-bit fixed-point precision.


def encode(x, frac_bits: int = FRAC_BITS):
    """Float -> fixed-point ring element (round-to-nearest)."""
    scaled = jnp.asarray(x, jnp.float64) * (1 << frac_bits)
    return jnp.round(scaled).astype(RING_DTYPE)


def decode(x, frac_bits: int = FRAC_BITS, dtype=jnp.float32):
    """Fixed-point ring element -> float."""
    return (jnp.asarray(x, RING_DTYPE).astype(jnp.float64)
            / (1 << frac_bits)).astype(dtype)


def truncate(x, frac_bits: int = FRAC_BITS):
    """Arithmetic right shift: rescale after a fixed-point multiply.

    Applied locally per share (CrypTen local truncation): exact up to one
    LSB, with a wrap failure probability ~|x|/2^63 (negligible for model
    activations).
    """
    return jnp.right_shift(x, frac_bits)


def rand_ring(key, shape):
    """Uniform ring element (uniform over all 2^64 values)."""
    bits = jax.random.bits(key, shape, dtype=jnp.uint64)
    return jax.lax.bitcast_convert_type(bits, RING_DTYPE)


def ring_matmul(a, b):
    """a @ b in the ring (int64 wraparound == mod 2^64)."""
    return jnp.matmul(a, b)


def ring_mul(a, b):
    return a * b


def fixed_point_matmul(a, b, frac_bits: int = FRAC_BITS):
    """Matmul of two fixed-point operands, rescaled back to `frac_bits`."""
    return truncate(ring_matmul(a, b), frac_bits)
