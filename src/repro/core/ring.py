"""Fixed-point arithmetic over the integer ring Z_{2^64}.

CrypTen-compatible semantics (paper §2.2): floating-point values are scaled
by 2^FRAC_BITS and embedded in a 64-bit two's-complement ring.  Signed int64
wraparound *is* arithmetic mod 2^64, so no explicit modular reduction is
ever needed.  Local truncation (arithmetic right shift of each share)
carries CrypTen's +-1 LSB error model; see tests/test_ring.py property
tests for the validated bound.

On TPU the ring matmul is served by kernels/ring_matmul (int8-limb MXU
decomposition); on host we use native int64 matmuls (which wrap).
"""
from __future__ import annotations

import jax

# The ring requires 64-bit integers.  This must run before any int64 array
# is created; repro.core re-exports this module first for that reason.
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

RING_BITS = 64
RING_DTYPE = jnp.int64
FRAC_BITS = 16  # CrypTen default 16-bit fixed-point precision.


def encode(x, frac_bits: int = FRAC_BITS):
    """Float -> fixed-point ring element (round-to-nearest)."""
    scaled = jnp.asarray(x, jnp.float64) * (1 << frac_bits)
    return jnp.round(scaled).astype(RING_DTYPE)


def decode(x, frac_bits: int = FRAC_BITS, dtype=jnp.float32):
    """Fixed-point ring element -> float."""
    return (jnp.asarray(x, RING_DTYPE).astype(jnp.float64)
            / (1 << frac_bits)).astype(dtype)


def truncate(x, frac_bits: int = FRAC_BITS):
    """Arithmetic right shift: rescale after a fixed-point multiply.

    Applied locally per share (CrypTen local truncation): exact up to one
    LSB, with a wrap failure probability ~|x|/2^63 (negligible for model
    activations).
    """
    return jnp.right_shift(x, frac_bits)


def rand_ring(key, shape):
    """Uniform ring element (uniform over all 2^64 values)."""
    bits = jax.random.bits(key, shape, dtype=jnp.uint64)
    return jax.lax.bitcast_convert_type(bits, RING_DTYPE)


# ---- matmul backend selection ---------------------------------------------
# "auto": Pallas int8-digit MXU kernel (kernels.ops.ring64_matmul) on TPU,
# native int64 matmul elsewhere.  "pallas" forces the kernel (interpret
# mode off-TPU — slow, for parity testing), "host" forces jnp.matmul.
import os  # noqa: E402

MATMUL_BACKENDS = ("auto", "host", "pallas")
_matmul_backend = os.environ.get("REPRO_RING_MATMUL", "auto")

matmul_dispatches = 0  # GEMM-dispatch counter (benchmarks read deltas)


def set_matmul_backend(name: str):
    """Select the ring-GEMM backend; returns the previous one."""
    global _matmul_backend
    assert name in MATMUL_BACKENDS, name
    prev, _matmul_backend = _matmul_backend, name
    return prev


# leading-dim stacks up to this size (the fused Beaver online phase
# batches the two parties) unroll into per-slice Pallas kernel calls
_PALLAS_MAX_STACK = 4


def _tile_aligned(dims) -> bool:
    return all(d > 0 and d % min(128, d) == 0 for d in dims)


def _pallas_eligible(a, b) -> bool:
    """ring64_matmul serves 2-D operands whose dims fill whole MXU
    tiles (d <= 128 or d % 128 == 0), plus small equal leading-dim
    stacks of such operands (unrolled per slice); everything else
    stays on the host path."""
    if a.ndim == 2 and b.ndim == 2:
        return _tile_aligned((*a.shape, b.shape[-1]))
    if (a.ndim == 3 and b.ndim == 3
            and a.shape[0] == b.shape[0] <= _PALLAS_MAX_STACK):
        return _tile_aligned((*a.shape[1:], b.shape[-1]))
    return False


# f64-digit host GEMM: worth it above ~32^3 MACs; digit products must
# stay inside the 52-bit f64 mantissa: 4 * K * (2^16-1)^2 < 2^52.
_F64_MIN_MACS = 1 << 15
_F64_MAX_K = 1 << 17


def _f64_digit_eligible(a, b) -> bool:
    if a.ndim < 2 or b.ndim < 2:
        return False
    k = a.shape[-1]
    # total MACs include broadcast batch dims: batched attention GEMMs
    # (many heads/slots x tiny per-head trailing dims) are exactly the
    # shapes XLA's scalar int64 loop handles worst
    ba, bb = a.shape[:-2], b.shape[:-2]
    if len(bb) > len(ba):
        ba, bb = bb, ba
    bb = (1,) * (len(ba) - len(bb)) + tuple(bb)
    batch = 1
    for da, db in zip(ba, bb):
        batch *= max(da, db)
    return (k <= _F64_MAX_K
            and batch * a.shape[-2] * k * b.shape[-1] >= _F64_MIN_MACS)


def _f64_digit_matmul(a, b):
    """Exact mod-2^64 GEMM out of ten float64 GEMMs (DESIGN.md §3).

    XLA's CPU int64 matmul is a scalar loop (~45x slower than the f64
    BLAS path), so each operand is split into four 16-bit digit planes
    lifted to f64; digit products (< 2^32) summed over K <= 2^17 rows
    stay below the 2^52 mantissa, so every dot is exact.  Only pairs
    with i+j <= 3 survive mod 2^64 -> 10 GEMMs, recombined with integer
    shifts.  Bit-identical to the int64 reference on all ring values."""
    ua = jax.lax.bitcast_convert_type(a, jnp.uint64)
    ub = jax.lax.bitcast_convert_type(b, jnp.uint64)
    da = [jnp.right_shift(ua, 16 * i).astype(jnp.uint16)
          .astype(jnp.float64) for i in range(4)]
    db = [jnp.right_shift(ub, 16 * i).astype(jnp.uint16)
          .astype(jnp.float64) for i in range(4)]
    acc = None
    for p in range(4):
        s = None
        for i in range(p + 1):
            d = jnp.matmul(da[i], db[p - i])
            s = d if s is None else s + d
        v = jnp.left_shift(s.astype(jnp.uint64), 16 * p)
        acc = v if acc is None else acc + v
    return jax.lax.bitcast_convert_type(acc, jnp.int64)


def ring_matmul(a, b):
    """a @ b in the ring (int64 wraparound == mod 2^64).

    Backend routing (DESIGN.md §3): on TPU, 2-D tile-aligned operands
    hit the Pallas int8-digit MXU kernel; off-TPU, large shapes hit the
    exact f64-digit GEMM; small/ragged shapes use the native int64
    matmul (which wraps).  All paths are bit-identical."""
    global matmul_dispatches
    matmul_dispatches += 1
    backend = _matmul_backend
    on_tpu = jax.default_backend() == "tpu"
    if backend == "pallas" or (backend == "auto" and on_tpu):
        if _pallas_eligible(a, b):
            from repro.kernels import ops
            if a.ndim == 3:  # fused-online party stack: unroll slices
                return jnp.stack([ops.ring64_matmul(a[i], b[i])
                                  for i in range(a.shape[0])])
            return ops.ring64_matmul(a, b)
    if backend == "auto" and not on_tpu and _f64_digit_eligible(a, b):
        return _f64_digit_matmul(a, b)
    return jnp.matmul(a, b)


def ring_mul(a, b):
    return a * b


def fixed_point_matmul(a, b, frac_bits: int = FRAC_BITS):
    """Matmul of two fixed-point operands, rescaled back to `frac_bits`."""
    return truncate(ring_matmul(a, b), frac_bits)
