"""Random permutations for parameter protection (paper §2.3, §5.1).

Permutations are stored as index vectors and applied with gathers —
numerically identical to the paper's dense permutation matrices (tests
verify equivalence) but O(n) instead of O(n^2) memory / O(n^3) compute.
Dense 0/1 matrices are materialized only where the *protocol* requires a
secret-shared matrix (Pi_PPP exact mode, protocols.pp_permute_exact).

Convention: a permutation `p` applied to axis `ax` of X yields
Y[..., i, ...] = X[..., p[i], ...], i.e. Y = X @ Pi where
Pi[j, i] = 1 iff j == p[i] (column permutation for the last axis).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np


def gen_perm(key, n: int):
    return jax.random.permutation(key, n)


def identity_perm(n: int):
    return jnp.arange(n)


def inv_perm(p):
    inv = jnp.zeros_like(p).at[p].set(jnp.arange(p.shape[0]))
    return inv


def apply_perm(x, p, axis: int = -1):
    return jnp.take(x, p, axis=axis)


def apply_inv_perm(x, p, axis: int = -1):
    return jnp.take(x, inv_perm(p), axis=axis)


def perm_matrix(p, dtype=jnp.int64):
    """Dense Pi with X @ Pi == apply_perm(X, p, axis=-1)."""
    n = p.shape[0]
    m = jnp.zeros((n, n), dtype)
    return m.at[p, jnp.arange(n)].set(1)


def permute_linear(w, b, p_in, p_out):
    """Permute a linear layer y = x @ W^T + b, W: (out, in).

    With x' = apply_perm(x, p_in), the permuted weights
    W'[o', i'] = W[p_out[o'], p_in[i']] satisfy
    apply_perm(y, p_out) = x' @ W'^T + b'.
    """
    w = jnp.take(jnp.take(w, p_out, axis=0), p_in, axis=1)
    b = None if b is None else jnp.take(b, p_out, axis=0)
    return w, b


@dataclass
class PermSet:
    """The developer's permutation set Π = {π, π1, π2, ...} keyed by axis
    size.  π (d), π2 (k) protect parameters; π1 (n) protects the
    sequence axis of attention intermediates and is generated per-request.
    """
    perms: dict = field(default_factory=dict)
    key: jax.Array | None = None

    @classmethod
    def create(cls, key, sizes):
        perms = {}
        for n in sorted(set(int(s) for s in sizes)):
            key, sub = jax.random.split(key)
            perms[n] = gen_perm(sub, n)
        return cls(perms=perms, key=key)

    def perm(self, n: int):
        return self.perms[int(n)]

    def fresh(self, n: int):
        """Per-request permutation (π1 for the sequence axis)."""
        self.key, sub = jax.random.split(self.key)
        return gen_perm(sub, int(n))


def log2_brute_force_space(n: int) -> float:
    """log2(n!) — the paper's brute-force security measure (§2.3)."""
    return float(np.sum(np.log2(np.arange(1, n + 1))))
