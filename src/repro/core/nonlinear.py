"""Exact privacy-preserving nonlinearities via state conversion
(paper §5.2.1 Algorithms 1-3 and beyond-paper extensions).

Pattern (2 rounds, (in+out) * 64 bits): P0 sends its share of the
*permuted* input -> P1 reconstructs X·pi, evaluates the nonlinearity in
plaintext float32 (permutation-equivariant, so f(X·pi) = f(X)·pi) ->
re-shares the permuted output.

Beyond-paper extensions for the assigned architecture pool:
  * pp_topk_router  — MoE router under an expert-axis permutation.
  * pp_block        — generic permuted-plaintext block eval (Pi_PPSSD for
    Mamba2/Zamba2: channel permutation commutes with depthwise conv,
    SiLU and the per-channel SSD scan).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.runtime import faults

from . import comm, ring
from .sharing import ShareTensor, reconstruct, share

# Paranoid-mode envelope for P1's decoded permuted activations: honest
# protocol values are bounded by the additive-mask depth (activations
# themselves are O(1-100)); a corrupted share or ring wrap decodes to
# ~2^47 or NaN and trips the guard at the very next reveal-compute seam.
OPEN_ENVELOPE = 4.0 * 1e4  # 4 * masking.MASK_MAGNITUDE (import-cycle-free)


def pp_apply(fn, x: ShareTensor, key, protocol: str,
             frac_bits: int = ring.FRAC_BITS) -> ShareTensor:
    """Reveal-compute-reshare on a permuted-state shared tensor."""
    x_plain = ring.decode(reconstruct(x), frac_bits, jnp.float32)
    # integrity guard (engine integrity="paranoid"): P1 already holds
    # x_plain in the clear here, so the check is party-local and bills
    # nothing — the ledger-independence contract is untouched
    if faults.paranoid():
        faults.check_envelope(x_plain, OPEN_ENVELOPE, protocol)
    y = fn(x_plain)
    comm.record(protocol, rounds=2,
                bits=(comm.numel(x.shape) + comm.numel(y.shape))
                * comm.RING_BITS)
    return share(key, ring.encode(y, frac_bits))


# ---- paper protocols -------------------------------------------------------

def pp_softmax(x: ShareTensor, key, axis: int = -1,
               frac_bits: int = ring.FRAC_BITS) -> ShareTensor:
    return pp_apply(lambda v: jax.nn.softmax(v, axis=axis), x, key,
                    "ppsm", frac_bits)


def pp_gelu(x: ShareTensor, key,
            frac_bits: int = ring.FRAC_BITS) -> ShareTensor:
    return pp_apply(lambda v: jax.nn.gelu(v, approximate=False), x, key,
                    "ppgelu", frac_bits)


def pp_silu(x: ShareTensor, key,
            frac_bits: int = ring.FRAC_BITS) -> ShareTensor:
    return pp_apply(jax.nn.silu, x, key, "ppsilu", frac_bits)


def pp_tanh(x: ShareTensor, key,
            frac_bits: int = ring.FRAC_BITS) -> ShareTensor:
    return pp_apply(jnp.tanh, x, key, "pptanh", frac_bits)


def pp_layernorm(x: ShareTensor, gamma_p, beta_p, key,
                 eps: float = 1e-5,
                 frac_bits: int = ring.FRAC_BITS) -> ShareTensor:
    """Pi_PPLN with permuted affine params held in plaintext by P1.

    LayerNorm statistics are permutation-invariant along the feature
    axis, so LN(X pi; gamma pi, beta pi) = LN(X; gamma, beta) pi.
    """
    def fn(v):
        mu = jnp.mean(v, axis=-1, keepdims=True)
        var = jnp.var(v, axis=-1, keepdims=True)
        return gamma_p * (v - mu) * jax.lax.rsqrt(var + eps) + beta_p

    return pp_apply(fn, x, key, "ppln", frac_bits)


def pp_rmsnorm(x: ShareTensor, gamma_p, key, eps: float = 1e-6,
               frac_bits: int = ring.FRAC_BITS) -> ShareTensor:
    def fn(v):
        ms = jnp.mean(jnp.square(v), axis=-1, keepdims=True)
        return gamma_p * v * jax.lax.rsqrt(ms + eps)

    return pp_apply(fn, x, key, "ppln", frac_bits)


# ---- beyond-paper extensions ----------------------------------------------

def pp_topk_router(logits: ShareTensor, top_k: int, key=None,
                   frac_bits: int = ring.FRAC_BITS,
                   normalize: bool = True):
    """MoE router: reveal expert-permuted logits, compute gates/top-k in
    plaintext at P1.  Gates/assignments stay plaintext (they drive
    plaintext gather/scatter of shares; expert identity is protected by
    the expert-axis permutation pi_e).  1 round, numel * 64 bits.
    """
    comm.record("pptopk", rounds=1,
                bits=comm.numel(logits.shape) * comm.RING_BITS)
    lp = ring.decode(reconstruct(logits), frac_bits, jnp.float32)
    probs = jax.nn.softmax(lp, axis=-1)
    gates, idx = jax.lax.top_k(probs, top_k)
    if normalize:
        gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    return gates, idx


def pp_block(fn, x: ShareTensor, key, protocol: str = "ppblock",
             frac_bits: int = ring.FRAC_BITS) -> ShareTensor:
    """Generic permuted-plaintext block (Pi_PPSSD for SSM blocks):
    reveal channel-permuted input, run `fn` (conv + SiLU + SSD scan +
    gating, all channel-permutation-equivariant) in plaintext, re-share.
    """
    return pp_apply(fn, x, key, protocol, frac_bits)
