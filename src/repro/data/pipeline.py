"""Deterministic synthetic data pipeline.

Token streams are generated from a counter-mode PRNG keyed by
(seed, step) so any host can materialize exactly its own slice of the
global batch — no coordination, perfectly resumable (the checkpoint
stores only the step counter), and identical across restarts/elastic
reshards.  This is the standard pattern for synthetic-data scale tests;
swapping in a real tokenized corpus only changes `_tokens_for_step`.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


def _tokens_for_step(cfg: ModelConfig, batch: int, seq: int, step: int,
                     seed: int = 0):
    key = jax.random.fold_in(jax.random.key(seed), step)
    # low-entropy structured stream (repeating n-grams) so tiny models
    # can actually learn it in examples/train_lm.py
    base = jax.random.randint(key, (batch, seq), 0,
                              max(cfg.vocab_size // 4, 2))
    pattern = jnp.arange(seq) % 17
    return (base + pattern[None, :]) % cfg.vocab_size


def make_batch(cfg: ModelConfig, batch: int, seq: int, step: int = 0,
               seed: int = 0, kind: str = "train"):
    """Concrete synthetic batch matching launch/specs.input_specs."""
    toks = _tokens_for_step(cfg, batch, seq, step, seed)
    out = {}
    if cfg.family == "encdec":
        dec = max(seq // cfg.decoder_ratio, 8)
        key = jax.random.fold_in(jax.random.key(seed + 1), step)
        out["embeds"] = jax.random.normal(key, (batch, seq, cfg.d_model),
                                          jnp.float32).astype(cfg.dtype)
        out["tokens"] = toks[:, :dec]
        if kind == "train":
            out["labels"] = jnp.roll(out["tokens"], -1, axis=-1)
        return out
    if cfg.input_kind == "embeddings":
        key = jax.random.fold_in(jax.random.key(seed + 1), step)
        out["embeds"] = jax.random.normal(key, (batch, seq, cfg.d_model),
                                          jnp.float32).astype(cfg.dtype)
        if cfg.mrope_sections:
            pos = jnp.arange(seq)[None, :].repeat(batch, 0)
            out["positions"] = jnp.stack([pos, pos // 4, pos % 4])
        if kind == "train":
            out["labels"] = jnp.roll(toks, -1, axis=-1)
        return out
    out["tokens"] = toks
    if kind == "train":
        out["labels"] = jnp.roll(toks, -1, axis=-1)
    return out


@dataclass
class DataPipeline:
    """Per-host view of the global batch, resumable by construction."""
    cfg: ModelConfig
    global_batch: int
    seq_len: int
    host_index: int = 0
    host_count: int = 1
    seed: int = 0
    step: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.host_count == 0
        return self.global_batch // self.host_count

    def resume(self, step: int):
        self.step = step

    def __iter__(self):
        return self

    def __next__(self):
        full = make_batch(self.cfg, self.global_batch, self.seq_len,
                          self.step, self.seed)
        lo = self.host_index * self.host_batch
        hi = lo + self.host_batch
        self.step += 1
        return jax.tree.map(
            lambda a: a[..., lo:hi, :] if a.ndim == 3 and
            a.shape[0] == 3 else a[lo:hi], full)
