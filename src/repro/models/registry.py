"""Family dispatch: one uniform functional API over every architecture."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

from . import ssm_lm, transformer, whisper, zamba2
from .config import ModelConfig


@dataclass(frozen=True)
class ModelApi:
    init_params: Callable
    train_loss: Callable
    prefill: Callable
    decode_step: Callable
    init_cache: Callable | None = None
    forward: Callable | None = None


_TRANSFORMER = ModelApi(
    init_params=transformer.init_params,
    train_loss=transformer.train_loss,
    prefill=transformer.prefill,
    decode_step=transformer.decode_step,
    init_cache=transformer.init_cache,
    forward=transformer.forward,
)

_APIS = {
    "dense": _TRANSFORMER,
    "moe": _TRANSFORMER,
    "encoder": _TRANSFORMER,
    "ssm": ModelApi(ssm_lm.init_params, ssm_lm.train_loss, ssm_lm.prefill,
                    ssm_lm.decode_step, ssm_lm.init_cache, ssm_lm.forward),
    "hybrid": ModelApi(zamba2.init_params, zamba2.train_loss,
                       zamba2.prefill, zamba2.decode_step,
                       zamba2.init_cache, zamba2.forward),
    "encdec": ModelApi(whisper.init_params, whisper.train_loss,
                       whisper.prefill, whisper.decode_step),
}


def get_api(cfg: ModelConfig) -> ModelApi:
    return _APIS[cfg.family]
