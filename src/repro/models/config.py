"""Unified model configuration covering the assigned architecture pool.

One frozen dataclass parameterizes every family: dense decoder LMs
(llama3 / minitron / coder / smollm), fine-grained MoE (deepseek-moe,
deepseek-v2 with MLA), VLM backbone (qwen2-vl, M-RoPE), enc-dec audio
backbone (whisper), SSM (mamba2), hybrid (zamba2), plus the paper's own
BERT / GPT-2 models.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax.numpy as jnp


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | encoder
    num_layers: int
    d_model: int
    num_heads: int = 0
    num_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 32000
    head_dim: int = 0           # 0 -> d_model // num_heads

    # block flavour
    norm_type: str = "rmsnorm"   # rmsnorm | layernorm
    act: str = "silu"            # silu | gelu
    ffn_type: str = "swiglu"     # swiglu | mlp
    pos_embed: str = "rope"      # rope | learned | none
    causal: bool = True
    prenorm: bool = True         # False: post-LN (BERT)
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    mrope_sections: tuple = ()   # qwen2-vl M-RoPE (t, h, w) half-dim split

    # MoE
    n_routed_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.001

    # MLA (deepseek-v2)
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # SSM (mamba2 / zamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    conv_kernel: int = 4
    ssm_chunk: int = 256
    attn_every: int = 0          # zamba2: shared attn block cadence

    # enc-dec (whisper)
    encoder_layers: int = 0
    decoder_ratio: int = 8       # dec_len = seq_len // ratio for shapes

    # inputs
    input_kind: str = "tokens"   # tokens | embeddings (vlm/audio stubs)

    # numerics / training
    dtype_str: str = "bfloat16"
    max_seq_len: int = 1 << 20
    norm_eps: float = 1e-5
    remat: str = "full"          # full | dots | none
    # §Perf hillclimb levers (baseline values first)
    attention_impl: str = "naive"   # naive | flash (online-softmax blocks)
    flash_block: int = 512
    moe_shard: str = "auto"         # auto | ep (explicit expert sharding)
    moe_rank_impl: str = "cumsum"   # cumsum | sort (O(T*K) dispatch)
    scores_dtype: str = "float32"   # float32 | bfloat16 score matmuls

    @property
    def dtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype_str]

    @property
    def dh(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def q_groups(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic state: SSM / hybrid archs run long_500k."""
        return self.family in ("ssm", "hybrid")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter count (for roofline MODEL_FLOPS = 6 N D) -------------
    def param_count(self, active_only: bool = False) -> int:
        d, L = self.d_model, self.num_layers
        n = self.vocab_size * d  # embedding
        if not self.tie_embeddings and self.family != "encoder":
            n += self.vocab_size * d
        if self.pos_embed == "learned":
            n += 4096 * d

        def attn_params() -> int:
            if self.use_mla:
                q = (d * self.q_lora_rank + self.q_lora_rank * self.num_heads
                     * (self.qk_nope_head_dim + self.qk_rope_head_dim))
                kv = (d * (self.kv_lora_rank + self.qk_rope_head_dim)
                      + self.kv_lora_rank * self.num_heads
                      * (self.qk_nope_head_dim + self.v_head_dim))
                o = self.num_heads * self.v_head_dim * d
                return q + kv + o
            dh = self.dh
            return (d * self.num_heads * dh + 2 * d * self.num_kv_heads * dh
                    + self.num_heads * dh * d)

        def ffn_params(dff: int) -> int:
            mult = 3 if self.ffn_type == "swiglu" else 2
            return mult * d * dff

        def moe_params(active: bool) -> int:
            routed = self.top_k if active else self.n_routed_experts
            n = routed * ffn_params(self.moe_d_ff)
            n += self.n_shared_experts * ffn_params(self.moe_d_ff)
            n += d * self.n_routed_experts  # router
            return n

        def mamba_params() -> int:
            di, G, N, H = (self.d_inner, self.ssm_ngroups, self.ssm_state,
                           self.ssm_nheads)
            in_p = d * (2 * di + 2 * G * N + H)
            conv = (di + 2 * G * N) * self.conv_kernel
            out_p = di * d
            return in_p + conv + out_p + 3 * H + di

        if self.family in ("dense", "encoder"):
            per = attn_params() + ffn_params(self.d_ff)
            n += L * (per + 2 * d)
        elif self.family == "moe":
            per = attn_params() + moe_params(active_only)
            n += L * (per + 2 * d)
        elif self.family == "ssm":
            n += L * (mamba_params() + d)
        elif self.family == "hybrid":
            n += L * (mamba_params() + d)
            n_attn = (L + self.attn_every - 1) // self.attn_every
            # one shared block's weights, applied n_attn times
            n += attn_params() + ffn_params(self.d_ff) + 2 * d
        elif self.family == "encdec":
            enc = self.encoder_layers * (attn_params()
                                         + ffn_params(self.d_ff) + 2 * d)
            dec = L * (2 * attn_params() + ffn_params(self.d_ff) + 3 * d)
            n += enc + dec
        n += d  # final norm
        return int(n)

    def flops_per_token(self, training: bool = False) -> float:
        """MODEL_FLOPS/token: 2*N_active (fwd) or 6*N_active (train)."""
        n = self.param_count(active_only=True)
        # embeddings are lookups, not matmuls
        n -= self.vocab_size * self.d_model
        return (6.0 if training else 2.0) * n


@dataclass(frozen=True)
class ShapeCell:
    """One assigned (arch x input-shape) dry-run cell."""
    name: str          # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}
