"""Shared plaintext building blocks: norms, RoPE/M-RoPE, GQA and MLA
attention (with KV caches), SwiGLU/MLP FFN, capacity-based MoE.

All functions are pure; params are plain dicts of arrays.  Matmuls run in
the config dtype with f32 accumulation; norms and softmax in f32.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from . import shard_ctx
from .config import ModelConfig

P32 = jnp.float32


def _dot(x, w):
    """x @ w^T with f32 accumulation."""
    return jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (w.ndim - 1,)), ((), ())),
        preferred_element_type=P32).astype(x.dtype)


def dense(p, x):
    y = _dot(x, p["w"])
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# ---- norms ------------------------------------------------------------------

def rmsnorm(p, x, eps):
    xf = x.astype(P32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (p["g"].astype(P32) * xf * jax.lax.rsqrt(ms + eps)).astype(x.dtype)


def layernorm(p, x, eps):
    xf = x.astype(P32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (p["g"].astype(P32) * y + p["b"].astype(P32)).astype(x.dtype)


def norm(cfg: ModelConfig, p, x):
    fn = rmsnorm if cfg.norm_type == "rmsnorm" else layernorm
    return fn(p, x, cfg.norm_eps)


def init_norm(cfg: ModelConfig, d=None):
    d = d or cfg.d_model
    p = {"g": jnp.ones((d,), P32)}
    if cfg.norm_type == "layernorm":
        p["b"] = jnp.zeros((d,), P32)
    return p


# ---- rotary embeddings ------------------------------------------------------

def rope_freqs(cfg: ModelConfig, positions, dh: int):
    """positions: (..., S) int -> cos/sin (..., S, dh//2) f32."""
    half = dh // 2
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(half, dtype=P32) / half))
    ang = positions[..., None].astype(P32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, dh); cos/sin: (B, S, half) or (B, S, H, half)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == x.ndim - 1:
        cos, sin = cos[..., None, :], sin[..., None, :]
    xf1, xf2 = x1.astype(P32), x2.astype(P32)
    return jnp.concatenate([xf1 * cos - xf2 * sin,
                            xf2 * cos + xf1 * sin], -1).astype(x.dtype)


def mrope_freqs(cfg: ModelConfig, position_ids, dh: int):
    """Qwen2-VL M-RoPE: position_ids (3, B, S) — temporal/height/width
    streams; cfg.mrope_sections splits the half-dim between streams."""
    half = dh // 2
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(half, dtype=P32) / half))
    ang = position_ids[..., None].astype(P32) * inv      # (3, B, S, half)
    idx = jnp.repeat(jnp.arange(3), jnp.asarray(cfg.mrope_sections),
                     total_repeat_length=half)           # stream per dim
    sel = jax.nn.one_hot(idx, 3, dtype=P32)              # (half, 3)
    ang_sel = jnp.einsum("tbsh,ht->bsh", ang, sel)
    return jnp.cos(ang_sel), jnp.sin(ang_sel)


# ---- attention --------------------------------------------------------------

def init_attention(cfg: ModelConfig, key):
    d, h, hk, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.dh
    ks = jax.random.split(key, 4)
    sc = d ** -0.5
    mk = lambda k, o, i: (jax.random.normal(k, (o, i), P32) * sc  # noqa: E731
                          ).astype(cfg.dtype)
    return {
        "wq": mk(ks[0], h * dh, d),
        "wk": mk(ks[1], hk * dh, d),
        "wv": mk(ks[2], hk * dh, d),
        "wo": mk(ks[3], d, h * dh),
    }


def _sdpa(q, k, v, mask, dh):
    """q: (B,Hk,G,S,dh), k/v: (B,Hk,T,dh), mask: (B,1,1,S,T) or None."""
    scores = jnp.einsum("bhgsd,bhtd->bhgst", q, k,
                        preferred_element_type=P32) / jnp.sqrt(
                            jnp.asarray(dh, P32))
    if mask is not None:
        scores = jnp.where(mask, scores, jnp.finfo(P32).min)
    probs = jax.nn.softmax(scores.astype(P32), axis=-1)
    return jnp.einsum("bhgst,bhtd->bhgsd", probs.astype(v.dtype), v)


def _sdpa_flash(q, k, v, dh, *, q_offset, kv_len, causal, block: int,
                score_dtype=P32):
    """Online-softmax attention: lax.scan over KV blocks so the (S, T)
    score matrix never materializes in HBM (§Perf lever; the Pallas
    kernels/flash_attention.py is the per-core TPU realization — this is
    its GSPMD-compatible whole-array form).

    q: (B,Hk,G,S,dh); k/v: (B,Hk,T,dh).  `kv_len` masks cache tail;
    `q_offset` is the absolute position of q[0] for causal masking."""
    B, Hk, G, S, _ = q.shape
    T = k.shape[2]
    blk = block
    while T % blk:
        blk //= 2
    nb = T // blk
    qf = q.astype(score_dtype) / jnp.sqrt(jnp.asarray(dh, score_dtype))
    q_pos = q_offset + jnp.arange(S)

    def body(carry, i):
        m_prev, l_prev, acc = carry
        kb = jax.lax.dynamic_slice_in_dim(k, i * blk, blk, 2
                                          ).astype(score_dtype)
        vb = jax.lax.dynamic_slice_in_dim(v, i * blk, blk, 2).astype(P32)
        s = jnp.einsum("bhgsd,bhtd->bhgst", qf, kb,
                       preferred_element_type=score_dtype)
        k_pos = i * blk + jnp.arange(blk)
        valid = k_pos[None, :] < kv_len
        if causal:
            valid = valid & (k_pos[None, :] <= q_pos[:, None])
        s = jnp.where(valid[None, None, None], s, -1e30)
        m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, -1, keepdims=True)
        acc = acc * alpha + jnp.einsum("bhgst,bhtd->bhgsd", p, vb)
        return (m_new, l_new, acc), None

    init = (jnp.full((B, Hk, G, S, 1), -1e30, P32),
            jnp.zeros((B, Hk, G, S, 1), P32),
            jnp.zeros((B, Hk, G, S, v.shape[-1]), P32))
    (m, l, acc), _ = jax.lax.scan(body, init, jnp.arange(nb))
    return (acc / jnp.maximum(l, 1e-30)).astype(v.dtype)


def attention(cfg: ModelConfig, p, x, *, positions, cache=None,
              cache_pos=None, rope_cs=None):
    """GQA attention.  Training/prefill: cache=None or write-through.
    Decode: x is (B, 1, d), cache holds (B, Hk, T, dh) K/V.

    Returns (out, new_cache)."""
    B, S, _ = x.shape
    h, hk, dh, g = cfg.num_heads, cfg.num_kv_heads, cfg.dh, cfg.q_groups
    q = dense({"w": p["wq"]}, x).reshape(B, S, hk, g, dh)
    k = dense({"w": p["wk"]}, x).reshape(B, S, hk, dh)
    v = dense({"w": p["wv"]}, x).reshape(B, S, hk, dh)

    if cfg.pos_embed == "rope":
        if rope_cs is None:
            rope_cs = rope_freqs(cfg, positions, dh)
        cos, sin = rope_cs
        q = apply_rope(q.reshape(B, S, hk * g, dh), cos, sin
                       ).reshape(B, S, hk, g, dh)
        k = apply_rope(k, cos, sin)

    # §Perf it1: shard attention over kv-heads, then query groups, then
    # the query-sequence axis — NEVER the dh contraction (sharding dh
    # turns every score matmul into an (S,T)-sized all-reduce, the
    # dominant baseline collective for kv_heads < TP-degree archs)
    q = shard_ctx.shard(q.transpose(0, 2, 3, 1, 4), model_axes=(1, 2, 3),
                        batch_axis=0)                     # (B,hk,g,S,dh)
    k = shard_ctx.shard(k.transpose(0, 2, 1, 3), model_axes=(1,),
                        batch_axis=0)                     # (B,hk,S,dh)
    v = shard_ctx.shard(v.transpose(0, 2, 1, 3), model_axes=(1,),
                        batch_axis=0)

    if cache is not None:
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(
            cache["k"].dtype), (0, 0, cache_pos, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(
            cache["v"].dtype), (0, 0, cache_pos, 0))
        new_cache = {"k": ck, "v": cv}
        # flash pays per-block overheads; a single decode query row is
        # strictly cheaper through the fused naive path
        if cfg.attention_impl == "flash" and S > 1:
            out = _sdpa_flash(q, ck.astype(x.dtype), cv.astype(x.dtype),
                              dh, q_offset=cache_pos,
                              kv_len=cache_pos + S, causal=True,
                              block=cfg.flash_block,
                              score_dtype=jnp.dtype(cfg.scores_dtype))
        else:
            T = ck.shape[2]
            kv_pos = jnp.arange(T)
            # valid = written positions; causal within the new block
            q_pos = cache_pos + jnp.arange(S)
            mask = (kv_pos[None, :] <= q_pos[:, None])[None, None, None]
            out = _sdpa(q, ck.astype(x.dtype), cv.astype(x.dtype), mask,
                        dh)
    else:
        new_cache = None
        if cfg.attention_impl == "flash" and S > 1:
            out = _sdpa_flash(q, k, v, dh, q_offset=0, kv_len=S,
                              causal=cfg.causal, block=cfg.flash_block,
                              score_dtype=jnp.dtype(cfg.scores_dtype))
        elif cfg.causal:
            q_pos = jnp.arange(S)
            mask = (q_pos[None, :] <= q_pos[:, None])[None, None, None]
            out = _sdpa(q, k, v, mask, dh)
        else:
            out = _sdpa(q, k, v, None, dh)

    out = out.transpose(0, 3, 1, 2, 4).reshape(B, S, h * dh)
    return dense({"w": p["wo"]}, out), new_cache


def init_attention_cache(cfg: ModelConfig, batch, max_len, dtype):
    shp = (batch, cfg.num_kv_heads, max_len, cfg.dh)
    return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}


# ---- MLA (deepseek-v2) ------------------------------------------------------

def init_mla(cfg: ModelConfig, key):
    d, h = cfg.d_model, cfg.num_heads
    qn, qr, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    qlr, kvlr = cfg.q_lora_rank, cfg.kv_lora_rank
    ks = jax.random.split(key, 6)
    sc = d ** -0.5
    mk = lambda k, o, i: (jax.random.normal(k, (o, i), P32) * sc  # noqa: E731
                          ).astype(cfg.dtype)
    return {
        "wq_a": mk(ks[0], qlr, d),
        "q_norm": init_norm(cfg, qlr),
        "wq_b": mk(ks[1], h * (qn + qr), qlr),
        "wkv_a": mk(ks[2], kvlr + qr, d),
        "kv_norm": init_norm(cfg, kvlr),
        "wkv_b": mk(ks[3], h * (qn + vd), kvlr),
        "wo": mk(ks[4], d, h * vd),
    }


def mla_attention(cfg: ModelConfig, p, x, *, positions, cache=None,
                  cache_pos=None):
    """Multi-head Latent Attention with compressed-KV cache.

    Cache layout: {"ckv": (B, T, kv_lora), "kpe": (B, T, qr)} — the MLA
    memory saving (latent cached, K/V up-projected on use).
    Returns (out, new_cache)."""
    B, S, _ = x.shape
    h = cfg.num_heads
    qn, qr, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim

    q = dense({"w": p["wq_b"]},
              norm(cfg, p["q_norm"], dense({"w": p["wq_a"]}, x)))
    q = q.reshape(B, S, h, qn + qr)
    q_nope, q_pe = q[..., :qn], q[..., qn:]

    kv_a = dense({"w": p["wkv_a"]}, x)                   # (B,S,kvlr+qr)
    ckv = norm(cfg, p["kv_norm"], kv_a[..., :cfg.kv_lora_rank])
    k_pe = kv_a[..., cfg.kv_lora_rank:]                  # (B,S,qr) shared

    cos, sin = rope_freqs(cfg, positions, qr)
    q_pe = apply_rope(q_pe, cos, sin)
    k_pe = apply_rope(k_pe[:, :, None, :], cos, sin)[:, :, 0, :]

    if cache is not None:
        ckv_full = jax.lax.dynamic_update_slice(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, cache_pos, 0))
        kpe_full = jax.lax.dynamic_update_slice(
            cache["kpe"], k_pe.astype(cache["kpe"].dtype), (0, cache_pos, 0))
        new_cache = {"ckv": ckv_full, "kpe": kpe_full}
        ckv_u, kpe_u = ckv_full.astype(x.dtype), kpe_full.astype(x.dtype)
        T = ckv_u.shape[1]
        q_pos = cache_pos + jnp.arange(S)
    else:
        ckv_u, kpe_u, new_cache = ckv, k_pe, None
        T = S
        q_pos = jnp.arange(S)

    # up-project latents to per-head K_nope and V
    kv = dense({"w": p["wkv_b"]}, ckv_u).reshape(B, T, h, qn + vd)
    k_nope, v = kv[..., :qn], kv[..., qn:]

    if cfg.attention_impl == "flash" and S > 1:
        # fold the decoupled RoPE part into one flash call:
        # concat [q_nope | q_pe] vs [k_nope | k_pe(broadcast)]
        qc = jnp.concatenate([q_nope, q_pe], -1)          # (B,S,h,qn+qr)
        kc = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kpe_u[:, :, None, :],
                                      (B, T, h, qr))], -1)
        qf = shard_ctx.shard(qc.transpose(0, 2, 1, 3)[:, :, None],
                             model_axes=(1,), batch_axis=0)
        kf = shard_ctx.shard(kc.transpose(0, 2, 1, 3),
                             model_axes=(1,), batch_axis=0)
        vf = shard_ctx.shard(v.transpose(0, 2, 1, 3),
                             model_axes=(1,), batch_axis=0)
        kv_len = (cache_pos + S) if cache is not None else S
        q_off = cache_pos if cache is not None else 0
        out = _sdpa_flash(qf, kf, vf, qn + qr, q_offset=q_off,
                          kv_len=kv_len, causal=True,
                          block=cfg.flash_block)[:, :, 0]
        out = out.transpose(0, 2, 1, 3).reshape(B, S, h * vd)
    else:
        scores = (jnp.einsum("bshd,bthd->bhst", q_nope, k_nope,
                             preferred_element_type=P32)
                  + jnp.einsum("bshd,btd->bhst", q_pe, kpe_u,
                               preferred_element_type=P32))
        scores = scores / jnp.sqrt(jnp.asarray(qn + qr, P32))
        mask = (jnp.arange(T)[None, :] <= q_pos[:, None])[None, None]
        scores = jnp.where(mask, scores, jnp.finfo(P32).min)
        probs = jax.nn.softmax(scores.astype(P32), -1).astype(x.dtype)
        out = jnp.einsum("bhst,bthd->bshd", probs, v).reshape(B, S,
                                                              h * vd)
    return dense({"w": p["wo"]}, out), new_cache


def init_mla_cache(cfg: ModelConfig, batch, max_len, dtype):
    return {"ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
            "kpe": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dtype)}


# ---- FFN --------------------------------------------------------------------

def init_ffn(cfg: ModelConfig, key, d_ff=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    sc = d ** -0.5
    mk = lambda k, o, i: (jax.random.normal(k, (o, i), P32) * sc  # noqa: E731
                          ).astype(cfg.dtype)
    if cfg.ffn_type == "swiglu":
        return {"w_gate": mk(ks[0], f, d), "w_up": mk(ks[1], f, d),
                "w_down": mk(ks[2], d, f)}
    return {"w_up": mk(ks[0], f, d), "b_up": jnp.zeros((f,), P32),
            "w_down": mk(ks[1], d, f), "b_down": jnp.zeros((d,), P32)}


def _act(cfg: ModelConfig, x):
    if cfg.act == "silu":
        return jax.nn.silu(x)
    if cfg.act == "relu2":  # minitron / nemotron squared ReLU
        return jnp.square(jax.nn.relu(x))
    return jax.nn.gelu(x, approximate=False)


def ffn(cfg: ModelConfig, p, x):
    if cfg.ffn_type == "swiglu":
        return dense({"w": p["w_down"]},
                     _act(cfg, dense({"w": p["w_gate"]}, x))
                     * dense({"w": p["w_up"]}, x))
    h = _act(cfg, dense({"w": p["w_up"], "b": p["b_up"]}, x))
    return dense({"w": p["w_down"], "b": p["b_down"]}, h)


# ---- MoE (capacity-based, rank-scatter dispatch) ----------------------------

def init_moe(cfg: ModelConfig, key):
    d, E, f = cfg.d_model, cfg.n_routed_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    sc = d ** -0.5
    mk = lambda k, shape: (jax.random.normal(k, shape, P32) * sc  # noqa: E731
                           ).astype(cfg.dtype)
    p = {
        "router": jax.random.normal(ks[0], (E, d), P32) * sc,
        "w_gate": mk(ks[1], (E, d, f)),
        "w_up": mk(ks[2], (E, d, f)),
        "w_down": mk(ks[3], (E, f, d)),
    }
    if cfg.n_shared_experts:
        shared = cfg.replace(ffn_type="swiglu")
        p["shared"] = init_ffn(shared, ks[4],
                               cfg.n_shared_experts * cfg.moe_d_ff)
    return p


def moe_ffn(cfg: ModelConfig, p, x, router_bias=None):
    """Top-k routed experts + shared experts (deepseek style).

    Dispatch: per-token top-k -> rank within expert via cumsum ->
    scatter into an (E, C, d) capacity buffer -> batched expert FFN ->
    gather back with gate-weighted combine.  Returns (y, aux_loss).
    """
    orig_shape = x.shape
    d = orig_shape[-1]
    xf = x.reshape(-1, d)
    T = xf.shape[0]
    E, K = cfg.n_routed_experts, cfg.top_k

    logits = _dot(xf, p["router"].astype(xf.dtype)).astype(P32)
    if router_bias is not None:
        logits = logits + router_bias
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, K)                  # (T, K)
    gates = gates / jnp.sum(gates, -1, keepdims=True)

    # load-balancing aux loss (Switch style)
    density = jnp.mean(jax.nn.one_hot(idx[:, 0], E, dtype=P32), 0)
    mean_probs = jnp.mean(probs, 0)
    aux = jnp.sum(density * mean_probs) * E * cfg.router_aux_loss

    C = max(int(T * K / E * cfg.capacity_factor), 1)
    C = -(-C // 8) * 8                                    # align

    flat_e = idx.reshape(-1)                              # (T*K,)
    if cfg.moe_rank_impl == "sort":
        # §Perf it1(moe): O(T*K) sort-based ranks — the (T*K, E)
        # one-hot cumsum is ~E/2 x more HBM traffic (dominant term in
        # the deepseek-v2 train baseline)
        order = jnp.argsort(flat_e)                       # stable
        sorted_e = flat_e[order]
        seg_start = jnp.searchsorted(sorted_e, sorted_e, side="left")
        rank_sorted = (jnp.arange(flat_e.shape[0]) - seg_start
                       ).astype(flat_e.dtype)
        rank = jnp.zeros_like(flat_e).at[order].set(rank_sorted)
    else:
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (T*K, E)
        ranks = jnp.cumsum(onehot, axis=0) - onehot
        rank = jnp.sum(ranks * onehot, axis=-1)           # (T*K,)
    keep = rank < C
    slot = jnp.where(keep, flat_e * C + rank, E * C)      # E*C = drop slot

    tok = jnp.repeat(xf, K, axis=0)                       # (T*K, d)
    buf = jnp.zeros((E * C + 1, d), xf.dtype).at[slot].add(tok)
    buf = shard_ctx.shard(buf[:-1].reshape(E, C, d), model_axes=(0,))

    h_g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"],
                     preferred_element_type=P32).astype(xf.dtype)
    h_u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"],
                     preferred_element_type=P32).astype(xf.dtype)
    h = shard_ctx.shard(_act(cfg, h_g) * h_u, model_axes=(0,))
    out = shard_ctx.shard(
        jnp.einsum("ecf,efd->ecd", h, p["w_down"],
                   preferred_element_type=P32).astype(xf.dtype),
        model_axes=(0,))

    gathered = out.reshape(E * C, d)[jnp.minimum(slot, E * C - 1)]
    gathered = jnp.where(keep[:, None], gathered, 0)
    combined = jnp.sum(gathered.reshape(T, K, d)
                       * gates[..., None].astype(xf.dtype), axis=1)

    if cfg.n_shared_experts:
        combined = combined + ffn(cfg.replace(ffn_type="swiglu"),
                                  p["shared"], xf)
    return combined.reshape(orig_shape), aux


# ---- embeddings / heads ------------------------------------------------------

def init_embed(cfg: ModelConfig, key, max_pos=4096):
    ks = jax.random.split(key, 3)
    p = {"tok": (jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model), P32)
                 * 0.02).astype(cfg.dtype)}
    if cfg.pos_embed == "learned":
        p["pos"] = (jax.random.normal(ks[1], (max_pos, cfg.d_model), P32)
                    * 0.02).astype(cfg.dtype)
    return p


def embed(cfg: ModelConfig, p, tokens, positions=None):
    x = jnp.take(p["tok"], tokens, axis=0)
    if cfg.pos_embed == "learned":
        x = x + jnp.take(p["pos"], positions, axis=0)
    return x


def init_lm_head(cfg: ModelConfig, key):
    if cfg.tie_embeddings:
        return {}
    return {"w": (jax.random.normal(key, (cfg.vocab_size, cfg.d_model), P32)
                  * cfg.d_model ** -0.5).astype(cfg.dtype)}


def lm_head(cfg: ModelConfig, p_head, p_embed, x):
    w = p_embed["tok"] if cfg.tie_embeddings else p_head["w"]
    return _dot(x, w).astype(P32)
