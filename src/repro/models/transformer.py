"""Decoder-only / encoder-only transformer LM covering the dense, MoE,
and VLM-backbone architectures (and the paper's BERT / GPT-2).

Layers are stacked (leading L axis) and executed with lax.scan so the
compiled HLO is O(1) in depth; each scan body is rematerialized according
to cfg.remat.  Training, prefill and single-token decode share one
forward; caches are pytrees with a leading layer axis scanned alongside
the weights.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import layers as L
from . import shard_ctx
from .config import ModelConfig

P32 = jnp.float32


# ---- init -------------------------------------------------------------------

def init_block(cfg: ModelConfig, key):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {"ln1": L.init_norm(cfg), "ln2": L.init_norm(cfg)}
    p["attn"] = L.init_mla(cfg, k1) if cfg.use_mla else L.init_attention(
        cfg, k1)
    if cfg.family == "moe":
        p["ffn"] = L.init_moe(cfg, k2)
    else:
        p["ffn"] = L.init_ffn(cfg, k2)
    return p


def init_params(cfg: ModelConfig, key):
    ke, kl, kh, kp = jax.random.split(key, 4)
    lkeys = jax.random.split(kl, cfg.num_layers)
    params = {
        "embed": L.init_embed(cfg, ke, max_pos=4096),
        "layers": jax.vmap(lambda k: init_block(cfg, k))(lkeys),
        "final_norm": L.init_norm(cfg),
        "head": L.init_lm_head(cfg, kh),
    }
    if cfg.family == "encoder":  # paper §2.1: embedding = lookup + LN
        params["embed_norm"] = L.init_norm(cfg)
    if cfg.family == "encoder":  # BERT-style pooler + classifier
        d = cfg.d_model
        kp1, kp2 = jax.random.split(kp)
        params["pooler"] = {
            "w": (jax.random.normal(kp1, (d, d), P32) * d ** -0.5
                  ).astype(cfg.dtype), "b": jnp.zeros((d,), P32)}
        params["classifier"] = {
            "w": (jax.random.normal(kp2, (2, d), P32) * d ** -0.5
                  ).astype(cfg.dtype), "b": jnp.zeros((2,), P32)}
    return params


# ---- one block ----------------------------------------------------------------

def block(cfg: ModelConfig, p, x, *, rope_cs, positions, cache=None,
          cache_pos=None):
    h = L.norm(cfg, p["ln1"], x) if cfg.prenorm else x
    if cfg.use_mla:
        attn_out, new_cache = L.mla_attention(
            cfg, p["attn"], h, positions=positions, cache=cache,
            cache_pos=cache_pos)
    else:
        attn_out, new_cache = L.attention(
            cfg, p["attn"], h, positions=positions, cache=cache,
            cache_pos=cache_pos, rope_cs=rope_cs)
    x = x + attn_out
    if not cfg.prenorm:                      # post-LN (BERT)
        x = L.norm(cfg, p["ln1"], x)
    h = L.norm(cfg, p["ln2"], x) if cfg.prenorm else x
    if cfg.family == "moe":
        f, aux = L.moe_ffn(cfg, p["ffn"], h)
    else:
        f, aux = L.ffn(cfg, p["ffn"], h), jnp.zeros((), P32)
    x = x + f
    if not cfg.prenorm:
        x = L.norm(cfg, p["ln2"], x)
    return x, new_cache, aux


_REMAT_POLICIES = {
    "full": None,  # save nothing
    "dots": "dots_with_no_batch_dims_saveable",
    "none": "everything_saveable",
}


def _maybe_remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    policy = None
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint(fn, policy=policy)


# ---- full forward ---------------------------------------------------------------

def forward(cfg: ModelConfig, params, batch, *, cache=None, cache_pos=None):
    """batch: {"tokens": (B,S)} or {"embeds": (B,S,d)}; optional
    {"positions": (B,S) or (3,B,S) for M-RoPE}.

    Returns (hidden (B,S,d), new_cache, aux_loss)."""
    if cfg.input_kind == "embeddings" and "embeds" in batch:
        x = batch["embeds"].astype(cfg.dtype)
        B, S = x.shape[:2]
    else:
        tokens = batch["tokens"]
        B, S = tokens.shape
        pos_idx = batch.get("positions")
        if pos_idx is None:
            base = cache_pos if cache_pos is not None else 0
            pos_idx = base + jnp.arange(S)[None, :].repeat(B, 0)
        x = L.embed(cfg, params["embed"], tokens,
                    positions=pos_idx if cfg.pos_embed == "learned" else None)
        if "embed_norm" in params:
            x = L.norm(cfg, params["embed_norm"], x)

    positions = batch.get("positions")
    if positions is None:
        base = cache_pos if cache_pos is not None else 0
        positions = base + jnp.arange(S)[None, :].repeat(B, 0)

    rope_cs = None
    if cfg.pos_embed == "rope":
        if cfg.mrope_sections:
            if positions.ndim == 2:  # text-only fallback: t=h=w
                positions = jnp.broadcast_to(positions[None],
                                             (3,) + positions.shape)
            rope_cs = L.mrope_freqs(cfg, positions, cfg.dh)
            positions = positions[0]
        else:
            rope_cs = L.rope_freqs(cfg, positions, cfg.dh)

    def body(carry, xs):
        xc, aux = carry
        xc = shard_ctx.act(xc)
        if cache is None:
            p_l = xs
            xc, _, a = block(cfg, p_l, xc, rope_cs=rope_cs,
                             positions=positions)
            return (shard_ctx.act(xc), aux + a), 0.0
        p_l, cache_l = xs
        xc, new_cache_l, a = block(cfg, p_l, xc, rope_cs=rope_cs,
                                   positions=positions, cache=cache_l,
                                   cache_pos=cache_pos)
        return (xc, aux + a), new_cache_l

    body = _maybe_remat(cfg, body)
    xs = params["layers"] if cache is None else (params["layers"], cache)
    (x, aux), new_cache = jax.lax.scan(body, (x, jnp.zeros((), P32)), xs)
    if cache is None:
        new_cache = None
    x = L.norm(cfg, params["final_norm"], x)
    return x, new_cache, aux


def logits_fn(cfg: ModelConfig, params, hidden):
    return shard_ctx.logits(
        L.lm_head(cfg, params["head"], params["embed"], hidden))


# ---- caches ----------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or cfg.dtype
    if cfg.use_mla:
        one = L.init_mla_cache(cfg, batch, max_len, dtype)
    else:
        one = L.init_attention_cache(cfg, batch, max_len, dtype)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.num_layers,) + a.shape),
        one)


# ---- entry points used by launch/ + serving/ ---------------------------------------

def train_loss(cfg: ModelConfig, params, batch):
    """Causal LM loss (encoder family: masked-token proxy loss)."""
    hidden, _, aux = forward(cfg, params, batch)
    logits = logits_fn(cfg, params, hidden)              # (B,S,V) f32
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = jnp.mean(logz - gold)
    return nll + aux


def prefill(cfg: ModelConfig, params, batch, max_len: int):
    """Process a prompt; return (last-token logits, filled cache)."""
    B, S = (batch["tokens"].shape if "tokens" in batch
            else batch["embeds"].shape[:2])
    cache = init_cache(cfg, B, max_len)
    hidden, cache, _ = forward(cfg, params, batch, cache=cache, cache_pos=0)
    logits = logits_fn(cfg, params, hidden[:, -1:, :])
    return logits[:, 0, :], cache, S


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    """tokens: (B, 1); pos: scalar current length.  One decode step."""
    batch = {"tokens": tokens}
    hidden, cache, _ = forward(cfg, params, batch, cache=cache,
                               cache_pos=pos)
    logits = logits_fn(cfg, params, hidden[:, -1:, :])
    return logits[:, 0, :], cache


# ---- encoder (BERT) adaptation layer -----------------------------------------------

def encoder_classify(cfg: ModelConfig, params, batch):
    hidden, _, _ = forward(cfg, params, batch)
    pooled = jnp.tanh(L.dense(params["pooler"], hidden[:, 0, :]))
    return L.dense(params["classifier"], pooled).astype(P32)
