"""Whisper-tiny backbone (arXiv:2212.04356): encoder-decoder transformer.

Per the assignment the conv/mel frontend is a STUB — `input_specs()`
provides precomputed frame embeddings (B, S_enc, d).  The decoder is a
standard causal transformer with cross-attention; decode_step consumes a
self-attention cache plus precomputed per-layer cross K/V.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L
from . import shard_ctx
from .config import ModelConfig

P32 = jnp.float32


def _init_xattn(cfg: ModelConfig, key):
    return L.init_attention(cfg, key)


def init_params(cfg: ModelConfig, key):
    ke, kd, kh, kp = jax.random.split(key, 4)
    ekeys = jax.random.split(ke, cfg.encoder_layers)
    dkeys = jax.random.split(kd, cfg.num_layers)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": L.init_norm(cfg), "attn": L.init_attention(cfg, k1),
                "ln2": L.init_norm(cfg), "ffn": L.init_ffn(cfg, k2)}

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"ln1": L.init_norm(cfg), "attn": L.init_attention(cfg, k1),
                "lnx": L.init_norm(cfg), "xattn": _init_xattn(cfg, k2),
                "ln2": L.init_norm(cfg), "ffn": L.init_ffn(cfg, k3)}

    d = cfg.d_model
    return {
        "embed": {"tok": (jax.random.normal(kh, (cfg.vocab_size, d), P32)
                          * 0.02).astype(cfg.dtype)},
        "enc_pos": (jax.random.normal(kp, (cfg.max_seq_len, d), P32)
                    * 0.02).astype(cfg.dtype),
        "dec_pos": (jax.random.normal(kp, (cfg.max_seq_len, d), P32)
                    * 0.02).astype(cfg.dtype),
        "enc_layers": jax.vmap(enc_layer)(ekeys),
        "dec_layers": jax.vmap(dec_layer)(dkeys),
        "enc_norm": L.init_norm(cfg),
        "dec_norm": L.init_norm(cfg),
    }


def _mha(cfg, p, q_in, kv_in, mask):
    """Bidirectional / cross attention (no rope; whisper uses learned pos)."""
    B, S, _ = q_in.shape
    T = kv_in.shape[1]
    h, dh = cfg.num_heads, cfg.dh
    q = L.dense({"w": p["wq"]}, q_in).reshape(B, S, h, dh)
    k = L.dense({"w": p["wk"]}, kv_in).reshape(B, T, h, dh)
    v = L.dense({"w": p["wv"]}, kv_in).reshape(B, T, h, dh)
    scores = jnp.einsum("bshd,bthd->bhst", q, k,
                        preferred_element_type=P32) / jnp.sqrt(
                            jnp.asarray(dh, P32))
    if mask is not None:
        scores = jnp.where(mask, scores, jnp.finfo(P32).min)
    probs = jax.nn.softmax(scores, -1).astype(q_in.dtype)
    out = jnp.einsum("bhst,bthd->bshd", probs, v).reshape(B, S, h * dh)
    return L.dense({"w": p["wo"]}, out)


def encode(cfg: ModelConfig, params, embeds):
    B, S, _ = embeds.shape
    x = embeds.astype(cfg.dtype) + params["enc_pos"][:S][None]

    def body(xc, p_l):
        xc = shard_ctx.act(xc)
        xc = xc + _mha(cfg, p_l["attn"], L.norm(cfg, p_l["ln1"], xc),
                       L.norm(cfg, p_l["ln1"], xc), None)
        xc = xc + L.ffn(cfg, p_l["ffn"], L.norm(cfg, p_l["ln2"], xc))
        return xc, 0.0

    if cfg.remat != "none":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.norm(cfg, params["enc_norm"], x)


def _cross_kv(cfg: ModelConfig, p_l, enc_out):
    B, T, _ = enc_out.shape
    h, dh = cfg.num_heads, cfg.dh
    k = L.dense({"w": p_l["xattn"]["wk"]}, enc_out).reshape(B, T, h, dh)
    v = L.dense({"w": p_l["xattn"]["wv"]}, enc_out).reshape(B, T, h, dh)
    return {"xk": k, "xv": v}


def _xattn_cached(cfg, p, q_in, xk, xv):
    B, S, _ = q_in.shape
    h, dh = cfg.num_heads, cfg.dh
    q = L.dense({"w": p["wq"]}, q_in).reshape(B, S, h, dh)
    scores = jnp.einsum("bshd,bthd->bhst", q, xk,
                        preferred_element_type=P32) / jnp.sqrt(
                            jnp.asarray(dh, P32))
    probs = jax.nn.softmax(scores, -1).astype(q_in.dtype)
    out = jnp.einsum("bhst,bthd->bshd", probs, xv).reshape(B, S, h * dh)
    return L.dense({"w": p["wo"]}, out)


def decode(cfg: ModelConfig, params, tokens, enc_out, *, cache=None,
           cache_pos=None):
    """Decoder stack.  cache = {"k","v" (self, per layer), "xk","xv"}."""
    B, S = tokens.shape
    base = cache_pos if cache_pos is not None else 0
    x = jnp.take(params["embed"]["tok"], tokens, axis=0) \
        + jax.lax.dynamic_slice_in_dim(params["dec_pos"], base, S, 0)[None]

    def body(carry, xs):
        xc = shard_ctx.act(carry)
        if cache is None:
            p_l = xs
            S_ = xc.shape[1]
            mask = (jnp.arange(S_)[None, :]
                    <= jnp.arange(S_)[:, None])[None, None]
            xc = xc + _mha(cfg, p_l["attn"], L.norm(cfg, p_l["ln1"], xc),
                           L.norm(cfg, p_l["ln1"], xc), mask)
            xkv = _cross_kv(cfg, p_l, enc_out)
            xc = xc + _xattn_cached(cfg, p_l["xattn"],
                                    L.norm(cfg, p_l["lnx"], xc),
                                    xkv["xk"], xkv["xv"])
            xc = xc + L.ffn(cfg, p_l["ffn"], L.norm(cfg, p_l["ln2"], xc))
            return xc, 0.0
        p_l, c_l = xs
        h = L.norm(cfg, p_l["ln1"], xc)
        q = L.dense({"w": p_l["attn"]["wq"]}, h).reshape(
            B, S, cfg.num_heads, cfg.dh)
        k = L.dense({"w": p_l["attn"]["wk"]}, h).reshape(
            B, S, cfg.num_heads, cfg.dh)
        v = L.dense({"w": p_l["attn"]["wv"]}, h).reshape(
            B, S, cfg.num_heads, cfg.dh)
        ck = jax.lax.dynamic_update_slice(
            c_l["k"], k.astype(c_l["k"].dtype), (0, cache_pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            c_l["v"], v.astype(c_l["v"].dtype), (0, cache_pos, 0, 0))
        T = ck.shape[1]
        q_pos = cache_pos + jnp.arange(S)
        mask = (jnp.arange(T)[None, :] <= q_pos[:, None])[None, None]
        scores = jnp.einsum("bshd,bthd->bhst", q, ck.astype(h.dtype),
                            preferred_element_type=P32) / jnp.sqrt(
                                jnp.asarray(cfg.dh, P32))
        scores = jnp.where(mask, scores, jnp.finfo(P32).min)
        probs = jax.nn.softmax(scores, -1).astype(h.dtype)
        attn = jnp.einsum("bhst,bthd->bshd", probs, cv.astype(h.dtype)
                          ).reshape(B, S, cfg.num_heads * cfg.dh)
        xc = xc + L.dense({"w": p_l["attn"]["wo"]}, attn)
        xc = xc + _xattn_cached(cfg, p_l["xattn"],
                                L.norm(cfg, p_l["lnx"], xc),
                                c_l["xk"].astype(h.dtype),
                                c_l["xv"].astype(h.dtype))
        xc = xc + L.ffn(cfg, p_l["ffn"], L.norm(cfg, p_l["ln2"], xc))
        return xc, {"k": ck, "v": cv, "xk": c_l["xk"], "xv": c_l["xv"]}

    if cfg.remat != "none":
        body = jax.checkpoint(body)
    xs = params["dec_layers"] if cache is None else (params["dec_layers"],
                                                     cache)
    x, new_cache = jax.lax.scan(body, x, xs)
    x = L.norm(cfg, params["dec_norm"], x)
    return x, (None if cache is None else new_cache)


def train_loss(cfg: ModelConfig, params, batch):
    enc_out = encode(cfg, params, batch["embeds"])
    hidden, _ = decode(cfg, params, batch["tokens"], enc_out)
    logits = shard_ctx.logits(
        L._dot(hidden, params["embed"]["tok"]).astype(P32))
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def prefill(cfg: ModelConfig, params, batch, max_len: int):
    enc_out = encode(cfg, params, batch["embeds"])
    B, S = batch["tokens"].shape
    h, dh = cfg.num_heads, cfg.dh
    cache = {
        "k": jnp.zeros((cfg.num_layers, B, max_len, h, dh), cfg.dtype),
        "v": jnp.zeros((cfg.num_layers, B, max_len, h, dh), cfg.dtype),
    }
    xkv = jax.vmap(lambda p_l: _cross_kv(cfg, p_l, enc_out)
                   )(params["dec_layers"])
    cache["xk"], cache["xv"] = xkv["xk"], xkv["xv"]
    hidden, cache = decode(cfg, params, batch["tokens"], enc_out,
                           cache=cache, cache_pos=0)
    logits = L._dot(hidden[:, -1:, :], params["embed"]["tok"]).astype(P32)
    return logits[:, 0, :], cache, S


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    hidden, cache = decode(cfg, params, tokens, None, cache=cache,
                           cache_pos=pos)
    logits = L._dot(hidden[:, -1:, :], params["embed"]["tok"]).astype(P32)
    return logits[:, 0, :], cache
