"""Ambient activation-sharding constraints.

launch/{dryrun,train,serve} install the mesh with `use_mesh`; model code
calls `act()` / `logits()` at the residual-stream boundaries so GSPMD
keeps activations batch-sharded inside scanned layer bodies (without a
constraint the microbatch scan loses the batch sharding and every layer
computes fully replicated — a ~dp_size x blowup visible in the dry-run
collective term).  No-ops when no mesh is installed (CPU tests)."""
from __future__ import annotations

import contextlib

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_STACK: list = []


@contextlib.contextmanager
def use_mesh(mesh):
    _STACK.append(mesh)
    try:
        yield
    finally:
        _STACK.pop()


def _mesh():
    return _STACK[-1] if _STACK else None


def _dp(mesh):
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _constrain(x, spec):
    mesh = _mesh()
    if mesh is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, spec))
    except ValueError:
        return x  # unshardable (e.g. batch not divisible): leave to GSPMD


def act(x, batch_axis: int = 0):
    """Residual-stream activations: batch over DP axes, rest replicated."""
    mesh = _mesh()
    if mesh is None:
        return x
    d = 1
    for a in _dp(mesh):
        d *= mesh.shape[a]
    if x.shape[batch_axis] % d != 0:
        return x
    spec = [None] * x.ndim
    spec[batch_axis] = _dp(mesh)
    return _constrain(x, P(*spec))


def logits(x):
    """(B, S, V) or (B, V): batch over DP, vocab over model."""
    mesh = _mesh()
    if mesh is None:
        return x
    d = 1
    for a in _dp(mesh):
        d *= mesh.shape[a]
    spec = [None] * x.ndim
    if x.shape[0] % d == 0:
        spec[0] = _dp(mesh)
    if x.shape[-1] % mesh.shape["model"] == 0:
        spec[-1] = "model"
    return _constrain(x, P(*spec))


def shard(x, model_axes=(), batch_axis=None):
    """Constrain: batch axis over DP (if divisible) + the first axis in
    `model_axes` divisible by the model-parallel degree over 'model'."""
    mesh = _mesh()
    if mesh is None:
        return x
    spec = [None] * x.ndim
    if batch_axis is not None:
        d = 1
        for a in _dp(mesh):
            d *= mesh.shape[a]
        if x.shape[batch_axis] % d == 0:
            spec[batch_axis] = _dp(mesh)
    m = mesh.shape["model"]
    for ax in model_axes:
        if x.shape[ax] % m == 0:
            spec[ax] = "model"
            break
    return _constrain(x, P(*spec))
