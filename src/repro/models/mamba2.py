"""Mamba2 (SSD — state-space duality, arXiv:2405.21060).

Chunked SSD: quadratic attention-like compute within chunks, linear
recurrence across chunks.  Decode carries an (B, H, P, N) state plus a
depthwise-conv tail — O(1) per token, which is why mamba2/zamba2 are the
archs assigned the long_500k cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import P32, dense, init_norm, rmsnorm

# ---- params -----------------------------------------------------------------


def conv_dim(cfg: ModelConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state


def in_proj_dim(cfg: ModelConfig) -> int:
    return 2 * cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state \
        + cfg.ssm_nheads


def init_mamba_block(cfg: ModelConfig, key):
    d, H = cfg.d_model, cfg.ssm_nheads
    ks = jax.random.split(key, 4)
    sc = d ** -0.5
    return {
        "in_proj": (jax.random.normal(ks[0], (in_proj_dim(cfg), d), P32)
                    * sc).astype(cfg.dtype),
        "conv_w": (jax.random.normal(ks[1], (conv_dim(cfg), cfg.conv_kernel),
                                     P32) * 0.1).astype(cfg.dtype),
        "conv_b": jnp.zeros((conv_dim(cfg),), P32),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=P32)),
        "D": jnp.ones((H,), P32),
        "dt_bias": jnp.zeros((H,), P32),
        "gate_norm": init_norm(cfg, cfg.d_inner),
        "out_proj": (jax.random.normal(ks[2], (d, cfg.d_inner), P32)
                     * sc).astype(cfg.dtype),
    }


# ---- depthwise causal conv ---------------------------------------------------

def causal_conv(w, b, x):
    """x: (B, L, C); w: (C, K) depthwise causal."""
    K = w.shape[1]
    lhs = x.transpose(0, 2, 1)[:, :, None, :]            # (B, C, 1, L)
    rhs = w.astype(x.dtype)[:, None, None, :]            # (C, 1, 1, K)
    out = jax.lax.conv_general_dilated(
        lhs, rhs, (1, 1), [(0, 0), (K - 1, 0)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        feature_group_count=w.shape[0])
    return out[:, :, 0, :].transpose(0, 2, 1) + b.astype(x.dtype)


def conv_step(w, b, tail, x_t):
    """One decode step. tail: (B, K-1, C) previous inputs; x_t: (B, 1, C)."""
    window = jnp.concatenate([tail, x_t], axis=1)        # (B, K, C)
    y = jnp.einsum("bkc,ck->bc", window.astype(P32),
                   w.astype(P32)) + b
    return y[:, None, :].astype(x_t.dtype), window[:, 1:, :]


# ---- chunked SSD --------------------------------------------------------------

def ssd_chunked(x, dt, A, B, C, chunk: int):
    """x: (Bt, L, H, P); dt: (Bt, L, H) (post-softplus); A: (H,) negative;
    B, C: (Bt, L, G, N).  Returns y: (Bt, L, H, P)."""
    Lr = x.shape[1]
    pad = (-Lr) % chunk
    if pad:
        # zero dt on padded tail => no state contribution, decay 1
        padfn = lambda a: jnp.pad(a, [(0, 0), (0, pad)]  # noqa: E731
                                  + [(0, 0)] * (a.ndim - 2))
        x, dt, B, C = padfn(x), padfn(dt), padfn(B), padfn(C)
    y = _ssd_chunked(x, dt, A, B, C, chunk)
    return y[:, :Lr] if pad else y


def _ssd_chunked(x, dt, A, B, C, chunk: int):
    Bt, L, H, Pd = x.shape
    G, N = B.shape[2], B.shape[3]
    nc = L // chunk
    rep = H // G

    xc = x.reshape(Bt, nc, chunk, H, Pd)
    dtc = dt.reshape(Bt, nc, chunk, H)
    Bc = jnp.repeat(B.reshape(Bt, nc, chunk, G, N), rep, axis=3)
    Cc = jnp.repeat(C.reshape(Bt, nc, chunk, G, N), rep, axis=3)

    a = dtc * A                                          # (Bt,nc,q,H) <= 0
    cA = jnp.cumsum(a, axis=2)

    # intra-chunk (quadratic in chunk length)
    seg = cA[:, :, :, None, :] - cA[:, :, None, :, :]    # (Bt,nc,q,s,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bcqhn,bcshn->bcqsh", Cc.astype(P32),
                        Bc.astype(P32)) * decay * dtc[:, :, None, :, :]
    y = jnp.einsum("bcqsh,bcshp->bcqhp", scores, xc.astype(P32))

    # chunk-local final states
    last = cA[:, :, -1:, :]                              # (Bt,nc,1,H)
    w = jnp.exp(last - cA) * dtc                         # (Bt,nc,q,H)
    local = jnp.einsum("bcqhn,bcqhp,bcqh->bchpn", Bc.astype(P32),
                       xc.astype(P32), w)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(last[:, :, 0, :])              # (Bt,nc,H)

    def step(s, inp):
        loc, dec = inp
        s_new = s * dec[:, :, None, None] + loc
        return s_new, s                                  # emit state *before*

    init = jnp.zeros((Bt, H, Pd, N), P32)
    _, s_prev = jax.lax.scan(
        step, init, (local.transpose(1, 0, 2, 3, 4),
                     chunk_decay.transpose(1, 0, 2)))
    s_prev = s_prev.transpose(1, 0, 2, 3, 4)             # (Bt,nc,H,P,N)

    y = y + jnp.einsum("bcqhn,bchpn->bcqhp", Cc.astype(P32), s_prev) \
        * jnp.exp(cA)[..., None]
    return y.reshape(Bt, L, H, Pd).astype(x.dtype)


def ssd_step(state, x_t, dt_t, A, B_t, C_t):
    """One-token SSD update.  state: (Bt,H,P,N); x_t: (Bt,H,P);
    dt_t: (Bt,H); B_t, C_t: (Bt,G,N).  Returns (y_t, new_state)."""
    H = x_t.shape[1]
    rep = H // B_t.shape[1]
    Bh = jnp.repeat(B_t, rep, axis=1).astype(P32)        # (Bt,H,N)
    Ch = jnp.repeat(C_t, rep, axis=1).astype(P32)
    decay = jnp.exp(dt_t * A)                            # (Bt,H)
    upd = jnp.einsum("bh,bhp,bhn->bhpn", dt_t, x_t.astype(P32), Bh)
    state = state * decay[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch)
    return y.astype(x_t.dtype), state


# ---- full block ----------------------------------------------------------------

def _split_proj(cfg: ModelConfig, zxbcdt):
    di, G, N, H = (cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state,
                   cfg.ssm_nheads)
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:di + conv_dim(cfg)]
    dt = zxbcdt[..., di + conv_dim(cfg):]
    assert dt.shape[-1] == H
    return z, xBC, dt


def _split_xbc(cfg: ModelConfig, xBC):
    di, G, N = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state
    return (xBC[..., :di], xBC[..., di:di + G * N],
            xBC[..., di + G * N:])


def mamba_block(cfg: ModelConfig, p, u, cache=None):
    """u: (Bt, L, d).  cache: {"state": (Bt,H,P,N), "conv": (Bt,K-1,Cv)}
    for single-token decode (L == 1).  Returns (out, new_cache)."""
    Bt, L, _ = u.shape
    H, Pd = cfg.ssm_nheads, cfg.ssm_headdim
    A = -jnp.exp(p["A_log"])

    zxbcdt = dense({"w": p["in_proj"]}, u)
    z, xBC, dt_raw = _split_proj(cfg, zxbcdt)
    dt = jax.nn.softplus(dt_raw.astype(P32) + p["dt_bias"])

    if cache is None:
        xBC = jax.nn.silu(causal_conv(p["conv_w"], p["conv_b"], xBC))
        x, Bv, Cv = _split_xbc(cfg, xBC)
        x = x.reshape(Bt, L, H, Pd)
        Bv = Bv.reshape(Bt, L, cfg.ssm_ngroups, cfg.ssm_state)
        Cv = Cv.reshape(Bt, L, cfg.ssm_ngroups, cfg.ssm_state)
        chunk = min(cfg.ssm_chunk, L)
        y = ssd_chunked(x, dt, A, Bv, Cv, chunk)
        y = y + p["D"].astype(P32)[None, None, :, None] * x.astype(P32)
        new_cache = None
    else:
        conv_out, conv_tail = conv_step(p["conv_w"], p["conv_b"],
                                        cache["conv"], xBC)
        xBC = jax.nn.silu(conv_out)
        x, Bv, Cv = _split_xbc(cfg, xBC)
        x1 = x.reshape(Bt, H, Pd)
        y1, state = ssd_step(cache["state"], x1, dt[:, 0], A,
                             Bv.reshape(Bt, cfg.ssm_ngroups, cfg.ssm_state),
                             Cv.reshape(Bt, cfg.ssm_ngroups, cfg.ssm_state))
        y = (y1 + p["D"].astype(P32)[None, :, None] * x1.astype(P32)
             )[:, None]
        new_cache = {"state": state, "conv": conv_tail}

    y = y.reshape(Bt, L, cfg.d_inner).astype(u.dtype)
    y = rmsnorm(p["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return dense({"w": p["out_proj"]}, y), new_cache


def init_mamba_cache(cfg: ModelConfig, batch, dtype):
    return {
        "state": jnp.zeros((batch, cfg.ssm_nheads, cfg.ssm_headdim,
                            cfg.ssm_state), P32),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, conv_dim(cfg)),
                          dtype),
    }


def prefill_final_cache(cfg: ModelConfig, p, u):
    """Run a full prefill and return the cache needed to continue
    decoding: final SSD state + conv tail."""
    Bt, L, _ = u.shape
    H, Pd = cfg.ssm_nheads, cfg.ssm_headdim
    A = -jnp.exp(p["A_log"])
    zxbcdt = dense({"w": p["in_proj"]}, u)
    _, xBC_raw, dt_raw = _split_proj(cfg, zxbcdt)
    dt = jax.nn.softplus(dt_raw.astype(P32) + p["dt_bias"])
    xBC = jax.nn.silu(causal_conv(p["conv_w"], p["conv_b"], xBC_raw))
    x, Bv, Cv = _split_xbc(cfg, xBC)
    x = x.reshape(Bt, L, H, Pd)
    Bv = Bv.reshape(Bt, L, cfg.ssm_ngroups, cfg.ssm_state)

    a = dt * A                                           # (Bt,L,H)
    cA = jnp.cumsum(a, axis=1)
    w = jnp.exp(cA[:, -1:, :] - cA) * dt
    state = jnp.einsum("blgn,blhp,blh->bhpn",
                       Bv.astype(P32), x.astype(P32), w) \
        if cfg.ssm_ngroups == 1 else jnp.einsum(
            "blhn,blhp,blh->bhpn",
            jnp.repeat(Bv, H // cfg.ssm_ngroups, 2).astype(P32),
            x.astype(P32), w)
    conv_tail = xBC_raw[:, -(cfg.conv_kernel - 1):, :]
    return {"state": state, "conv": conv_tail}
