"""Zamba2 hybrid: a Mamba2 backbone with a *shared* attention+MLP block
applied every `attn_every` layers (arXiv:2411.15242).

Layout: the first (num_layers // attn_every) * attn_every mamba blocks
run in groups of `attn_every`, each group preceded by one application of
the shared attention block (own KV cache per application, shared
weights); remaining mamba blocks form a tail.  Simplification vs the
released model (concat-embedding input to the shared block, per-app LoRA
deltas) noted in DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L
from . import mamba2, shard_ctx
from .config import ModelConfig
from .transformer import block as attn_block

P32 = jnp.float32


def group_shape(cfg: ModelConfig):
    n_groups = cfg.num_layers // cfg.attn_every
    tail = cfg.num_layers - n_groups * cfg.attn_every
    return n_groups, tail


def init_params(cfg: ModelConfig, key):
    ke, kl, ks, kh = jax.random.split(key, 4)
    lkeys = jax.random.split(kl, cfg.num_layers)
    mamba_layers = jax.vmap(lambda k: {
        "ln": L.init_norm(cfg),
        "mamba": mamba2.init_mamba_block(cfg, k)})(lkeys)
    k1, k2 = jax.random.split(ks)
    shared = {"ln1": L.init_norm(cfg), "ln2": L.init_norm(cfg),
              "attn": L.init_attention(cfg, k1),
              "ffn": L.init_ffn(cfg, k2)}
    return {
        "embed": L.init_embed(cfg, ke),
        "mamba_layers": mamba_layers,
        "shared": shared,
        "final_norm": L.init_norm(cfg),
        "head": L.init_lm_head(cfg, kh),
    }


def _split_groups(cfg: ModelConfig, tree):
    """(L, ...) stacked leaves -> ((G, E, ...), (tail, ...))."""
    n_groups, tail = group_shape(cfg)
    cut = n_groups * cfg.attn_every
    head = jax.tree.map(
        lambda a: a[:cut].reshape((n_groups, cfg.attn_every) + a.shape[1:]),
        tree)
    rest = jax.tree.map(lambda a: a[cut:], tree)
    return head, rest


def _mamba_scan(cfg, x, layers, caches, cache_pos):
    def body(xc, xs):
        xc = shard_ctx.act(xc)
        if caches is None:
            p_l = xs
            out, _ = mamba2.mamba_block(cfg, p_l["mamba"],
                                        L.norm(cfg, p_l["ln"], xc))
            return xc + out, 0.0
        p_l, c_l = xs
        out, nc = mamba2.mamba_block(cfg, p_l["mamba"],
                                     L.norm(cfg, p_l["ln"], xc), cache=c_l)
        return xc + out, nc

    if cfg.remat != "none":
        body = jax.checkpoint(body)
    xs = layers if caches is None else (layers, caches)
    return jax.lax.scan(body, x, xs)


def forward(cfg: ModelConfig, params, batch, *, cache=None, cache_pos=None):
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = L.embed(cfg, params["embed"], tokens)
    base = cache_pos if cache_pos is not None else 0
    positions = base + jnp.arange(S)[None, :].repeat(B, 0)
    rope_cs = L.rope_freqs(cfg, positions, cfg.dh)
    shared = params["shared"]

    g_layers, t_layers = _split_groups(cfg, params["mamba_layers"])
    if cache is not None:
        g_mcache, t_mcache = _split_groups(cfg, cache["mamba"])
        a_cache = cache["attn"]
    else:
        g_mcache = t_mcache = a_cache = None

    def group_body(xc, xs):
        if cache is None:
            layer_p = xs
            xg, _, _ = attn_block(cfg, shared, xc, rope_cs=rope_cs,
                                  positions=positions)
            xg, _ = _mamba_scan(cfg, xg, layer_p, None, cache_pos)
            return xg, 0.0
        layer_p, mcache_g, acache_g = xs
        xg, new_acache, _ = attn_block(cfg, shared, xc, rope_cs=rope_cs,
                                       positions=positions, cache=acache_g,
                                       cache_pos=cache_pos)
        xg, new_mcache = _mamba_scan(cfg, xg, layer_p, mcache_g, cache_pos)
        return xg, (new_mcache, new_acache)

    if cfg.remat != "none":
        group_body = jax.checkpoint(group_body)
    xs = g_layers if cache is None else (g_layers, g_mcache, a_cache)
    x, group_out = jax.lax.scan(group_body, x, xs)
    x, tail_out = _mamba_scan(cfg, x, t_layers, t_mcache, cache_pos)

    if cache is None:
        new_cache = None
    else:
        n_groups, _ = group_shape(cfg)
        new_gm, new_ac = group_out
        flat_gm = jax.tree.map(
            lambda a: a.reshape((n_groups * cfg.attn_every,) + a.shape[2:]),
            new_gm)
        new_m = jax.tree.map(lambda a, b: jnp.concatenate([a, b], 0),
                             flat_gm, tail_out)
        new_cache = {"mamba": new_m, "attn": new_ac}

    x = L.norm(cfg, params["final_norm"], x)
    return x, new_cache, jnp.zeros((), P32)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or cfg.dtype
    n_groups, _ = group_shape(cfg)
    m_one = mamba2.init_mamba_cache(cfg, batch, dtype)
    a_one = L.init_attention_cache(cfg, batch, max_len, dtype)
    stack = lambda t, n: jax.tree.map(            # noqa: E731
        lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), t)
    return {"mamba": stack(m_one, cfg.num_layers),
            "attn": stack(a_one, n_groups)}


def train_loss(cfg: ModelConfig, params, batch):
    hidden, _, _ = forward(cfg, params, batch)
    logits = shard_ctx.logits(
        L.lm_head(cfg, params["head"], params["embed"], hidden))
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def prefill(cfg: ModelConfig, params, batch, max_len: int):
    """Hybrid prefill: run with caches sized max_len (attention) and
    capture SSM final states via the cached path on the last token.

    For simplicity and exactness we run the cached forward over the whole
    prompt (attention caches are written in place; SSM decode-path caches
    are only valid for single tokens) — so we run the *uncached* forward
    for hidden states and rebuild SSM states with prefill_final_cache
    inside a dedicated scan."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = L.embed(cfg, params["embed"], tokens)
    positions = jnp.arange(S)[None, :].repeat(B, 0)
    rope_cs = L.rope_freqs(cfg, positions, cfg.dh)
    shared = params["shared"]
    g_layers, t_layers = _split_groups(cfg, params["mamba_layers"])
    a_cache = init_cache(cfg, B, max_len)["attn"]

    def mamba_scan_cachecap(xc, layers):
        def body(xi, p_l):
            h = L.norm(cfg, p_l["ln"], xi)
            out, _ = mamba2.mamba_block(cfg, p_l["mamba"], h)
            nc = mamba2.prefill_final_cache(cfg, p_l["mamba"], h)
            return xi + out, nc
        return jax.lax.scan(body, xc, layers)

    def group_body(xc, xs):
        layer_p, acache_g = xs
        xg, new_ac, _ = attn_block(cfg, shared, xc, rope_cs=rope_cs,
                                   positions=positions, cache=acache_g,
                                   cache_pos=0)
        xg, new_mc = mamba_scan_cachecap(xg, layer_p)
        return xg, (new_mc, new_ac)

    x, (gm, ga) = jax.lax.scan(group_body, x, (g_layers, a_cache))
    x, tm = mamba_scan_cachecap(x, t_layers)

    n_groups, _ = group_shape(cfg)
    flat_gm = jax.tree.map(
        lambda a: a.reshape((n_groups * cfg.attn_every,) + a.shape[2:]), gm)
    mcache = jax.tree.map(lambda a, b: jnp.concatenate([a, b], 0),
                          flat_gm, tm)
    x = L.norm(cfg, params["final_norm"], x)
    logits = L.lm_head(cfg, params["head"], params["embed"], x[:, -1:, :])
    return logits[:, 0, :], {"mamba": mcache, "attn": ga}, S


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    hidden, cache, _ = forward(cfg, params, {"tokens": tokens},
                               cache=cache, cache_pos=pos)
    logits = L.lm_head(cfg, params["head"], params["embed"],
                       hidden[:, -1:, :])
    return logits[:, 0, :], cache
