"""Mamba2 language model (attention-free): embed -> scanned pre-norm
mamba blocks -> norm -> head.  O(1)-state decode enables the long_500k
cell."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L
from . import mamba2, shard_ctx
from .config import ModelConfig

P32 = jnp.float32


def init_params(cfg: ModelConfig, key):
    ke, kl, kh = jax.random.split(key, 3)
    lkeys = jax.random.split(kl, cfg.num_layers)
    return {
        "embed": L.init_embed(cfg, ke),
        "layers": jax.vmap(lambda k: {
            "ln": L.init_norm(cfg),
            "mamba": mamba2.init_mamba_block(cfg, k)})(lkeys),
        "final_norm": L.init_norm(cfg),
        "head": L.init_lm_head(cfg, kh),
    }


def forward(cfg: ModelConfig, params, batch, *, cache=None, cache_pos=None):
    x = L.embed(cfg, params["embed"], batch["tokens"])

    def body(carry, xs):
        xc = shard_ctx.act(carry)
        if cache is None:
            p_l = xs
            out, _ = mamba2.mamba_block(cfg, p_l["mamba"],
                                        L.norm(cfg, p_l["ln"], xc))
            return xc + out, 0.0
        p_l, cache_l = xs
        out, new_cache = mamba2.mamba_block(cfg, p_l["mamba"],
                                            L.norm(cfg, p_l["ln"], xc),
                                            cache=cache_l)
        return xc + out, new_cache

    if cfg.remat != "none":
        body = jax.checkpoint(body)
    xs = params["layers"] if cache is None else (params["layers"], cache)
    x, new_cache = jax.lax.scan(body, x, xs)
    x = L.norm(cfg, params["final_norm"], x)
    return x, (None if cache is None else new_cache), jnp.zeros((), P32)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    """SSM cache is O(1) in sequence length (max_len unused)."""
    del max_len
    one = mamba2.init_mamba_cache(cfg, batch, dtype or cfg.dtype)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.num_layers,) + a.shape),
        one)


def train_loss(cfg: ModelConfig, params, batch):
    hidden, _, _ = forward(cfg, params, batch)
    logits = shard_ctx.logits(
        L.lm_head(cfg, params["head"], params["embed"], hidden))
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def prefill(cfg: ModelConfig, params, batch, max_len: int):
    """SSM prefill: run the sequence, capture final state per layer."""
    B, S = batch["tokens"].shape
    x = L.embed(cfg, params["embed"], batch["tokens"])

    def body(xc, p_l):
        h = L.norm(cfg, p_l["ln"], xc)
        out, _ = mamba2.mamba_block(cfg, p_l["mamba"], h)
        new_cache = mamba2.prefill_final_cache(cfg, p_l["mamba"], h)
        return xc + out, new_cache

    x, cache = jax.lax.scan(body, x, params["layers"])
    x = L.norm(cfg, params["final_norm"], x)
    logits = L.lm_head(cfg, params["head"], params["embed"], x[:, -1:, :])
    return logits[:, 0, :], cache, S


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    del pos  # SSM state is position-free
    hidden, cache, _ = forward(cfg, params, {"tokens": tokens},
                               cache=cache, cache_pos=0)
    logits = L.lm_head(cfg, params["head"], params["embed"],
                       hidden[:, -1:, :])
    return logits[:, 0, :], cache
