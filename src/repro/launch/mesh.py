"""Production mesh construction.

A function, not a module constant: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax init)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod (TPU v5e pod), or 2 pods = 512 chips.

    The `pod` axis carries (a) extra data parallelism for training and
    (b) the two-party mapping of the Centaur protocol for private
    serving (share exchange = collective-permute over `pod`)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(devices: int = 8, model: int = 2):
    data = devices // model
    return jax.make_mesh((data, model), ("data", "model"))


def dp_axes(mesh) -> tuple:
    """Axes carrying data parallelism (batch sharding)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_size(mesh) -> int:
    return mesh.shape["model"]


def data_size(mesh) -> int:
    out = 1
    for a in dp_axes(mesh):
        out *= mesh.shape[a]
    return out
