"""Static analysis of post-SPMD HLO for roofline extraction.

XLA's HloCostAnalysis counts while-loop bodies once, which under-counts
scan-over-layers models by ~L x.  This module parses the compiled HLO
text (one per-device SPMD module), builds the computation call graph
(while bodies/conditions, to_apply, calls, branches), reads loop trip
counts from the `known_trip_count` backend_config XLA attaches to
rolled-up scans, and accumulates **per-device**:

  * dot FLOPs: 2 * numel(result) * prod(contracted lhs dims)
    (operand shapes resolved through a module-wide symbol table)
  * convolution FLOPs (approximate, kernel-based)
  * memory bytes touched: sum of result+operand bytes over real
    instructions (bitcast/GTE/tuple/parameter excluded) — an upper-bound
    DRAM-traffic proxy on the post-fusion graph
  * collective WIRE bytes per device by kind, using ring-algorithm costs
    with the replica-group size g:
        all-gather         result * (g-1)/g
        reduce-scatter     result * (g-1)        (operand = g*result)
        all-reduce         result * 2(g-1)/g
        all-to-all         result * (g-1)/g
        collective-permute result

Validated against known-layer-count models in tests/test_dryrun.py.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
               "s64": 8, "u64": 8, "f64": 8, "pred": 1, "s8": 1, "u8": 1,
               "s16": 2, "u16": 2, "c64": 8, "s4": 1, "u4": 1}

_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%([\w.\-]+)\s*\(.*\)\s*->")
_WHILE = re.compile(r"\bwhile\(.*?condition=%?([\w.\-]+),\s*"
                    r"body=%?([\w.\-]+)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL = re.compile(r"(?:to_apply|calls)=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_COLLECTIVE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_GROUPS_NEW = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_OLD = re.compile(r"replica_groups=\{\{([\d,]+)\}")
# operands may carry their type in scheduled/fused dumps:
#   dot(f32[4,16]{1,0} %lhs, f32[16,16]{1,0} %rhs)
_DOT_OPS = re.compile(r"\bdot\(\s*(?:\w+\[[\d,]*\](?:\{[^}]*\})?\s+)?"
                      r"%([\w.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CONV = re.compile(r"\bconvolution\(")
_OPCODE = re.compile(r"^\s*(?:ROOT\s+)?%[\w.\-]+\s*=\s*[^ ]+\s+"
                     r"([\w\-]+)\(")
_OPERANDS = re.compile(r"%([\w.\-]+)")

_NO_TRAFFIC = {"get-tuple-element", "tuple", "bitcast", "parameter",
               "constant", "iota", "after-all", "partition-id",
               "replica-id"}

_WIRE_FACTOR = {
    "all-gather": lambda g: (g - 1) / g,
    "reduce-scatter": lambda g: float(g - 1),
    "all-reduce": lambda g: 2 * (g - 1) / g,
    "all-to-all": lambda g: (g - 1) / g,
    "collective-permute": lambda g: 1.0,
}


def _nums(s: str):
    return [int(x) for x in s.split(",") if x]


def _prod(xs):
    out = 1
    for x in xs:
        out *= x
    return out


def _first_shape(txt: str):
    m = _SHAPE.search(txt)
    return (_nums(m.group(2)), m.group(1)) if m else (None, None)


def _all_shape_bytes(txt: str) -> int:
    total = 0
    for m in _SHAPE.finditer(txt):
        total += _prod(_nums(m.group(2))) * DTYPE_BYTES.get(m.group(1), 4)
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_NEW.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_OLD.search(line)
    if m:
        return max(len(_nums(m.group(1))), 1)
    return default


@dataclass
class Computation:
    name: str
    is_entry: bool = False
    dot_flops: float = 0.0
    conv_flops: float = 0.0
    mem_bytes: float = 0.0
    collectives: dict = field(default_factory=dict)
    whiles: list = field(default_factory=list)   # (body, trip)
    children: list = field(default_factory=list)


def parse_computations(hlo: str, n_devices: int = 2) -> dict:
    comps: dict[str, Computation] = {}
    symbols: dict[str, int] = {}     # instr name -> result bytes
    dims_of: dict[str, list] = {}    # instr name -> result dims
    cur: Computation | None = None
    pending_dots: list = []
    pending_mem: list = []           # (comp, [operand names], own_bytes)

    for line in hlo.splitlines():
        hdr = _COMP_HDR.match(line)
        if hdr and line.rstrip().endswith("{"):
            cur = Computation(hdr.group(2), is_entry=bool(hdr.group(1)))
            comps[cur.name] = cur
            for pm in re.finditer(r"([\w.\-]+):\s*(\w+)\[([\d,]*)\]", line):
                dims = _nums(pm.group(3))
                dims_of[pm.group(1)] = dims
                symbols[pm.group(1)] = _prod(dims) * DTYPE_BYTES.get(
                    pm.group(2), 4)
            continue
        if cur is None:
            continue
        d = _DEF.match(line)
        if not d:
            continue
        rhs = d.group(2)
        dims, dt = _first_shape(rhs)
        rbytes = _all_shape_bytes(rhs.split(" ", 1)[0]) \
            if rhs.startswith("(") else (
                _prod(dims) * DTYPE_BYTES.get(dt, 4) if dims is not None
                else 0)
        symbols[d.group(1)] = rbytes
        if dims is not None:
            dims_of[d.group(1)] = dims

        opm = _OPCODE.match(line)
        opcode = opm.group(1) if opm else ""
        # ---- memory traffic proxy ----
        # dynamic-slice reads only the slice (not its whole operand —
        # critical for scan-stacked weights); dynamic-update-slice is
        # in-place (read+write the update region only)
        name_l = d.group(1)
        if "dynamic-update-slice" in name_l or \
                opcode == "dynamic-update-slice":
            pending_mem.append((cur, [], 0, ("dus", None)))
            args = re.search(r"\((.*?)\)(?:,|$| )", rhs)
            ops = _OPERANDS.findall(args.group(1)) if args else []
            pending_mem[-1] = (cur, ops, 0, ("dus", None))
        elif "dynamic-slice" in name_l or opcode == "dynamic-slice":
            pending_mem.append((cur, [], 2 * rbytes, None))
        elif opcode and opcode not in _NO_TRAFFIC:
            args = re.search(r"\((.*?)\)(?:,|$| )", rhs)
            ops = _OPERANDS.findall(args.group(1)) if args else []
            pending_mem.append((cur, ops, rbytes, None))
        # ---- collectives ----
        m = _COLLECTIVE.search(line)
        if m:
            kind = m.group(1)
            g = _group_size(line, n_devices)
            wire = rbytes * _WIRE_FACTOR[kind](g)
            if kind == "reduce-scatter":
                pass  # rbytes is already the scattered result
            cur.collectives[kind] = cur.collectives.get(kind, 0.0) + wire
        # ---- dots / convs ----
        if " dot(" in rhs:
            dm = _DOT_OPS.search(rhs)
            cm = _CONTRACT.search(rhs)
            if dm and dims is not None:
                pending_dots.append((cur, dims, dm.group(1),
                                     cm.group(1) if cm else ""))
        if _CONV.search(rhs):
            shapes = _SHAPE.findall(rhs)
            rdims = _nums(shapes[0][1]) if shapes else []
            kern = _nums(shapes[2][1]) if len(shapes) > 2 else []
            cur.conv_flops += 2.0 * _prod(rdims) * max(
                _prod(kern) // max(rdims[-1] if rdims else 1, 1), 1)
        # ---- control flow ----
        wm = _WHILE.search(line)
        if wm:
            tm = _TRIP.search(line)
            cur.whiles.append((wm.group(2),
                               int(tm.group(1)) if tm else 1))
            cur.children.append(wm.group(1))
        else:
            for c in _CALL.finditer(line):
                cur.children.append(c.group(1))
        bm = _BRANCHES.search(line)
        if bm:
            cur.children.extend(x.strip().lstrip("%")
                                for x in bm.group(1).split(","))

    for comp, rdims, lhs, cdims in pending_dots:
        lshape = dims_of.get(lhs)
        k = 1
        if lshape:
            for dd in _nums(cdims):
                if dd < len(lshape):
                    k *= lshape[dd]
        comp.dot_flops += 2.0 * _prod(rdims) * k
    for comp, ops, own, special in pending_mem:
        if special and special[0] == "dus":
            sizes = [symbols.get(o, 0) for o in ops]
            if sizes:
                # in-place: traffic = 2 x (everything but the aliased
                # buffer, i.e. the update region + indices)
                comp.mem_bytes += 2 * (sum(sizes) - max(sizes))
            continue
        comp.mem_bytes += own + sum(symbols.get(o, 0) for o in ops)
    return comps


def multipliers(comps: dict) -> dict:
    """Execution count per computation: topological sum over the call
    DAG (each call-site edge contributes caller_count x trip)."""
    entry = next((c for c in comps.values() if c.is_entry), None)
    mult = {name: 0.0 for name in comps}
    if entry is None:
        return mult
    edges: dict[str, list] = {name: [] for name in comps}
    indeg = {name: 0 for name in comps}
    for name, c in comps.items():
        for body, trip in c.whiles:
            if body in comps:
                edges[name].append((body, trip))
                indeg[body] += 1
        for child in c.children:
            if child in comps:
                edges[name].append((child, 1))
                indeg[child] += 1
    mult[entry.name] = 1.0
    ready = [n for n, d in indeg.items() if d == 0]
    while ready:
        name = ready.pop()
        for child, trip in edges[name]:
            mult[child] += mult[name] * trip
            indeg[child] -= 1
            if indeg[child] == 0:
                ready.append(child)
    return mult


def analyze_hlo(hlo: str, default_trip: int = 1, n_devices: int = 2
                ) -> dict:
    """Per-device totals with loop trip counts applied."""
    comps = parse_computations(hlo, n_devices=n_devices)
    mult = multipliers(comps)
    flops = 0.0
    mem = 0.0
    coll = {"all-gather": 0.0, "all-reduce": 0.0, "reduce-scatter": 0.0,
            "all-to-all": 0.0, "collective-permute": 0.0}
    trips = {}
    for name, c in comps.items():
        m = mult.get(name, 0.0)
        flops += (c.dot_flops + c.conv_flops) * m
        mem += c.mem_bytes * m
        for k, v in c.collectives.items():
            coll[k] += v * m
        for body, trip in c.whiles:
            trips[body] = trip
    coll["total"] = sum(coll.values())
    return {"flops": flops, "mem_bytes": mem, "collectives": coll,
            "trips": trips}
