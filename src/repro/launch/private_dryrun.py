import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=" +
                           os.environ.get("REPRO_DRYRUN_DEVICES", "512"))

# ruff: noqa: E402
"""Dry-run of the Centaur PRIVATE inference path on the production
meshes — proof that the paper's protocol lowers and compiles as one SPMD
program at pod scale.

Deployment mapping (DESIGN.md §2): party P0 <-> pod 0, P1 <-> pod 1;
share-exchange messages are the protocol traffic.  In this single-
program form both shares are computed SPMD with activations sharded over
`data`; the exact cross-party wire traffic is taken from the protocol
ledger (shape-exact, Table-1 formulas), which is *more* precise than HLO
collective parsing for the protocol's semantics.

    PYTHONPATH=src python -m repro.launch.private_dryrun \
        --model gpt2-base --multi-pod
"""
import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import comm
from repro.core.private_model import build_private_model, private_forward
from repro.launch.dryrun import ICI_BW, mem_analysis
from repro.launch.mesh import make_production_mesh
from repro.models import shard_ctx
from repro.models.registry import get_api


def run(model: str, multi_pod: bool, batch: int, seq: int,
        out_dir: str | None, mode: str = "centaur"):
    cfg = get_config(model)
    api = get_api(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    key = jax.random.key(0)

    def step(tokens):
        params = api.init_params(cfg, key)          # traced, no alloc
        pm = build_private_model(cfg, params, key, mode=mode)
        return private_forward(pm, tokens)

    tokens = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    from jax.sharding import NamedSharding, PartitionSpec as P
    tok_sh = NamedSharding(mesh, P(("pod", "data") if multi_pod
                                   else ("data",), None))
    t0 = time.time()
    with mesh, shard_ctx.use_mesh(mesh), comm.ledger() as led:
        lowered = jax.jit(step, in_shardings=(tok_sh,)).lower(tokens)
        compiled = lowered.compile()
    dt = time.time() - t0
    mem = mem_analysis(compiled)
    cost = compiled.cost_analysis() or {}
    res = {
        "model": model, "mode": mode,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "batch": batch, "seq": seq, "compile_s": round(dt, 1),
        "protocol_bytes": led.total_bytes(),
        "protocol_rounds": led.total_rounds(),
        "protocol_bytes_per_token": led.total_bytes() / (batch * seq),
        "cross_pod_time_ici_s": led.total_bytes() / ICI_BW,
        "memory_analysis": mem,
        "xla_flops": float(cost.get("flops", 0.0)),
    }
    print(json.dumps(res, indent=1))
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(
                out_dir,
                f"private_{mode}_{model}_{res['mesh']}.json"),
                "w") as f:
            json.dump(res, f, indent=1)
    return res


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="gpt2-base")
    ap.add_argument("--mode", default="centaur",
                    choices=["centaur", "smpc", "mpcformer",
                             "secformer"],
                    help="PPTI mode to lower at pod scale (the suite "
                         "executor makes every share mode one SPMD "
                         "program)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args(argv)
    run(args.model, args.multi_pod, args.batch, args.seq,
        args.out, mode=args.mode)


if __name__ == "__main__":
    main()
