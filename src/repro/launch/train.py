"""Production training launcher.

Single-host CPU runs execute reduced configs directly; on a TPU slice
the same entry point builds the production mesh, applies the sharding
rules from launch/sharding.py, and runs the identical fault-tolerant
loop (params/opt sharded, data pipeline per-host).

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --reduced --steps 50
"""
from __future__ import annotations

import argparse

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.configs import ARCH_IDS, get_config
from repro.data.pipeline import DataPipeline
from repro.launch import sharding as shr
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.models import shard_ctx
from repro.models.registry import get_api
from repro.runtime.fault_tolerance import PreemptionGuard, StragglerMonitor
from repro.training.optimizer import OptConfig
from repro.training.train_loop import run_training


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--preempt-flag", default=None,
                    help="touch this file to request clean preemption")
    ap.add_argument("--mesh", choices=["none", "test", "pod", "multipod"],
                    default="none")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    opt = OptConfig(lr=args.lr, compress_grads=args.compress_grads)
    pipe = DataPipeline(cfg, global_batch=args.global_batch,
                        seq_len=args.seq_len,
                        host_index=jax.process_index(),
                        host_count=jax.process_count())
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    guard = PreemptionGuard(flag_file=args.preempt_flag)
    mon = StragglerMonitor()

    mesh = None
    if args.mesh == "test":
        mesh = make_test_mesh(len(jax.devices()),
                              model=min(2, len(jax.devices())))
    elif args.mesh == "pod":
        mesh = make_production_mesh()
    elif args.mesh == "multipod":
        mesh = make_production_mesh(multi_pod=True)

    def go():
        res = run_training(cfg, opt, pipe, num_steps=args.steps,
                           checkpoint_mgr=mgr, preemption=guard,
                           straggler=mon,
                           num_microbatches=args.microbatches)
        for step, loss in res.losses:
            print(f"step {step:5d} loss {loss:.4f}")
        for act in mon.check():
            print(f"straggler action: {act}")
        return res

    if mesh is not None:
        with mesh, shard_ctx.use_mesh(mesh):
            return go()
    return go()


if __name__ == "__main__":
    main()
