"""ShapeDtypeStruct stand-ins for every dry-run cell — weak-type
correct, shardable, zero allocation."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models.config import SHAPES, ModelConfig, ShapeCell
from repro.models.registry import get_api

CACHE_PAD = 128  # decode cells write one token past the prefilled cache


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def param_specs(cfg: ModelConfig):
    api = get_api(cfg)
    return jax.eval_shape(lambda: api.init_params(cfg, jax.random.key(0)))


def batch_specs(cfg: ModelConfig, cell: ShapeCell, kind: str | None = None):
    """Model inputs for one cell.  kind: train | prefill | decode."""
    kind = kind or cell.kind
    B, S = cell.global_batch, cell.seq_len
    out = {}
    if kind == "decode":
        out["tokens"] = sds((B, 1), jnp.int32)
        return out
    if cfg.family == "encdec":
        dec = max(S // cfg.decoder_ratio, 8)
        out["embeds"] = sds((B, S, cfg.d_model), cfg.dtype)
        out["tokens"] = sds((B, dec), jnp.int32)
        if kind == "train":
            out["labels"] = sds((B, dec), jnp.int32)
        return out
    if cfg.input_kind == "embeddings":
        out["embeds"] = sds((B, S, cfg.d_model), cfg.dtype)
        if cfg.mrope_sections:
            out["positions"] = sds((3, B, S), jnp.int32)
        if kind == "train":
            out["labels"] = sds((B, S), jnp.int32)
        return out
    out["tokens"] = sds((B, S), jnp.int32)
    if kind == "train":
        out["labels"] = sds((B, S), jnp.int32)
    return out


def cache_specs(cfg: ModelConfig, cell: ShapeCell):
    """Decode cells: the filled KV cache after a `seq_len` prefill."""
    api = get_api(cfg)
    max_len = cell.seq_len + CACHE_PAD
    pre_batch = batch_specs(cfg, cell, kind="prefill")

    def run(params, batch):
        _, cache, _ = api.prefill(cfg, params, batch, max_len=max_len)
        return cache

    return jax.eval_shape(run, param_specs(cfg), pre_batch)


def input_specs(cfg: ModelConfig, cell_name: str):
    """Everything dryrun needs for one (arch x shape) cell."""
    cell = SHAPES[cell_name]
    out = {"cell": cell, "params": param_specs(cfg),
           "batch": batch_specs(cfg, cell)}
    if cell.kind == "decode":
        out["cache"] = cache_specs(cfg, cell)
        out["pos"] = sds((), jnp.int32)
    return out
