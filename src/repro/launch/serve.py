"""Serving launcher: plaintext continuous batching or private serving
in any PPTI mode for any --arch.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
        --reduced --requests 6
    PYTHONPATH=src python -m repro.launch.serve --arch gpt2-tiny \
        --mode centaur
    PYTHONPATH=src python -m repro.launch.serve --arch gpt2-tiny \
        --mode smpc --requests 2

Servable modes (centaur/smpc/mpcformer/secformer) on dense archs run
the slot-batched private engine; --mode permute (nothing is hidden, so
there is nothing to serve) and non-dense families fall back to one
private forward, jitted where the suite supports it.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import comm
from repro.models.registry import get_api
from repro.serving.engine import ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-tiny")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mode", choices=["plain", "centaur", "smpc",
                                       "mpcformer", "secformer",
                                       "permute"],
                    default="plain")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=48)
    ap.add_argument("--buckets", default=None,
                    help="prefill length buckets for the private engine:"
                         " 'pow2' (the default ladder), 'none'"
                         " (exact-length prefill, one compile per"
                         " distinct prompt length), or comma-separated"
                         " lengths")
    ap.add_argument("--chunk-size", type=int, default=None,
                    help="chunked prefill (DESIGN.md §10): consume each"
                         " prompt as fixed-size chunks against the slot"
                         " cache — ONE compiled chunk program for every"
                         " length mix; replaces --buckets (max-len must"
                         " be a multiple of the chunk size)")
    ap.add_argument("--integrity", choices=["off", "paranoid"],
                    default="off",
                    help="arm the party-local runtime integrity guards"
                         " (DESIGN.md §11): opened-value envelopes,"
                         " cache-splice structure, ledger conservation."
                         " Guards bill zero comm")
    ap.add_argument("--health", action="store_true",
                    help="print the engine health snapshot (party"
                         " liveness, pool stock, quarantine census)"
                         " after serving")
    ap.add_argument("--transport", choices=["loopback", "socket"],
                    default="loopback",
                    help="comm runtime (DESIGN.md §14): 'loopback'"
                         " passes shares through in-process (bit-exact"
                         " legacy behavior); 'socket' spawns a peer"
                         " process and moves every open's bytes over"
                         " TCP")
    ap.add_argument("--rtt-ms", type=float, default=0.0,
                    help="injected per-round wire latency for"
                         " --transport socket")
    ap.add_argument("--bandwidth-gbps", type=float, default=None,
                    help="injected wire bandwidth (Gbit/s) for"
                         " --transport socket")
    ap.add_argument("--dealer-proc", action="store_true",
                    help="run the Beaver dealer as a separate process:"
                         " an async pool streams triples ahead of"
                         " demand over its own socket (DESIGN.md §14)")
    args = ap.parse_args(argv)
    if args.chunk_size is not None:
        if args.buckets is not None:
            # reject the conflict instead of silently dropping a ladder
            ap.error("--chunk-size replaces --buckets; drop one")
        buckets = None
    else:
        b = args.buckets or "pow2"
        buckets = (None if b == "none" else
                   "pow2" if b == "pow2" else
                   tuple(int(x) for x in b.split(",")))

    cfg = get_config(args.arch, reduced=args.reduced)
    api = get_api(cfg)
    params = api.init_params(cfg, jax.random.key(0))

    def random_prompts():
        # mixed lengths on purpose: realistic traffic for the bucketed
        # prefill path (exact-length engines compile per length)
        key = jax.random.key(1)
        prompts = []
        for i in range(args.requests):
            key, k = jax.random.split(key)
            n = min(3 + (5 * i) % 11, args.max_len - 1)
            prompts.append(list(np.asarray(jax.random.randint(
                k, (n,), 0, cfg.vocab_size))))
        return prompts

    if args.mode == "plain":
        eng = ServingEngine(cfg, params, max_slots=4,
                            max_len=args.max_len)
        rids = [eng.submit(p, max_new_tokens=args.max_new)
                for p in random_prompts()]
        t0 = time.monotonic()
        outs = eng.run_to_completion()
        dt = time.monotonic() - t0
        tok = sum(len(v) for v in outs.values())
        print(f"served {len(rids)} requests / {tok} tokens in {dt:.2f}s "
              f"({tok / dt:.1f} tok/s)")
        for rid in rids:
            print(f"  req {rid}: {outs[rid]}")
        return

    servable = (args.mode != "permute" and cfg.family == "dense"
                and not cfg.use_mla)
    if not servable:
        # permute hides nothing (no engine), and non-dense families
        # have no KV-cache serving path yet: run one private forward
        # (suite.jittable() decides jit vs the eager fallback)
        from repro.core.private_model import (build_private_model,
                                              private_forward)
        pm = build_private_model(cfg, params, jax.random.key(2),
                                 mode=args.mode)
        tokens = jax.random.randint(jax.random.key(3), (1, 16), 0,
                                    cfg.vocab_size)
        with comm.ledger() as led:
            logits = private_forward(pm, tokens, jit=True)
        print(f"[{args.mode}] private forward ok: logits "
              f"{np.asarray(logits).shape}, comm "
              f"{led.total_bytes() / 1e6:.1f} MB / "
              f"{led.total_rounds()} rounds")
        return

    from repro.serving.engine import PrivateServingEngine
    bw = (args.bandwidth_gbps * 1e9 if args.bandwidth_gbps else None)
    eng = PrivateServingEngine(cfg, params, jax.random.key(2),
                               mode=args.mode, max_slots=4,
                               max_len=args.max_len, buckets=buckets,
                               chunk_size=args.chunk_size,
                               integrity=args.integrity,
                               transport=args.transport,
                               rtt_ms=args.rtt_ms, bandwidth_bps=bw,
                               dealer_proc=args.dealer_proc)
    with comm.ledger() as led:
        rids = [eng.submit(p, max_new_tokens=args.max_new)
                for p in random_prompts()]
        t0 = time.monotonic()
        outs, stats = eng.run_to_completion()
        dt = time.monotonic() - t0
    tok = sum(len(v) for v in outs.values())
    cs = eng.compile_stats()
    chunked = (f" ({cs['chunk_ticks']} chunk ticks)"
               if args.chunk_size else "")
    print(f"[{args.mode}] served {len(rids)} requests / {tok} tokens "
          f"in {dt:.2f}s ({tok / dt:.1f} tok/s), "
          f"comm {led.total_bytes() / 1e6:.1f} MB / "
          f"{led.total_rounds()} rounds, "
          f"{cs['prefill_programs']}+{cs['decode_programs']} compiled "
          f"prefill+decode programs over {cs['prefills']} prefills"
          f"{chunked} / {cs['decode_ticks']} ticks")
    for rid in rids:
        st = stats[rid]
        flags = "".join([", truncated" if st["truncated"] else "",
                         ", prompt-truncated"
                         if st["prompt_truncated"] else ""])
        print(f"  req {rid}: {outs.get(rid, '<not delivered>')} "
              f"({st['online_bits'] / 8e6:.1f} MB online, "
              f"{st['rounds']} rounds, status {st['status']}{flags})")
    ts = eng.transport.stats()
    if ts["real"]:
        print(f"transport: {ts['kind']} rtt={ts['rtt_ms']:.1f}ms, "
              f"{ts['messages']} msgs / {ts['rounds']} rounds / "
              f"{ts['bytes_moved'] / 1e6:.1f} MB on the wire "
              f"({ts['wire_s']:.2f}s), peer "
              f"{'alive' if ts['peer_alive'] else 'DEAD'}")
    if args.health:
        h = eng.health()
        parties = " ".join(f"{k}={v}" for k, v in h["parties"].items())
        pool = h["pool"] or {}
        pf = pool.get("prefetch", {})
        print(f"health: {parties}; pool taken "
              f"{sum(pool.get('taken', {}).values())} / in stock "
              f"{sum(pool.get('in_stock', {}).values())}"
              f" (prefetch {pf.get('hits', 0)} hits /"
              f" {pf.get('misses', 0)} misses); "
              f"quarantined {h['quarantined']}; failed {h['failed']}; "
              f"faults {h['faults']}; ticks {h['ticks']}")
        if args.dealer_proc:
            print(f"dealer: {pool.get('dealer')}; "
                  f"degraded={pool.get('degraded')}")
    eng.close()


if __name__ == "__main__":
    main()
