"""Serving launcher: plaintext continuous batching or Centaur private
inference for any --arch.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
        --reduced --requests 6
    PYTHONPATH=src python -m repro.launch.serve --arch gpt2-tiny \
        --mode centaur
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import comm
from repro.models.registry import get_api
from repro.serving.engine import ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-tiny")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mode", choices=["plain", "centaur"],
                    default="plain")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    api = get_api(cfg)
    params = api.init_params(cfg, jax.random.key(0))

    if args.mode == "plain":
        eng = ServingEngine(cfg, params, max_slots=4, max_len=128)
        key = jax.random.key(1)
        rids = []
        for i in range(args.requests):
            key, k = jax.random.split(key)
            prompt = list(np.asarray(jax.random.randint(
                k, (4,), 0, cfg.vocab_size)))
            rids.append(eng.submit(prompt, max_new_tokens=args.max_new))
        t0 = time.monotonic()
        outs = eng.run_to_completion()
        dt = time.monotonic() - t0
        tok = sum(len(v) for v in outs.values())
        print(f"served {len(rids)} requests / {tok} tokens in {dt:.2f}s "
              f"({tok / dt:.1f} tok/s)")
        for rid in rids:
            print(f"  req {rid}: {outs[rid]}")
        return

    from repro.core.private_model import (build_private_model,
                                          private_forward)
    pm = build_private_model(cfg, params, jax.random.key(2),
                             mode="centaur")
    tokens = jax.random.randint(jax.random.key(3), (1, 16), 0,
                                cfg.vocab_size)
    with comm.ledger() as led:
        logits = private_forward(pm, tokens, jit=True)
    print(f"private forward ok: logits {np.asarray(logits).shape}, "
          f"comm {led.total_bytes() / 1e6:.1f} MB / "
          f"{led.total_rounds()} rounds")


if __name__ == "__main__":
    main()
