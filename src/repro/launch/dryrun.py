import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=" +
                           os.environ.get("REPRO_DRYRUN_DEVICES", "512"))

# ruff: noqa: E402
"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh)
cell, record memory/cost/collective analysis for EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-405b \
      --shape train_4k [--multi-pod] [--out experiments/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse
import json
import re
import sys
import time
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.launch import sharding as shr
from repro.launch.hlo_stats import analyze_hlo
from repro.launch.mesh import data_size, make_production_mesh
from repro.launch.specs import CACHE_PAD, batch_specs, cache_specs, \
    input_specs, param_specs
from repro.models.config import SHAPES
from repro.models.registry import get_api
from repro.models import shard_ctx
from repro.training.optimizer import OptConfig, init_opt_state
from repro.training.train_loop import build_train_step

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s/link

def build_step(cfg, cell, mesh, num_micro):
    """Returns (fn, args_specs, in_shardings, donate)."""
    api = get_api(cfg)
    gb = cell.global_batch
    if cell.kind == "train":
        step = build_train_step(cfg, OptConfig(), num_microbatches=num_micro)
        p = param_specs(cfg)
        o = jax.eval_shape(partial(init_opt_state, opt=OptConfig()), p)
        b = batch_specs(cfg, cell)
        ps = shr.param_shardings(cfg, p, mesh)
        os_ = shr.opt_shardings(cfg, o, ps)
        bs = shr.batch_shardings(cfg, b, mesh, gb)
        return step, (p, o, b), (ps, os_, bs), (0, 1)
    if cell.kind == "prefill":
        max_len = cell.seq_len + CACHE_PAD

        def step(params, batch):
            logits, cache, _ = api.prefill(cfg, params, batch, max_len)
            return logits, cache

        p = param_specs(cfg)
        b = batch_specs(cfg, cell)
        ps = shr.param_shardings(cfg, p, mesh)
        bs = shr.batch_shardings(cfg, b, mesh, gb)
        return step, (p, b), (ps, bs), ()
    # decode
    max_len = cell.seq_len + CACHE_PAD

    def step(params, cache, tokens, pos):
        return api.decode_step(cfg, params, cache, tokens, pos)

    p = param_specs(cfg)
    c = cache_specs(cfg, cell)
    b = batch_specs(cfg, cell)
    ps = shr.param_shardings(cfg, p, mesh)
    cs = shr.cache_shardings(cfg, c, mesh, gb, max_len)
    bs = shr.batch_shardings(cfg, b, mesh, gb)
    from jax.sharding import NamedSharding, PartitionSpec as P
    pos_sh = NamedSharding(mesh, P())
    return step, (p, c, b["tokens"], jax.ShapeDtypeStruct((), jnp.int32)), \
        (ps, cs, bs["tokens"], pos_sh), (1,)


def mem_analysis(compiled):
    try:
        ma = compiled.memory_analysis()
        if ma is None:
            raise ValueError
        return {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "code_bytes": int(ma.generated_code_size_in_bytes),
        }
    except Exception:
        return None


def arg_bytes_per_device(args_specs, shardings, mesh):
    """Fallback/analytic per-device input bytes from the shardings."""
    total = 0
    for spec_tree, sh_tree in zip(args_specs, shardings):
        leaves = jax.tree.leaves(spec_tree)
        shs = jax.tree.leaves(sh_tree, is_leaf=lambda x: hasattr(x, "spec"))
        for leaf, sh in zip(leaves, shs):
            n = 1
            for s in leaf.shape:
                n *= s
            n *= jnp.dtype(leaf.dtype).itemsize
            shards = 1
            for ax in jax.tree.leaves(tuple(sh.spec)):
                if ax is not None:
                    shards *= mesh.shape[ax]
            total += n // max(shards, 1)
    return total


def _make_mesh(multi_pod: bool, mesh_spec: str | None):
    if mesh_spec:
        dims = tuple(int(x) for x in mesh_spec.split("x"))
        axes = ("pod", "data", "model") if len(dims) == 3 \
            else ("data", "model")
        return jax.make_mesh(dims, axes)
    return make_production_mesh(multi_pod=multi_pod)


def run_cell(arch: str, shape: str, multi_pod: bool, reduced: bool = False,
             mesh_spec: str | None = None, overrides: dict | None = None):
    cfg = get_config(arch, reduced=reduced)
    if overrides:
        cfg = cfg.replace(**overrides)
    cell = SHAPES[shape]
    if shape == "long_500k" and not cfg.supports_long_context:
        return {"arch": arch, "shape": shape, "skipped":
                "long_500k requires sub-quadratic attention "
                "(DESIGN.md §Arch-applicability)"}
    mesh = _make_mesh(multi_pod, mesh_spec)
    num_micro = max(cell.global_batch // data_size(mesh), 1) \
        if cell.kind == "train" else 1
    num_micro = min(num_micro, 16)
    t0 = time.time()
    step, args, in_sh, donate = build_step(cfg, cell, mesh, num_micro)
    with mesh, shard_ctx.use_mesh(mesh):
        jitted = jax.jit(step, in_shardings=in_sh, donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):      # older jax: one dict per program
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    chips = 1
    for s in mesh.shape.values():
        chips *= s
    stats = analyze_hlo(hlo, default_trip=cfg.num_layers,
                        n_devices=chips)
    # analyze_hlo gives PER-DEVICE dot/conv flops, memory-traffic proxy
    # and collective wire bytes, with loop trip counts applied.
    # Globalize (x chips) for the prescribed roofline formulas; the
    # terms below divide by chips again, i.e. terms are per-chip seconds.
    flops = stats["flops"] * chips
    bytes_accessed = stats["mem_bytes"] * chips
    coll = {k: float(v) * chips for k, v in stats["collectives"].items()}
    coll["trips"] = stats["trips"]
    mem = mem_analysis(compiled)
    raw_flops = float(cost.get("flops", 0.0))
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    tokens = cell.global_batch * (cell.seq_len if cell.kind == "train"
                                  else (cell.seq_len if cell.kind ==
                                        "prefill" else 1))
    model_flops = cfg.flops_per_token(training=(cell.kind == "train")) \
        * tokens
    if cell.kind == "decode":
        # decode attention reads the whole KV state: add 2*cache FLOPs
        model_flops += 0  # reported separately via cache bytes

    result = {
        "arch": arch, "shape": shape, "overrides": overrides or {},
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips, "num_microbatches": num_micro,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "hlo_flops": flops, "hlo_bytes": bytes_accessed,
        "xla_cost_raw": {"flops": raw_flops, "bytes": raw_bytes},
        "collective_bytes": coll, "memory_analysis": mem,
        "arg_bytes_per_device": arg_bytes_per_device(args, in_sh, mesh),
        "model_flops": model_flops,
        "terms": {
            "compute_s": flops / (chips * PEAK_FLOPS),
            "memory_s": bytes_accessed / (chips * HBM_BW),
            "collective_s": coll["total"] / (chips * ICI_BW),
        },
    }
    t = result["terms"]
    result["bottleneck"] = max(t, key=t.get)
    result["useful_flops_frac"] = (model_flops / flops) if flops else None
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default=None)
    ap.add_argument("--shape", choices=list(SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default=None,
                    help="override mesh, e.g. 4x4 or 2x2x4 (tests)")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (perf variants)")
    ap.add_argument("--tag", default="",
                    help="suffix for the output file name")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args(argv)

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES:
                for mp in ([False, True] if (args.both_meshes or True)
                           else [args.multi_pod]):
                    cells.append((arch, shape, mp))
    else:
        meshes = [False, True] if args.both_meshes else [args.multi_pod]
        for mp in meshes:
            cells.append((args.arch, args.shape, mp))

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            try:
                v = float(v)
            except ValueError:
                pass
        overrides[k] = v

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch, shape, mp in cells:
        mesh_name = args.mesh or ('2x16x16' if mp else '16x16')
        tag = f"{arch}_{shape}_{mesh_name}" + \
            (f"_{args.tag}" if args.tag else "")
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path):
            print(f"[skip] {tag} (exists)")
            continue
        print(f"[dryrun] {tag} ...", flush=True)
        try:
            res = run_cell(arch, shape, mp, reduced=args.reduced,
                           mesh_spec=args.mesh, overrides=overrides)
        except Exception as e:  # noqa: BLE001 — report and continue
            res = {"arch": arch, "shape": shape,
                   "mesh": "2x16x16" if mp else "16x16",
                   "error": f"{type(e).__name__}: {e}"}
            failures += 1
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
        if "error" in res:
            print(f"  ERROR {res['error'][:300]}")
        elif "skipped" in res:
            print(f"  skipped: {res['skipped']}")
        else:
            print(f"  ok flops={res['hlo_flops']:.3e} "
                  f"coll={res['collective_bytes']['total']:.3e}B "
                  f"bottleneck={res['bottleneck']} "
                  f"compile={res['compile_s']}s")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
