"""Rule-based GSPMD sharding assignment.

Parameters: the largest divisible axis of every >=2D leaf is tensor-
parallel over `model`; when `fsdp` is set (default for >=30B configs)
the next divisible axis is additionally sharded over `data` (FSDP /
ZeRO-3 for params; optimizer moments always follow the param spec, i.e.
ZeRO-1 comes for free).  Stacked-layer leading axes and tiny leaves stay
replicated.  Caches: batch over the DP axes, then the largest non-
sequence axis over `model`.

These are the *baseline* rules; §Perf iterations override per-cell via
the `overrides` hook.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

from .mesh import data_size, dp_axes, model_size

FSDP_THRESHOLD = 2_000_000  # leaves bigger than this also shard over data


def _assign(shape, skip_axes, mesh, fsdp_leaf):
    m = model_size(mesh)
    d = data_size(mesh)
    spec = [None] * len(shape)
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    tp_axis = None
    for i in order:
        if i in skip_axes:
            continue
        if shape[i] % m == 0:
            spec[i] = "model"
            tp_axis = i
            break
    if fsdp_leaf:
        for i in order:
            if i in skip_axes or i == tp_axis:
                continue
            if shape[i] % d == 0:
                spec[i] = "data"
                break
    return P(*spec)


def param_pspec(cfg: ModelConfig, path, leaf, mesh, fsdp: bool | None = None):
    shape = leaf.shape
    if len(shape) < 2:
        return P()
    skip = set()
    # stacked per-layer leading axis stays unsharded
    if shape[0] in (cfg.num_layers, getattr(cfg, "encoder_layers", -1),
                    cfg.num_layers // max(cfg.attn_every, 1)):
        skip.add(0)
    if fsdp is None:
        fsdp = cfg.param_count() > 20_000_000_000
    big = 1
    for s in shape:
        big *= s
    return _assign(shape, skip, mesh, fsdp and big > FSDP_THRESHOLD)


def param_shardings(cfg: ModelConfig, params_shapes, mesh,
                    fsdp: bool | None = None, overrides=None):
    def one(path, leaf):
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        if overrides:
            for pat, spec in overrides.items():
                if pat in name:
                    return NamedSharding(mesh, spec)
        return NamedSharding(mesh, param_pspec(cfg, path, leaf, mesh, fsdp))

    return jax.tree_util.tree_map_with_path(one, params_shapes)


def batch_pspec(cfg: ModelConfig, leaf_shape, mesh, global_batch):
    dp = dp_axes(mesh)
    d = data_size(mesh)
    if len(leaf_shape) == 0:
        return P()
    # M-RoPE position ids: (3, B, S)
    if len(leaf_shape) >= 2 and leaf_shape[0] == 3 \
            and leaf_shape[1] == global_batch:
        return P(None, dp if global_batch % d == 0 else None)
    if leaf_shape[0] == global_batch and global_batch % d == 0:
        return P(dp)
    return P()


def batch_shardings(cfg: ModelConfig, batch_shapes, mesh, global_batch):
    return jax.tree.map(
        lambda leaf: NamedSharding(mesh, batch_pspec(cfg, leaf.shape, mesh,
                                                     global_batch)),
        batch_shapes)


def cache_pspec(cfg: ModelConfig, leaf_shape, mesh, batch, max_len):
    m = model_size(mesh)
    d = data_size(mesh)
    dp = dp_axes(mesh)
    spec = [None] * len(leaf_shape)
    # batch axis: first axis whose size == batch (after the layer axis)
    b_axis = None
    for i, s in enumerate(leaf_shape[1:], start=1):
        if s == batch:
            b_axis = i
            break
    if b_axis is not None and batch % d == 0:
        spec[b_axis] = dp
    order = sorted(range(len(leaf_shape)), key=lambda i: -leaf_shape[i])
    for i in order:
        if i == 0 or i == b_axis or leaf_shape[i] == max_len:
            continue  # layer axis / batch / sequence stay unsharded
        if leaf_shape[i] % m == 0:
            spec[i] = "model"
            break
    return P(*spec)


def cache_shardings(cfg: ModelConfig, cache_shapes, mesh, batch, max_len):
    return jax.tree.map(
        lambda leaf: NamedSharding(
            mesh, cache_pspec(cfg, leaf.shape, mesh, batch, max_len)),
        cache_shapes)


def opt_shardings(cfg: ModelConfig, opt_shapes, param_shardings_tree):
    """ZeRO-1: moments follow the param shardings (m/v mirror params)."""
    mesh = jax.tree.leaves(param_shardings_tree)[0].mesh

    def like(sub):
        return jax.tree.map(lambda p, s: s, sub, param_shardings_tree)

    out = {"m": like(opt_shapes["m"]), "v": like(opt_shapes["v"]),
           "step": NamedSharding(mesh, P())}
    if "err" in opt_shapes:
        out["err"] = like(opt_shapes["err"])
    return out
