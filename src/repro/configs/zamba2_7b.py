"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention blocks
(arXiv:2411.15242).

81L d_model=3584 32H (kv=32) d_ff=14336 ssm_state=64; the shared
attention+MLP block (one set of weights) is applied every 6 mamba
blocks."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
    d_ff=14336, vocab_size=32000,
    ssm_state=64, ssm_expand=2, ssm_headdim=64, ssm_ngroups=1,
    conv_kernel=4, ssm_chunk=256, attn_every=6,
    norm_type="rmsnorm", act="gelu", ffn_type="swiglu",
)

REDUCED = CONFIG.replace(
    num_layers=5, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
    ssm_state=16, ssm_headdim=32, ssm_chunk=16, attn_every=2,
    vocab_size=256, dtype_str="float32", remat="none",
)
