"""llama3-405b [dense] (arXiv:2407.21783).

126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b", family="dense",
    num_layers=126, d_model=16384, num_heads=128, num_kv_heads=8,
    d_ff=53248, vocab_size=128256, rope_theta=500000.0,
    norm_type="rmsnorm", act="silu", ffn_type="swiglu",
)

REDUCED = CONFIG.replace(
    num_layers=2, d_model=128, num_heads=8, num_kv_heads=2, d_ff=256,
    vocab_size=256, dtype_str="float32", remat="none",
)
