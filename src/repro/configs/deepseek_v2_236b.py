"""deepseek-v2-236b [moe] — MLA + fine-grained MoE (arXiv:2405.04434; hf).

60L d_model=5120 128H d_ff(expert)=1536 vocab=102400, MoE 160 routed
top-6 + 2 shared, MLA kv_lora=512 (q_lora=1536, qk_nope=128, qk_rope=64,
v_head=128 per the paper's released config)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128,
    d_ff=1536, vocab_size=102400,
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    n_routed_experts=160, n_shared_experts=2, top_k=6, moe_d_ff=1536,
    norm_type="rmsnorm", act="silu", ffn_type="swiglu",
)

REDUCED = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
    qk_rope_head_dim=8, v_head_dim=16,
    n_routed_experts=8, n_shared_experts=2, top_k=2, moe_d_ff=32,
    d_ff=32, vocab_size=256, dtype_str="float32", remat="none",
    capacity_factor=4.0,  # dropless at E=8,K=2 (tests compare decode==forward)
)
