"""qwen2-vl-7b [vlm] — M-RoPE backbone (arXiv:2409.12191; hf).

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.  The vision
frontend is a STUB per the assignment: input_specs() provides
precomputed patch embeddings + (3, B, S) M-RoPE position ids."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="dense",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
    d_ff=18944, vocab_size=152064, rope_theta=1000000.0,
    mrope_sections=(16, 24, 24),  # half-dim 64 = 16+24+24
    input_kind="embeddings",
    norm_type="rmsnorm", act="silu", ffn_type="swiglu",
)

REDUCED = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
    vocab_size=256, mrope_sections=(4, 2, 2),  # half-dim 8
    dtype_str="float32", remat="none",
)
