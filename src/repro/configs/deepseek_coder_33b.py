"""deepseek-coder-33b [dense] — llama-arch (arXiv:2401.14196; hf).

62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b", family="dense",
    num_layers=62, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=19200, vocab_size=32256, rope_theta=100000.0,
    norm_type="rmsnorm", act="silu", ffn_type="swiglu",
)

REDUCED = CONFIG.replace(
    num_layers=2, d_model=112, num_heads=7, num_kv_heads=1, d_ff=224,
    vocab_size=256, dtype_str="float32", remat="none",
)
