"""minitron-4b [dense] — pruned nemotron (arXiv:2407.14679; hf).

32L d_model=3072 24H (GQA kv=8, head_dim=128) d_ff=9216 vocab=256000.
Nemotron uses squared-ReLU MLP (no gate)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b", family="dense",
    num_layers=32, d_model=3072, num_heads=24, num_kv_heads=8,
    head_dim=128, d_ff=9216, vocab_size=256000,
    norm_type="layernorm", act="relu2", ffn_type="mlp",
)

REDUCED = CONFIG.replace(
    num_layers=2, d_model=96, num_heads=4, num_kv_heads=2, head_dim=32,
    d_ff=192, vocab_size=256, dtype_str="float32", remat="none",
)
