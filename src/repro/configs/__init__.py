"""Architecture registry: --arch <id> resolves here."""
from importlib import import_module

_ARCH_MODULES = {
    "deepseek-v2-236b": "deepseek_v2_236b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "llama3-405b": "llama3_405b",
    "minitron-4b": "minitron_4b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "smollm-360m": "smollm_360m",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "whisper-tiny": "whisper_tiny",
    "mamba2-130m": "mamba2_130m",
    "zamba2-7b": "zamba2_7b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(arch_id: str, reduced: bool = False):
    if arch_id in _ARCH_MODULES:
        mod = import_module(f"repro.configs.{_ARCH_MODULES[arch_id]}")
        return mod.REDUCED if reduced else mod.CONFIG
    from . import paper_models as pm
    table = {c.name: c for c in (pm.BERT_BASE, pm.BERT_LARGE, pm.GPT2_BASE,
                                 pm.GPT2_LARGE, pm.BERT_TINY, pm.GPT2_TINY)}
    return table[arch_id]
