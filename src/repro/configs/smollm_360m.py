"""smollm-360m [dense] — llama-arch small (hf:HuggingFaceTB/SmolLM).

32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m", family="dense",
    num_layers=32, d_model=960, num_heads=15, num_kv_heads=5,
    d_ff=2560, vocab_size=49152,
    norm_type="rmsnorm", act="silu", ffn_type="swiglu",
)

REDUCED = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
    vocab_size=256, dtype_str="float32", remat="none",
)
