"""deepseek-moe-16b [moe] — fine-grained MoE (arXiv:2401.06066; hf).

28L d_model=2048 16H (MHA kv=16) d_ff(expert)=1408 vocab=102400,
64 routed top-6 + 2 shared."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=102400,
    n_routed_experts=64, n_shared_experts=2, top_k=6, moe_d_ff=1408,
    norm_type="rmsnorm", act="silu", ffn_type="swiglu",
)

REDUCED = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    n_routed_experts=8, n_shared_experts=2, top_k=2, moe_d_ff=32,
    d_ff=32, vocab_size=256, dtype_str="float32", remat="none",
    capacity_factor=4.0,  # dropless at E=8,K=2 (tests compare decode==forward)
)
