"""The paper's own evaluation models (Appendix D): BERT / GPT-2."""
from repro.models.config import ModelConfig

BERT_BASE = ModelConfig(
    name="bert-base", family="encoder",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
    d_ff=3072, vocab_size=30522, causal=False, prenorm=False,
    norm_type="layernorm", act="gelu", ffn_type="mlp",
    pos_embed="learned",
)
BERT_LARGE = BERT_BASE.replace(name="bert-large", num_layers=24,
                               d_model=1024, num_heads=16,
                               num_kv_heads=16, d_ff=4096)
GPT2_BASE = ModelConfig(
    name="gpt2-base", family="dense",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
    d_ff=3072, vocab_size=50257, tie_embeddings=True,
    norm_type="layernorm", act="gelu", ffn_type="mlp",
    pos_embed="learned",
)
GPT2_LARGE = GPT2_BASE.replace(name="gpt2-large", num_layers=36,
                               d_model=1280, num_heads=20,
                               num_kv_heads=20, d_ff=5120)

# tiny variants for tests/examples (fast on CPU, exercised end-to-end)
BERT_TINY = BERT_BASE.replace(name="bert-tiny", num_layers=2, d_model=64,
                              num_heads=4, num_kv_heads=4, d_ff=128,
                              vocab_size=384, dtype_str="float32",
                              remat="none")
GPT2_TINY = GPT2_BASE.replace(name="gpt2-tiny", num_layers=2, d_model=64,
                              num_heads=4, num_kv_heads=4, d_ff=128,
                              vocab_size=384, dtype_str="float32",
                              remat="none")
