"""whisper-tiny [audio] — enc-dec backbone (arXiv:2212.04356).

4L(enc)+4L(dec) d_model=384 6H d_ff=1536 vocab=51865.  Conv/mel
frontend is a STUB: input_specs() provides precomputed frame
embeddings."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="encdec",
    num_layers=4, encoder_layers=4, d_model=384, num_heads=6,
    num_kv_heads=6, d_ff=1536, vocab_size=51865,
    norm_type="layernorm", act="gelu", ffn_type="mlp",
    pos_embed="learned", input_kind="embeddings",
    max_seq_len=33024,  # enough for prefill_32k / decode_32k positions
)

REDUCED = CONFIG.replace(
    num_layers=2, encoder_layers=2, d_model=64, num_heads=4,
    num_kv_heads=4, d_ff=128, vocab_size=256, max_seq_len=512,
    dtype_str="float32", remat="none",
)
