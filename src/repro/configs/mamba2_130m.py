"""mamba2-130m [ssm] — SSD, attention-free (arXiv:2405.21060).

24L d_model=768 ssm_state=128 (expand=2, headdim=64 -> 24 ssd heads)
vocab=50280."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    num_layers=24, d_model=768, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_ngroups=1,
    conv_kernel=4, ssm_chunk=256,
    norm_type="rmsnorm",
)

REDUCED = CONFIG.replace(
    num_layers=2, d_model=64, ssm_state=16, ssm_headdim=32,
    ssm_chunk=16, vocab_size=256, dtype_str="float32", remat="none",
)
