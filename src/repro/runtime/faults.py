"""Deterministic protocol fault injection + runtime integrity guards.

Chaos engineering for the private serving stack (DESIGN.md §11): a
seedable :class:`FaultInjector` holds declarative :class:`FaultPlan`s
("corrupt the share side of the 3rd matmul open during request r's
prefill", "exhaust the TriplePool after 5 takes", "NaN request r's
decoded logits", "wrap the ring on one opened row") and is consulted
from tiny hooks at the protocol's natural seams — ``comm.record`` /
``comm.replay`` (transport), ``beaver._open_masked`` / ``sharing.reveal``
(opened values), ``TriplePool.take``/``generate`` and the
``TripleDealer`` triple methods (offline phase), and the serving
engine's logits decode.  Plans fire on deterministic per-plan call
counters scoped by the ambient engine phase, so a chaos run is
bit-reproducible: the same plans against the same engine always corrupt
the same message.

Integrity guards (``check_envelope`` / ``check_tree_match``) are the
runtime tripwires behind the engine's ``integrity="paranoid"`` flag.
They are party-local computations on values a party already holds in
plaintext (decoded pp-permuted activations at P1, decoded logits at the
client, a party's own cache-share metadata) and therefore record ZERO
ledger events — the PR-5 ledger-independence contract stays
bit-identical with guards on.  NOTE the one value class a guard can
never bound: a masked Beaver opening E = X - A is *uniform* on the ring
by construction, so there is no magnitude envelope at `_open_masked`
itself; envelopes apply only where the protocol legitimately decodes
(pp_apply inputs, head logits), which is also exactly where corruption
must surface to do damage.

Jit caveat: value-corruption plans (``corrupt_open`` / ``ring_wrap``)
act on concrete arrays only and skip tracers — corrupting a traced
value would bake the fault into a cached compiled program and poison
every later fault-free call.  Raising plans (pool/dealer/transport)
fire on the Python side and work under jit too (transport faults on the
jit path fire from ``comm.replay``).  Chaos sweeps run the engine with
``decode_jit=False`` when they need value corruption.

This module deliberately imports nothing from ``repro.core`` at import
time (the core protocol modules import it), and nothing here touches
the ledger stacks.
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass

import jax
import numpy as np


# =============================================================================
# typed failure hierarchy
# =============================================================================

class ServingFault(Exception):
    """Base of every fault the serving engine knows how to survive."""


class ProtocolIntegrityError(ServingFault):
    """An integrity guard tripped: an opened/decoded value escaped its
    envelope, a cache splice changed shape/dtype, or per-request
    accounting stopped summing to the ledger."""


class TransportFault(ServingFault):
    """A protocol message failed in transit (injected at comm.record /
    comm.replay)."""


class DealerFault(ServingFault):
    """The trusted dealer failed to produce offline material."""


class PoolExhausted(DealerFault):
    """The TriplePool ran dry and could not restock."""


class InvalidRequest(ServingFault, ValueError):
    """A submitted request is malformed (empty prompt, non-positive
    token budget).  Raised explicitly so it survives ``python -O``."""


class EngineConfigError(ServingFault, ValueError):
    """Engine construction was given an inconsistent configuration.
    Raised explicitly so it survives ``python -O``."""


# =============================================================================
# fault plans
# =============================================================================

#: plan kind -> the hook ("op") it fires at
OP_OF = {"corrupt_open": "open", "ring_wrap": "open",
         "pool_exhaust": "take", "dealer_fault": "dealer",
         "transport_drop": "record", "nan_logits": "logits"}

FAULT_KINDS = tuple(OP_OF)


@dataclass
class FaultPlan:
    """One declarative fault: fire `kind` at the `index`-th call of its
    hook that matches (site, phase, rid).  `persist=True` keeps firing
    on every later matching call (e.g. a pool that STAYS exhausted).

    `site` filters on the protocol/spec name seen at the seam
    ("matmul", "ppsm", "reveal", ... — "*" matches all); `phase` on the
    engine phase ("prefill" | "decode" | "*"); `rid` on the request
    being prefilled (None matches any).  `row` picks the leading-axis
    row a value corruption lands on (slot index during a batched decode
    tick); `magnitude` is the decoded size of the injected offset."""
    kind: str
    site: str = "*"
    phase: str = "*"
    index: int = 0
    rid: int | None = None
    row: int = 0
    persist: bool = False
    magnitude: float = 1e9

    def __post_init__(self):
        if self.kind not in OP_OF:
            raise EngineConfigError(
                f"unknown fault kind {self.kind!r}; one of {FAULT_KINDS}")


class FaultInjector:
    """Deterministic fault scheduler: per-plan counters over matching
    hook calls; `fired` logs (kind, op, site, phase, rid, count) for
    every injection so tests can assert exact reproducibility."""

    def __init__(self, *plans: FaultPlan, seed: int = 0):
        self.plans = list(plans)
        self.seed = seed
        self._counts = [0] * len(self.plans)
        self.fired: list[tuple] = []

    def reset(self):
        self._counts = [0] * len(self.plans)
        self.fired = []

    def _arm(self, op: str, site: str, rid=None):
        """Count this hook call against every matching plan; return the
        plans whose trigger index is reached."""
        if rid is None:
            rid = current_rid()
        phase = current_phase()
        hits = []
        for j, p in enumerate(self.plans):
            if OP_OF[p.kind] != op:
                continue
            if p.site != "*" and p.site != site:
                continue
            if p.phase != "*" and p.phase != phase:
                continue
            if p.rid is not None and p.rid != rid:
                continue
            c = self._counts[j]
            self._counts[j] += 1
            if c == p.index or (p.persist and c >= p.index):
                hits.append(p)
                self.fired.append((p.kind, op, site, phase, rid, c))
        return hits


# =============================================================================
# ambient stacks: active injector, engine phase, integrity mode
# =============================================================================

_INJECTORS: list[FaultInjector] = []
_PHASES: list[tuple[str, object]] = [("*", None)]
_INTEGRITY: list[str] = ["off"]


@contextlib.contextmanager
def inject(injector: FaultInjector):
    """Activate an injector for the enclosed block (innermost wins)."""
    _INJECTORS.append(injector)
    try:
        yield injector
    finally:
        _INJECTORS.pop()


@contextlib.contextmanager
def phase(name: str, rid=None):
    """Engine-phase scope ("prefill" / "decode") for plan targeting."""
    _PHASES.append((name, rid))
    try:
        yield
    finally:
        _PHASES.pop()


def current_phase() -> str:
    return _PHASES[-1][0]


def current_rid():
    return _PHASES[-1][1]


@contextlib.contextmanager
def integrity(mode: str):
    """Integrity-guard scope: "paranoid" arms check_envelope inside the
    protocol stack for the enclosed block, "off" disarms it."""
    if mode not in ("off", "paranoid"):
        raise EngineConfigError(f"integrity mode {mode!r}; "
                                "one of ('off', 'paranoid')")
    _INTEGRITY.append(mode)
    try:
        yield
    finally:
        _INTEGRITY.pop()


def paranoid() -> bool:
    return _INTEGRITY[-1] == "paranoid"


# =============================================================================
# hooks (called from the protocol seams; no-ops without an injector)
# =============================================================================

def _is_tracer(x) -> bool:
    return isinstance(x, jax.core.Tracer)


def _corrupt(value, plan: FaultPlan):
    from repro.core import ring  # lazy: avoids any import-time cycle
    if plan.kind == "ring_wrap":
        # +2^63 mod 2^64: flips the sign bit — the canonical overflow
        off = np.int64(-(1 << 63))
    else:
        off = np.int64(int(plan.magnitude) << ring.FRAC_BITS)
    if value.ndim == 0:
        return value + off
    idx = plan.row % value.shape[0]
    return value.at[idx].add(off)


def on_open(protocol: str, value):
    """Seam hook on every opened/revealed ring tensor.  May return a
    corrupted copy (concrete values only — tracers pass through
    uncounted so eager and jit traces never diverge on cached
    programs)."""
    if not _INJECTORS or _is_tracer(value):
        return value
    for p in _INJECTORS[-1]._arm("open", protocol):
        value = _corrupt(value, p)
    return value


def on_record(protocol: str, rounds: int, bits: int, online: bool = True):
    """Seam hook on every comm event (after billing: the bytes crossed,
    then the failure surfaced — partial ticks stay sum-conserving)."""
    if not _INJECTORS:
        return
    for p in _INJECTORS[-1]._arm("record", protocol):
        raise TransportFault(
            f"injected transport fault: {protocol} "
            f"({rounds} rounds / {bits} bits, "
            f"{'online' if online else 'offline'})")


def on_transport(protocol: str) -> bool:
    """Seam hook inside a REAL (byte-moving) transport.  Returns True
    when a ``transport_drop`` plan fires, in which case the transport
    performs a GENUINE drop — the peer swallows the message and the
    sender's receive times out on the wire — instead of the synthetic
    raise of :func:`on_record`.  Arms the same ``"record"`` op with the
    same site, so a ``transport_drop`` plan written against the ledger
    seam targets the socket seam without changes (only the failure
    mechanism differs: a real timeout instead of an immediate raise)."""
    if not _INJECTORS:
        return False
    return bool(_INJECTORS[-1]._arm("record", protocol))


def on_take(spec):
    """Seam hook on TriplePool.take (spec already canonical)."""
    if not _INJECTORS:
        return
    for _ in _INJECTORS[-1]._arm("take", spec[0]):
        raise PoolExhausted(f"injected pool exhaustion at take({spec})")


def on_dealer(kind: str):
    """Seam hook on offline-material generation (dealer crash)."""
    if not _INJECTORS:
        return
    for _ in _INJECTORS[-1]._arm("dealer", kind):
        raise DealerFault(f"injected dealer fault generating {kind!r}")


def on_logits(rid, logits):
    """Seam hook on a request's decoded logits row (numpy, engine
    side).  Returns the (possibly NaN'd) row."""
    if not _INJECTORS:
        return logits
    for _ in _INJECTORS[-1]._arm("logits", "logits", rid=rid):
        logits = np.full_like(logits, np.nan)
    return logits


# =============================================================================
# integrity guards — party-local, zero ledger events
# =============================================================================

def check_envelope(x, limit: float, what: str):
    """Paranoid-mode tripwire on a legitimately decoded plaintext value:
    finite and |x| <= limit (a multiple of masking.MASK_MAGNITUDE at the
    call site).  Party-local — the checking party already holds `x` in
    plaintext — so it bills nothing.  Skips tracers (under jit the
    check runs on the eager reference path only)."""
    if not paranoid() or _is_tracer(x):
        return
    xa = np.asarray(x)
    if xa.size == 0:
        return
    if not np.isfinite(xa).all():
        raise ProtocolIntegrityError(f"{what}: non-finite decoded value")
    m = float(np.abs(xa).max())
    if m > limit:
        raise ProtocolIntegrityError(
            f"{what}: |decoded value| {m:.4g} escapes envelope "
            f"{limit:.4g} — corrupted share or ring wrap")


def check_finite_logits(logits, limit: float, what: str):
    """Envelope for decoded logits rows; always-on version used by the
    engine regardless of tracing (logits are concrete numpy there)."""
    la = np.asarray(logits)
    if not np.isfinite(la).all():
        raise ProtocolIntegrityError(f"{what}: non-finite logits")
    if la.size and float(np.abs(la).max()) > limit:
        raise ProtocolIntegrityError(
            f"{what}: logits escape envelope {limit:.4g}")


def check_tree_match(new, ref, what: str):
    """Structural guard: `new` must match `ref` in pytree structure,
    leaf shapes and dtypes (cache-splice integrity).  Party-local on
    share metadata; bills nothing."""
    ns = jax.tree.structure(new)
    rs = jax.tree.structure(ref)
    if ns != rs:
        raise ProtocolIntegrityError(
            f"{what}: pytree structure changed ({ns} != {rs})")
    for a, b in zip(jax.tree.leaves(new), jax.tree.leaves(ref)):
        if jax.numpy.shape(a) != jax.numpy.shape(b):
            raise ProtocolIntegrityError(
                f"{what}: leaf shape changed "
                f"({jax.numpy.shape(a)} != {jax.numpy.shape(b)})")
        da = getattr(a, "dtype", None)
        db = getattr(b, "dtype", None)
        if da != db:
            raise ProtocolIntegrityError(
                f"{what}: leaf dtype changed ({da} != {db})")


@dataclass
class FaultLogEntry:
    """Engine-side record of a survived fault (health() telemetry)."""
    tick: int
    phase: str
    rid: object
    error: str
    detail: str = ""
    retries: int = 0
    outcome: str = "retried"   # retried | failed | quarantined


def summarize_faults(entries: list[FaultLogEntry]) -> dict:
    out: dict[str, int] = {}
    for e in entries:
        out[e.error] = out.get(e.error, 0) + 1
    return out
