"""Transport runtime: the comm seam as a real byte-moving layer.

Every protocol open is *billed* through `core.comm`; this module is
where it is *transported*.  A `Transport` carries two seams:

* ``exchange(protocol, arrays)`` — the payload seam of an EAGER open
  (`beaver._open_masked`, `sharing.reveal`; `open_weight` / `open_rows`
  route through the former).  The caller hands over the share that the
  other party must receive; a real transport serializes it, moves the
  bytes, and the caller reconstructs from the bytes that actually
  arrived — the wire is the source of truth.
* ``push(protocol, rounds, bits)`` — the payload seam of a REPLAYED
  schedule event (`comm.replay`, the jit path).  A compiled program
  owns its values, so the transport moves a size-faithful dummy buffer
  and injects the event's round latency.  The captured schedules are
  proven data-independent (tests/test_ledger_independence.py), so byte
  counts and round counts leak nothing beyond the public shapes — the
  timing argument of DESIGN.md §14.

`LoopbackTransport` (the default) is a pure pass-through with counters:
bit-exact with the pre-transport behavior, zero wire.  `SocketTransport`
spawns `transport_peer.py` as a separate process and moves real bytes
over TCP with injectable RTT / bandwidth shaping, and consults
`faults.on_transport` so an injected `transport_drop` becomes a GENUINE
wire timeout (the peer swallows the frame; the sender's recv expires).

Fidelity note — eager vs replay: an eager matmul performs its two opens
as two sequential socket round trips where the 2-party protocol bills
ONE concurrent round; replayed schedules shape latency from the billed
rounds exactly.  Eager + socket is the byte-correctness path; jit +
socket (the serving engine) is the measured-latency path.
"""
from __future__ import annotations

import atexit
import os
import socket as socketlib
import subprocess
import sys
import threading
import time

import numpy as np

from repro.runtime import faults
from repro.runtime.transport_peer import ACK, DROP, ECHO, EXIT, HDR, _CHUNK

_PEER = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                     "transport_peer.py")


class Transport:
    """Interface consumed by `core.comm` (ambient via `comm.transported`).

    ``real`` distinguishes byte-moving transports: `comm.record` /
    `comm.replay` route fault injection to the transport seam when it is
    True and keep the legacy synthetic `faults.on_record` raise when it
    is False, so loopback runs are bit-exact with history."""

    kind = "none"
    real = False

    def exchange(self, protocol, arrays, reply=True):
        """Move `arrays` (one party's shares) across the wire; return
        the tuple as received by the other side.  With ``reply=False``
        the payload crosses one way (a reveal) and the caller keeps its
        local values."""
        raise NotImplementedError

    def push(self, protocol, rounds, bits):
        """Execute one replayed schedule event on the wire."""
        raise NotImplementedError

    def stats(self) -> dict:
        return {"kind": self.kind, "real": self.real}

    def close(self):
        pass


class LoopbackTransport(Transport):
    """In-process identity transport: values pass through untouched
    (the SPMD simulation already holds both parties' shares), only the
    counters move.  Default for every engine; bit-exact with the
    pre-transport runtime by construction."""

    kind = "loopback"
    real = False

    def __init__(self):
        self.messages = 0
        self.bytes_moved = 0
        self.rounds = 0

    def exchange(self, protocol, arrays, reply=True):
        if any(faults._is_tracer(a) for a in arrays):
            return arrays
        self.messages += 1
        self.rounds += 1
        n = sum(int(getattr(a, "nbytes", 0)) for a in arrays)
        self.bytes_moved += n * (2 if reply else 1)
        return arrays

    def push(self, protocol, rounds, bits):
        self.messages += 1
        self.rounds += int(rounds)
        self.bytes_moved += int(bits) // 8

    def stats(self) -> dict:
        return {"kind": self.kind, "real": False,
                "messages": self.messages, "rounds": self.rounds,
                "bytes_moved": self.bytes_moved}


class SocketTransport(Transport):
    """Cross-process transport: one spawned echo peer per instance.

    The peer plays the mirror party: every exchanged share is answered
    by the equal-sized share crossing the other direction (TCP echo), so
    total wire bytes equal the billed bits exactly, and reconstruction
    uses the received buffer.  ``rtt_ms`` / ``bandwidth_bps`` shape the
    peer's reply delay — latency is injected ON the wire, where a real
    sender blocks."""

    kind = "socket"
    real = True

    def __init__(self, rtt_ms: float = 0.0, bandwidth_bps: float | None = None,
                 timeout_s: float = 30.0, drop_timeout_s: float = 0.25):
        self.rtt_s = float(rtt_ms) / 1e3
        self.bandwidth_bps = bandwidth_bps
        self.timeout_s = timeout_s
        self.drop_timeout_s = drop_timeout_s
        self.messages = 0
        self.bytes_moved = 0
        self.rounds = 0
        self.drops = 0
        self.wire_s = 0.0
        self._lock = threading.RLock()
        self._closed = False
        self._proc = subprocess.Popen(
            [sys.executable, _PEER],
            stdout=subprocess.PIPE, text=True)
        line = self._proc.stdout.readline()
        if not line.startswith("TRANSPORT_PORT "):
            raise faults.TransportFault(
                f"transport peer failed to start (got {line!r})")
        self._sock = socketlib.create_connection(
            ("127.0.0.1", int(line.split()[1])), timeout=timeout_s)
        self._sock.setsockopt(socketlib.IPPROTO_TCP,
                              socketlib.TCP_NODELAY, 1)
        atexit.register(self.close)

    # ---- framing -----------------------------------------------------------
    def _recv_exact(self, n: int, timeout: float, what: str) -> bytes:
        self._sock.settimeout(timeout)
        buf = bytearray()
        try:
            while len(buf) < n:
                chunk = self._sock.recv(min(_CHUNK, n - len(buf)))
                if not chunk:
                    raise faults.TransportFault(
                        f"transport peer closed the connection ({what})")
                buf += chunk
        except socketlib.timeout as err:
            raise faults.TransportFault(
                f"transport timeout after {timeout}s waiting for {what}"
            ) from err
        return bytes(buf)

    def _round_trip(self, op: int, delay: float, payload: bytes,
                    what: str) -> bytes:
        """One send + one reply; on DROP the reply never comes and the
        bounded receive expires — a genuine wire timeout."""
        t0 = time.perf_counter()
        try:
            self._sock.sendall(HDR.pack(op, delay, len(payload)) + payload)
            timeout = self.drop_timeout_s if op == DROP else self.timeout_s
            hdr = self._recv_exact(HDR.size, timeout, what)
            _, _, n = HDR.unpack(hdr)
            return self._recv_exact(n, self.timeout_s, what) if n else b""
        except OSError as err:
            raise faults.TransportFault(f"transport send failed: {err}") \
                from err
        finally:
            self.wire_s += time.perf_counter() - t0

    def _delay(self, wire_bits: int, rounds: int = 1) -> float:
        d = rounds * self.rtt_s
        if self.bandwidth_bps:
            d += wire_bits / self.bandwidth_bps
        return d

    # ---- Transport interface -----------------------------------------------
    def exchange(self, protocol, arrays, reply=True):
        if any(faults._is_tracer(a) for a in arrays):
            return arrays
        bufs = [np.asarray(a) for a in arrays]
        payload = b"".join(b.tobytes() for b in bufs)
        nbytes = len(payload) * (2 if reply else 1)
        with self._lock:
            drop = faults.on_transport(protocol)
            self.messages += 1
            self.rounds += 1
            if drop:
                self.drops += 1
                self._round_trip(DROP, 0.0, payload,
                                 f"{protocol} exchange (dropped)")
                raise faults.TransportFault(   # unreachable safety net:
                    f"dropped {protocol} produced a reply")
            echoed = self._round_trip(ECHO if reply else ACK,
                                      self._delay(nbytes * 8), payload,
                                      f"{protocol} exchange")
            self.bytes_moved += nbytes
        if not reply:
            return arrays
        # reconstruct from the bytes that actually arrived
        import jax.numpy as jnp
        out, off = [], 0
        for b in bufs:
            arr = np.frombuffer(echoed, dtype=b.dtype,
                                count=b.size, offset=off).reshape(b.shape)
            out.append(jnp.asarray(arr))
            off += b.nbytes
        return tuple(out)

    def push(self, protocol, rounds, bits):
        rounds, bits = int(rounds), int(bits)
        half = bits // 16   # bytes each way: total wire == billed bits
        with self._lock:
            drop = faults.on_transport(protocol)
            self.messages += 1
            self.rounds += rounds
            delay = self._delay(bits, rounds)
            if drop:
                self.drops += 1
                self._round_trip(DROP, 0.0, bytes(half),
                                 f"{protocol} replay (dropped)")
                return
            if half:
                self._round_trip(ECHO, delay, bytes(half),
                                 f"{protocol} replay")
                self.bytes_moved += 2 * half
            elif rounds or delay:
                self._round_trip(ACK, delay, b"", f"{protocol} replay")

    def stats(self) -> dict:
        return {"kind": self.kind, "real": True,
                "rtt_ms": self.rtt_s * 1e3,
                "bandwidth_bps": self.bandwidth_bps,
                "messages": self.messages, "rounds": self.rounds,
                "bytes_moved": self.bytes_moved, "drops": self.drops,
                "wire_s": round(self.wire_s, 6),
                "peer_alive": self._proc.poll() is None}

    def close(self):
        if self._closed:
            return
        self._closed = True
        try:
            self._sock.sendall(HDR.pack(EXIT, 0.0, 0))
        except OSError:
            pass
        try:
            self._sock.close()
        finally:
            if self._proc.poll() is None:
                try:
                    self._proc.wait(timeout=2.0)
                except subprocess.TimeoutExpired:
                    self._proc.kill()
            if self._proc.stdout is not None:
                self._proc.stdout.close()


def make_transport(spec, rtt_ms: float = 0.0,
                   bandwidth_bps: float | None = None) -> Transport:
    """Resolve an engine/CLI transport spec: None or "loopback" build a
    fresh `LoopbackTransport`, "socket" a `SocketTransport` with the
    given shaping, and a `Transport` instance passes through."""
    if spec is None or spec == "loopback":
        return LoopbackTransport()
    if spec == "socket":
        return SocketTransport(rtt_ms=rtt_ms, bandwidth_bps=bandwidth_bps)
    if isinstance(spec, Transport):
        return spec
    raise faults.EngineConfigError(
        f"unknown transport {spec!r}; one of ('loopback', 'socket') "
        f"or a Transport instance")
