"""Dealer as a separate process: an async offline-phase producer.

``python -m repro.runtime.dealer_service --serve`` runs the trusted
dealer of the 2-of-2 protocol (paper §2.2) as its own process: it
listens on a localhost socket, prints ``DEALER_PORT <n>``, and answers
generation requests ``{spec, key, n}`` with serialized triple batches.
Generation runs through `beaver.gen_batch` — the SAME code path the
in-process `TriplePool` uses — with the pool's own PRG key shipped per
request, so the material streaming back is bit-identical to what the
pool would have generated locally (jax's threefry PRG is deterministic
across processes on the same backend).

`AsyncTriplePool` (built via :func:`make_async_pool`) is the client
half: a `TriplePool` whose `generate` issues a non-blocking request and
whose deliveries are filed by a reader thread, so the jitted online
compute of one tick overlaps the dealer's generation and share delivery
for the next (`reserve` installs a per-spec low watermark; `take` tops
the spec back up the moment stock plus in-flight material drops below
one tick's demand).  Request DECISIONS depend only on stock + pending —
a quantity conserved across the delivery race — so the (spec, n, key)
request stream, and therefore every triple, is deterministic for a
given serving history regardless of thread scheduling.

Trust boundary (DESIGN.md §14): the dealer process sees specs (public
shapes) and PRG keys, never activation shares — exactly the CrypTen
trusted-third-party model this repo simulates.  If the process dies,
in-flight takes surface `PoolExhausted` (§11 quarantine) and the pool
degrades to in-process generation, so the engine survives for new
traffic.

This module's import is stdlib-only: the service child announces its
port (and the parent connects) in milliseconds, BEFORE jax initializes
on either end of the socket; heavy imports happen lazily.
"""
from __future__ import annotations

import atexit
import json
import os
import socket as socketlib
import struct
import subprocess
import sys
import threading
import time
from collections import deque

from repro.runtime.transport_peer import EXIT, HDR, recv_exact

GEN, TRIPLES = 5, 6
_LEN = struct.Struct("<I")


# =============================================================================
# service side (child process)
# =============================================================================

def serve(announce=None):
    srv = socketlib.socket(socketlib.AF_INET, socketlib.SOCK_STREAM)
    srv.bind(("127.0.0.1", 0))
    srv.listen(1)
    print(f"DEALER_PORT {srv.getsockname()[1]}",
          flush=True, file=announce or sys.stdout)
    conn, _ = srv.accept()
    conn.setsockopt(socketlib.IPPROTO_TCP, socketlib.TCP_NODELAY, 1)

    # heavy imports AFTER the port announcement and accept, so the
    # parent is never blocked on this process's jax startup
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import beaver

    gen_cache: dict = {}
    try:
        while True:
            hdr = recv_exact(conn, HDR.size)
            if hdr is None:
                return
            op, _, n = HDR.unpack(hdr)
            payload = recv_exact(conn, n) if n else b""
            if payload is None or op == EXIT:
                return
            if op != GEN:
                continue
            req = json.loads(payload)
            spec = beaver._canon_spec(req["spec"])
            key = jax.random.wrap_key_data(
                jnp.asarray(req["key"], dtype=jnp.uint32))
            triples = beaver.gen_batch(spec, key, int(req["n"]),
                                       jit_cache=gen_cache)
            raw = b"".join(np.asarray(leaf).tobytes()
                           for tree in triples
                           for leaf in jax.tree.leaves(tree))
            meta = json.dumps({"spec": req["spec"],
                               "n": int(req["n"])}).encode()
            body = _LEN.pack(len(meta)) + meta + raw
            conn.sendall(HDR.pack(TRIPLES, 0.0, len(body)) + body)
    finally:
        conn.close()
        srv.close()


# =============================================================================
# client side (serving process)
# =============================================================================

def _dealer_fault(msg: str):
    from repro.runtime import faults
    return faults.DealerFault(msg)


class DealerClient:
    """Owns the dealer subprocess, the request socket, and the reader
    thread that files deliveries.  The reader blocks in ``recv`` (GIL
    released), so share delivery genuinely overlaps the main thread's
    jitted compute; its last-delivery timestamp doubles as the
    dealer-process heartbeat source."""

    def __init__(self, proc: subprocess.Popen, sock: socketlib.socket):
        self._proc = proc
        self._sock = sock
        self._send_lock = threading.Lock()
        self._cond = threading.Condition()
        self._inbox: deque = deque()      # (spec, n, raw leaf bytes)
        self._templates: dict = {}        # spec -> (treedef, leaf SDSs)
        self._dead = False
        self.requests = 0
        self.deliveries = 0
        self.bytes_out = 0
        self.bytes_in = 0
        self.last_beat = time.monotonic()
        self._reader = threading.Thread(target=self._read_loop,
                                        daemon=True,
                                        name="dealer-client-reader")
        self._reader.start()
        atexit.register(self.close)

    @classmethod
    def spawn(cls) -> "DealerClient":
        """Launch ``python -m repro.runtime.dealer_service --serve`` and
        connect.  The child runs this same interpreter with a
        PYTHONPATH that resolves `repro`, so its jax/PRG stack matches
        bit-for-bit."""
        src = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))))
        env = dict(os.environ)
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        proc = subprocess.Popen(
            [sys.executable, "-m", "repro.runtime.dealer_service",
             "--serve"],
            stdout=subprocess.PIPE, text=True, env=env)
        line = proc.stdout.readline()
        if not line.startswith("DEALER_PORT "):
            proc.kill()
            raise _dealer_fault(
                f"dealer service failed to start (got {line!r})")
        sock = socketlib.create_connection(
            ("127.0.0.1", int(line.split()[1])), timeout=60.0)
        sock.setsockopt(socketlib.IPPROTO_TCP, socketlib.TCP_NODELAY, 1)
        return cls(proc, sock)

    # ---- reader thread ----------------------------------------------------
    def _read_loop(self):
        try:
            while True:
                hdr = self._recv_exact(HDR.size)
                if hdr is None:
                    break
                op, _, n = HDR.unpack(hdr)
                body = self._recv_exact(n)
                if body is None or op != TRIPLES:
                    break
                mlen = _LEN.unpack_from(body)[0]
                meta = json.loads(body[_LEN.size:_LEN.size + mlen])
                with self._cond:
                    self._inbox.append((meta["spec"], meta["n"],
                                        body[_LEN.size + mlen:]))
                    self.deliveries += 1
                    self.bytes_in += n
                    self.last_beat = time.monotonic()
                    self._cond.notify_all()
        except OSError:
            pass
        finally:
            with self._cond:
                self._dead = True
                self._cond.notify_all()

    def _recv_exact(self, n: int):
        buf = bytearray()
        while len(buf) < n:
            try:
                chunk = self._sock.recv(min(1 << 20, n - len(buf)))
            except OSError:
                return None
            if not chunk:
                return None
            buf += chunk
        return bytes(buf)

    # ---- main-thread API --------------------------------------------------
    def request(self, spec, key_data, n: int):
        """Non-blocking generation request (FIFO per connection)."""
        import numpy as np
        payload = json.dumps(
            {"spec": list(spec), "key": np.asarray(key_data).tolist(),
             "n": int(n)}).encode()
        with self._send_lock:
            if not self.alive():
                raise _dealer_fault("dealer process is not running")
            try:
                self._sock.sendall(HDR.pack(GEN, 0.0, len(payload))
                                   + payload)
            except OSError as err:
                raise _dealer_fault(
                    f"dealer request failed: {err}") from err
            self.requests += 1
            self.bytes_out += len(payload)

    def pop_delivered(self) -> list:
        """Drain the inbox, decoding deliveries into triple pytrees
        (decode runs on the caller's thread — the reader only moves
        bytes)."""
        with self._cond:
            items = list(self._inbox)
            self._inbox.clear()
        return [(spec, self._decode(spec, n, raw))
                for spec, n, raw in
                ((_canon(s), n, r) for s, n, r in items)]

    def _decode(self, spec, n: int, raw: bytes) -> list:
        import jax
        import jax.numpy as jnp
        import numpy as np

        from repro.core import beaver
        tpl = self._templates.get(spec)
        if tpl is None:
            kind, shapes = spec[0], spec[1:]
            abstract = jax.eval_shape(
                lambda: beaver._GEN[kind](jax.random.key(0), *shapes))
            tpl = self._templates[spec] = (jax.tree.structure(abstract),
                                           jax.tree.leaves(abstract))
        treedef, leaf_sds = tpl
        trees, off = [], 0
        for _ in range(n):
            leaves = []
            for sd in leaf_sds:
                count = int(np.prod(sd.shape, dtype=np.int64))
                dtype = np.dtype(sd.dtype)
                arr = np.frombuffer(raw, dtype=dtype,
                                    count=count, offset=off)
                leaves.append(jnp.asarray(arr.reshape(sd.shape)))
                off += count * dtype.itemsize
            trees.append(jax.tree.unflatten(treedef, leaves))
        return trees

    def wait(self, timeout: float) -> bool:
        """Block until a delivery is available (True) or the stream is
        dead / the timeout expired (False)."""
        with self._cond:
            if self._inbox:
                return True
            if self._dead:
                return False
            self._cond.wait(timeout)
            return bool(self._inbox)

    def alive(self) -> bool:
        return not self._dead and self._proc.poll() is None

    def kill(self):
        """Hard-kill the dealer process (crash tests / injected
        dealer faults against a real producer)."""
        self._proc.kill()

    def close(self):
        with self._send_lock:
            try:
                self._sock.sendall(HDR.pack(EXIT, 0.0, 0))
            except OSError:
                pass
            try:
                self._sock.close()
            except OSError:
                pass
        if self._proc.poll() is None:
            try:
                self._proc.wait(timeout=2.0)
            except subprocess.TimeoutExpired:
                self._proc.kill()
        if self._proc.stdout is not None:
            self._proc.stdout.close()

    def stats(self) -> dict:
        return {"alive": self.alive(), "pid": self._proc.pid,
                "requests": self.requests, "deliveries": self.deliveries,
                "bytes_in": self.bytes_in, "bytes_out": self.bytes_out}


def _canon(spec) -> tuple:
    return tuple((spec[0],) + tuple(tuple(int(d) for d in s)
                                    for s in spec[1:]))


# =============================================================================
# async pool (drop-in TriplePool with a background producer)
# =============================================================================

def make_async_pool(key, client: DealerClient, batch: int = 8,
                    take_timeout_s: float = 30.0):
    """Build an ``AsyncTriplePool`` — a `beaver.TriplePool` subclass
    whose offline phase streams through `client`.  A factory (rather
    than a module-level class) keeps this module's import stdlib-only;
    the class is created on first use, when jax is loaded anyway."""
    from collections import deque as _deque

    from repro.core import beaver, comm
    from repro.runtime import faults

    class AsyncTriplePool(beaver.TriplePool):
        def __init__(self):
            super().__init__(key, batch)
            self._client = client
            self._pending: dict[tuple, int] = {}
            self._watermark: dict[tuple, int] = {}
            self._quantum: dict[tuple, int] = {}
            self._take_timeout_s = take_timeout_s
            self.degraded = False

        # ---- dealer-process liveness (engine heartbeat source) --------
        def dealer_alive(self) -> bool:
            return not self.degraded and self._client.alive()

        def dealer_client(self) -> DealerClient:
            return self._client

        # ---- offline phase -------------------------------------------
        def generate(self, spec, n: int):
            spec = beaver._canon_spec(spec)
            if self.degraded or not self._client.alive():
                if not self.degraded:
                    self._fail(spec, "died before generate")
                # in-process fallback: the engine survives for new
                # traffic on the same (deterministic) PRG stream
                return super().generate(spec, n)
            try:
                beaver._fault_dealer(spec[0])
            except faults.DealerFault:
                # an injected dealer fault against a REAL producer is a
                # genuine crash: kill the process, then surface it
                self._client.kill()
                self.degraded = True
                self._pending.clear()
                raise
            k = self._next_key()
            import jax
            self._client.request(list(spec), jax.random.key_data(k), n)
            self._pending[spec] = self._pending.get(spec, 0) + n
            comm.record("dealer_triple", rounds=1,
                        bits=n * beaver._spec_offline_bits(spec),
                        online=False)

        def _drain(self):
            for spec, triples in self._client.pop_delivered():
                pool = self._pools.setdefault(spec, _deque())
                pool.extend(triples)
                self._pending[spec] = max(
                    0, self._pending.get(spec, 0) - len(triples))
                self._high_water[spec] = max(
                    self._high_water.get(spec, 0), len(pool))

        def _in_flight(self, spec) -> int:
            return (len(self._pools.get(spec, ()))
                    + self._pending.get(spec, 0))

        def _fail(self, spec, how: str):
            self.degraded = True
            self._pending.clear()
            raise faults.PoolExhausted(
                f"dealer process {how} with take({spec}) outstanding — "
                f"pool drained, degrading to in-process generation")

        # ---- online phase --------------------------------------------
        def take(self, spec):
            spec = beaver._canon_spec(spec)
            beaver._fault_take(spec)
            self._drain()
            pool = self._pools.setdefault(spec, _deque())
            self._note_take(spec, len(pool))
            if not pool:
                if not self._pending.get(spec):
                    n = min(self.batch,
                            max(1, self._taken.get(spec, 0)))
                    self.generate(spec, n)
                deadline = time.monotonic() + self._take_timeout_s
                while not pool:
                    if self.degraded:
                        break   # degraded generate filled synchronously
                    if not self._client.wait(timeout=0.05):
                        if not self._client.alive():
                            self._fail(spec, "died")
                        if time.monotonic() > deadline:
                            self._fail(spec, "timed out")
                    self._drain()
            self._taken[spec] = self._taken.get(spec, 0) + 1
            triple = pool.popleft()
            # low-watermark prefetch: top the spec back up NOW so the
            # dealer generates for the next tick while this tick's
            # jitted compute runs — the overlap that makes the offline
            # phase genuinely asynchronous
            wm = self._watermark.get(spec)
            if (wm and not self.degraded
                    and self._in_flight(spec) < wm):
                self.generate(spec, self._quantum.get(spec, wm))
            return triple

        def prefetch(self, specs):
            self._drain()
            counts: dict[tuple, int] = {}
            for s in specs:
                s = beaver._canon_spec(s)
                counts[s] = counts.get(s, 0) + 1
            for spec, n in counts.items():
                have = self._in_flight(spec)
                if have < n:
                    self.generate(spec, n - have)

        def reserve(self, specs, steps: int = 1):
            steps = max(int(steps), 1)
            self._drain()
            counts: dict[tuple, int] = {}
            for s in specs:
                s = beaver._canon_spec(s)
                counts[s] = counts.get(s, 0) + 1
            for spec, c in counts.items():
                # the watermark/quantum pair drives take()'s top-up;
                # counting in-flight material bounds outstanding
                # requests to one refill quantum per spec (backpressure)
                self._watermark[spec] = c
                self._quantum[spec] = steps * c
                if self._in_flight(spec) < c:
                    self.generate(spec, steps * c)

        def stock(self) -> dict:
            self._drain()
            out = super().stock()
            out["pending"] = sum(self._pending.values())
            out["degraded"] = self.degraded
            out["dealer"] = self._client.stats()
            return out

        def close(self):
            self._client.close()

    return AsyncTriplePool()


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(
        description="CENTAUR dealer service (separate-process offline "
                    "phase)")
    ap.add_argument("--serve", action="store_true",
                    help="run the dealer service (child process mode)")
    args = ap.parse_args(argv)
    if args.serve:
        serve()
    else:
        ap.print_help()


if __name__ == "__main__":
    main()
