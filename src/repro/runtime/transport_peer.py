"""Echo peer for `runtime.transport.SocketTransport` — the other party.

Run directly by file path (NOT ``-m``) so the child process imports
nothing but the stdlib: no jax, no repro — startup is milliseconds, and
the peer can never deadlock on the parent's compilation locks.  The
parent spawns one peer per transport, reads ``TRANSPORT_PORT <n>`` from
its stdout, connects, and speaks the frame protocol below.

Frame = 17-byte header ``<BdQ`` (op, reply-delay seconds, payload
length) + payload bytes.  Ops:

* ``ECHO`` — sleep ``delay`` then send the payload back (the mirror
  party's equal-sized share crossing the other direction; the delay is
  the injected RTT + bandwidth model applied on the wire, where a
  sender actually blocks).
* ``ACK``  — sleep ``delay`` then send an empty frame (a round with no
  payload, e.g. a replayed round marker).
* ``DROP`` — swallow the frame and send NOTHING.  The sender's receive
  times out: an injected `transport_drop` becomes a genuine wire
  timeout.  The stream stays framed — the next message proceeds.
* ``EXIT`` — close the connection and exit.
"""
import socket
import struct
import sys
import time

HDR = struct.Struct("<BdQ")
ECHO, ACK, DROP, EXIT = 1, 2, 3, 4
_CHUNK = 1 << 20


def recv_exact(conn, n):
    """Read exactly n bytes or return None on EOF."""
    buf = bytearray()
    while len(buf) < n:
        chunk = conn.recv(min(_CHUNK, n - len(buf)))
        if not chunk:
            return None
        buf += chunk
    return bytes(buf)


def serve(announce=None):
    srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    srv.bind(("127.0.0.1", 0))
    srv.listen(1)
    print(f"TRANSPORT_PORT {srv.getsockname()[1]}",
          flush=True, file=announce or sys.stdout)
    conn, _ = srv.accept()
    conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    try:
        while True:
            hdr = recv_exact(conn, HDR.size)
            if hdr is None:
                return
            op, delay, n = HDR.unpack(hdr)
            payload = recv_exact(conn, n) if n else b""
            if payload is None or op == EXIT:
                return
            if op == DROP:
                continue
            if delay > 0:
                time.sleep(delay)
            if op == ECHO:
                conn.sendall(HDR.pack(ECHO, 0.0, len(payload)) + payload)
            elif op == ACK:
                conn.sendall(HDR.pack(ACK, 0.0, 0))
    finally:
        conn.close()
        srv.close()


if __name__ == "__main__":
    serve()
