"""Fault-tolerance runtime: heartbeats, straggler detection, cooperative
preemption.  Host-side orchestration logic — pure Python, unit-tested
with injected clocks so behaviour is verifiable without a cluster."""
from __future__ import annotations

import os
import signal
import time
from collections import defaultdict, deque
from dataclasses import dataclass, field


class HeartbeatMonitor:
    """Tracks per-host liveness; a host is dead after `timeout` seconds
    of silence.  On a real deployment every host POSTs beats to the
    coordinator; here beats are injected directly."""

    def __init__(self, timeout: float = 60.0, clock=time.monotonic):
        self.timeout = timeout
        self.clock = clock
        self.last = {}

    def beat(self, host: int, at: float | None = None):
        self.last[host] = self.clock() if at is None else at

    def dead_hosts(self, now: float | None = None):
        now = self.clock() if now is None else now
        return sorted(h for h, t in self.last.items()
                      if now - t > self.timeout)

    def all_alive(self) -> bool:
        return not self.dead_hosts()


@dataclass
class StragglerPolicy:
    threshold: float = 1.5      # x median step time
    min_observations: int = 5
    action: str = "alert"       # alert | evict | rebalance


class StragglerMonitor:
    """Per-host EMA of step durations.  In synchronous SPMD a straggler
    slows every step; the monitor feeds the launcher's policy: alert,
    evict (drop the host and trigger an elastic restart at a smaller
    mesh from the last checkpoint), or rebalance (shrink its data
    shard)."""

    def __init__(self, policy: StragglerPolicy | None = None,
                 ema: float = 0.3):
        # a fresh policy per monitor: a shared default instance would
        # alias policy mutations across every monitor in the process
        self.policy = StragglerPolicy() if policy is None else policy
        self.ema_alpha = ema
        self.times: dict[int, float] = {}
        self.counts: dict[int, int] = defaultdict(int)
        self.events: list = []
        self.last_step: dict[int, int] = {}

    def observe(self, host: int, step: int, duration: float):
        # drop stale/duplicate step reports (a re-delivered beat or an
        # out-of-order arrival must not inflate the observation count
        # or drag the EMA backwards in time)
        last = self.last_step.get(host)
        if last is not None and step <= last:
            return
        self.last_step[host] = step
        prev = self.times.get(host, duration)
        self.times[host] = (1 - self.ema_alpha) * prev \
            + self.ema_alpha * duration
        self.counts[host] += 1

    def _median(self):
        vals = sorted(self.times.values())
        return vals[len(vals) // 2]

    def stragglers(self):
        if len(self.times) < 2:
            return []
        med = self._median()
        out = []
        for h, t in self.times.items():
            if (self.counts[h] >= self.policy.min_observations
                    and t > self.policy.threshold * med):
                out.append((h, t / med))
        return sorted(out)

    def check(self):
        """Returns the actions the launcher should take this step."""
        actions = []
        for host, slowdown in self.stragglers():
            actions.append({"host": host, "slowdown": slowdown,
                            "action": self.policy.action})
            self.events.append((host, slowdown, self.policy.action))
        return actions


class PreemptionGuard:
    """Cooperative preemption: SIGTERM or a sentinel file requests a
    clean checkpoint-and-exit; the training loop polls should_stop()."""

    def __init__(self, flag_file: str | None = None,
                 install_signal: bool = False):
        self.flag_file = flag_file
        self._flag = False
        self._prev_handler = None
        if install_signal:  # opt-in; tests use the file/explicit path
            # chain, don't clobber: a pre-existing SIGTERM handler
            # (the launcher's own checkpointer, a supervisor's hook)
            # still runs after the flag is raised
            self._prev_handler = signal.signal(signal.SIGTERM,
                                               self._on_signal)

    def _on_signal(self, signum=None, frame=None):
        self._flag = True
        if callable(self._prev_handler):
            self._prev_handler(signum, frame)

    def request(self):
        self._flag = True

    def should_stop(self) -> bool:
        if self._flag:
            return True
        return bool(self.flag_file and os.path.exists(self.flag_file))


@dataclass
class ElasticPlan:
    """Given surviving hosts, pick the largest power-of-two data-parallel
    degree that the global batch divides by — the launcher restarts the
    job with this mesh and restores from the latest checkpoint (host
    arrays are mesh-agnostic; see checkpoint.manager)."""
    global_batch: int
    model_parallel: int

    def plan(self, alive_hosts: int, chips_per_host: int = 4):
        chips = alive_hosts * chips_per_host
        data = max(chips // self.model_parallel, 1)
        while data > 1 and (self.global_batch % data
                            or (data & (data - 1))):
            data -= 1
        return {"data": data, "model": self.model_parallel,
                "chips_used": data * self.model_parallel}
