"""Distributed checkpointing: atomic, async, elastic.

Layout: <dir>/step_<N>/ {manifest.json, leaf_<i>.npy ...} written to a
tmp dir and os.replace'd (atomic on POSIX).  Leaves are stored by
tree-path name, so restore works across *any* mesh shape — the loader
re-places each logical array under the current sharding (elastic
rescale).  An async writer thread keeps the step loop unblocked; `wait`
drains it (called before preemption exit)."""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading

import jax
import numpy as np


def _path_name(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 async_write: bool = True):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._q: queue.Queue = queue.Queue()
        self._async = async_write
        if async_write:
            self._worker = threading.Thread(target=self._run, daemon=True)
            self._worker.start()

    # ---- write -----------------------------------------------------------
    def save(self, step: int, tree):
        """Snapshot to host memory immediately; write async."""
        leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
        host = [( _path_name(p), np.asarray(a)) for p, a in leaves]
        if self._async:
            self._q.put((step, host))
        else:
            self._write(step, host)

    def _run(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            self._write(*item)
            self._q.task_done()

    def _write(self, step: int, host_leaves):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        names = []
        for i, (name, arr) in enumerate(host_leaves):
            np.save(os.path.join(tmp, f"leaf_{i}.npy"), arr)
            names.append(name)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "leaves": names}, f)
        shutil.rmtree(final, ignore_errors=True)
        os.replace(tmp, final)
        self._gc()

    def wait(self):
        if self._async:
            self._q.join()

    def _gc(self):
        steps = sorted(self.list_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ---- read ------------------------------------------------------------
    def list_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name,
                                               "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def restore(self, step: int, like=None, shardings=None):
        """Load a checkpoint.  `like` provides the pytree structure; when
        `shardings` (same structure) is given each leaf is device_put
        under it — this is the elastic-rescale path (host arrays are mesh
        agnostic)."""
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        arrays = [np.load(os.path.join(d, f"leaf_{i}.npy"))
                  for i in range(len(manifest["leaves"]))]
        if like is None:
            return {"step": manifest["step"], "arrays": arrays,
                    "names": manifest["leaves"]}
        treedef = jax.tree_util.tree_structure(like)
        tree = jax.tree_util.tree_unflatten(treedef, arrays)
        if shardings is not None:
            tree = jax.tree.map(jax.device_put, tree, shardings)
        out = dict(tree) if isinstance(tree, dict) else tree
        if isinstance(out, dict):
            out["step"] = manifest["step"]
        return out

    def restore_latest(self, like=None, shardings=None):
        steps = self.list_steps()
        if not steps:
            return None
        return self.restore(steps[-1], like=like, shardings=shardings)
