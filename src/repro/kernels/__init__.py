# Pallas TPU kernels for the compute hot-spots the paper optimizes:
#   ring_matmul      Z_2^32 / Z_2^64 GEMM on the MXU via signed int8
#                    digits (the TPU form of CrypTen's ring GEMM)
#   flash_attention  online-softmax attention (P1's permuted-plaintext
#                    hot loop; the §Perf memory-term lever)
#   softmax/rmsnorm  fused Pi_PPSM / Pi_PPLN plaintext evaluation
#   ssd_scan         chunked Mamba2 SSD for Pi_PPSSD
# ops.py = jit'd wrappers (interpret on CPU, compiled on TPU);
# ref.py = pure-jnp oracles used by tests/test_kernels.py sweeps.
from . import ops, ref  # noqa: F401
