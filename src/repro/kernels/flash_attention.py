"""Flash attention (forward) — the fix for the dominant memory-roofline
term the dry-run exposes at seq >= 4k: the naive path materializes the
(S, S) score matrix to HBM; here scores never leave VMEM.

Grid (B*H, S/bq, T/bk): the KV axis is the sequential minor dimension
carrying running max / sum / accumulator scratch (standard online
softmax).  Causal masking via absolute q/k positions; KV blocks entirely
above the diagonal are skipped with @pl.when."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  causal: bool, bq: int, bk: int, k_steps: int,
                  scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = (not causal) or (ki * bk <= qi * bq + bq - 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale        # (bq, d)
        k = k_ref[0].astype(jnp.float32)                # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32,
                                                       (bq, bk), 0)
            k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32,
                                                       (bq, bk), 1)
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == k_steps - 1)
    def _done():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def flash_attention_p(q, k, v, *, causal: bool = True, bq: int = 128,
                      bk: int = 128, interpret: bool = True):
    """q: (B, H, S, D); k, v: (B, H, T, D) -> (B, H, S, D)."""
    B, H, S, D = q.shape
    T = k.shape[2]
    bq = max(min(bq, S), 1)
    while S % bq:
        bq -= 1
    bk = max(min(bk, T), 1)
    while T % bk:
        bk -= 1
    k_steps = T // bk
    scale = float(D) ** -0.5
    kernel = functools.partial(_flash_kernel, causal=causal, bq=bq,
                               bk=bk, k_steps=k_steps, scale=scale)
    q3 = q.reshape(B * H, S, D)
    k3 = k.reshape(B * H, T, D)
    v3 = v.reshape(B * H, T, D)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, S // bq, k_steps),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q3, k3, v3)
    return out.reshape(B, H, S, D)
