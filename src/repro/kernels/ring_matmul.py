"""Ring matmul mod 2^32 / 2^64 on the TPU MXU via signed base-256 digits.

The TPU adaptation of CrypTen's integer-ring GEMM (DESIGN.md §3): the
MXU has no int32/int64 path, but int8 x int8 -> int32 is native.  Each
int32 operand is decomposed into four signed digits d_i in [-128, 127]
(balanced base 256 with carry), so

    x . y  =  sum_{i,j}  (d_i(x) . d_j(y)) * 2^{8(i+j)}        (exact)

* mod 2^32 ("narrow"): terms with i+j > 3 vanish -> 10 int8 MXU dots,
  int32 accumulation (two's-complement wraparound IS mod 2^32).
* exact-mod-2^64 ("wide"): all 16 digit pairs accumulate into an int64
  scratch (int64 add/shift lowers to the VPU; the dots stay int8 MXU).
  Used by ops.ring64_matmul to compose the full Z_{2^64} GEMM out of
  one wide + two narrow passes.

Grid (M/bm, N/bn, K/bk); K is the sequential minor axis accumulating
into a VMEM scratch tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DIGITS = 4


def _signed_digits(x):
    """int32 -> (4 int8 digit planes, effective carry gamma in {-1,0,1}).

    Balanced base-256 digits reconstruct the *unsigned* low word:
    x_u == sum_i d_i 2^{8i} + carry * 2^32.  Relative to the signed
    value x_s = x_u - 2^32*[x<0], the digit sum is
    x_s - 2^32*gamma with gamma = carry - [x<0]; the narrow (mod 2^32)
    product drops gamma, the wide (mod 2^64) product adds the
    2^32-weighted gamma cross terms."""
    out = []
    carry = jnp.zeros_like(x)
    for i in range(DIGITS):
        limb = jnp.bitwise_and(jnp.right_shift(x, 8 * i), 0xFF) + carry
        d = jnp.bitwise_and(limb + 128, 0xFF) - 128
        carry = jnp.right_shift(limb - d, 8)
        out.append(d.astype(jnp.int8))
    neg = jnp.bitwise_and(jnp.right_shift(x, 31), 1)
    return out, (carry - neg).astype(jnp.int8)


def _ring_matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, wide: bool,
                        k_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    da, ca = _signed_digits(a_ref[...].astype(jnp.int32))
    db, cb = _signed_digits(b_ref[...].astype(jnp.int32))
    acc = acc_ref[...]
    for i in range(DIGITS):
        for j in range(DIGITS):
            p = i + j
            if not wide and p > 3:
                continue  # 2^{8p} == 0 mod 2^32
            dot = jax.lax.dot_general(
                da[i], db[j], (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32)
            if wide:
                acc += dot.astype(jnp.int64) << (8 * p)
            else:
                acc += dot << (8 * p)
    if wide:
        # digit sums represent x - carry*2^32: add the 2^32-weighted
        # cross terms (carry . digits), mod 2^32, shifted into the
        # high word (8 extra int8 dots)
        corr = jnp.zeros(acc.shape, jnp.int32)
        for j in range(DIGITS):
            corr += jax.lax.dot_general(
                ca, db[j], (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32) << (8 * j)
            corr += jax.lax.dot_general(
                da[j], cb, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32) << (8 * j)
        acc += corr.astype(jnp.int64) << 32
    acc_ref[...] = acc

    @pl.when(k == k_steps - 1)
    def _done():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("wide", "bm", "bn", "bk",
                                             "interpret"))
def ring_matmul_p(a, b, *, wide: bool = False, bm: int = 128,
                  bn: int = 128, bk: int = 128, interpret: bool = True):
    """a: (M, K) int32, b: (K, N) int32 -> (M, N) int32 (mod 2^32) or
    int64 (exact signed product accumulated mod 2^64) when wide."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, \
        (a.shape, b.shape, (bm, bn, bk))
    k_steps = K // bk
    out_dtype = jnp.int64 if wide else jnp.int32
    kernel = functools.partial(_ring_matmul_kernel, wide=wide,
                               k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid=(M // bm, N // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), out_dtype)],
        interpret=interpret,
    )(a.astype(jnp.int32), b.astype(jnp.int32))
