"""Chunked SSD scan (Mamba2) — P1's plaintext hot loop for Pi_PPSSD.

Grid (B, L/Q): the chunk axis is sequential, carrying the (H, P, N)
inter-chunk state in VMEM scratch.  Within a chunk the quadratic
attention-like form runs on the MXU; the state update is one outer
product + decay per chunk (vs per token in the naive recurrence)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, o_ref, state_ref, *,
                chunk: int, rep: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)          # (Q, H, P)
    dt = dt_ref[0].astype(jnp.float32)        # (Q, H)
    A = a_ref[...].astype(jnp.float32)        # (H,)
    Bv = b_ref[0].astype(jnp.float32)         # (Q, G, N)
    Cv = c_ref[0].astype(jnp.float32)
    Bh = jnp.repeat(Bv, rep, axis=1)          # (Q, H, N)
    Ch = jnp.repeat(Cv, rep, axis=1)

    a = dt * A                                # (Q, H), <= 0
    cA = jnp.cumsum(a, axis=0)
    # intra-chunk quadratic part
    seg = cA[:, None, :] - cA[None, :, :]     # (Q, S, H)
    iot = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jot = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where((jot <= iot)[:, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("qhn,shn->qsh", Ch, Bh) * decay \
        * dt[None, :, :]
    y = jnp.einsum("qsh,shp->qhp", scores, x)
    # inter-chunk contribution from carried state
    state = state_ref[...]                    # (H, P, N)
    y = y + jnp.einsum("qhn,hpn->qhp", Ch, state) \
        * jnp.exp(cA)[:, :, None]
    # state update
    last = cA[-1:, :]                         # (1, H)
    w = jnp.exp(last - cA) * dt               # (Q, H)
    local = jnp.einsum("qhn,qhp,qh->hpn", Bh, x, w)
    state_ref[...] = state * jnp.exp(last[0])[:, None, None] + local
    o_ref[0] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_p(x, dt, A, B, C, *, chunk: int = 64,
               interpret: bool = True):
    """x: (Bt, L, H, P); dt: (Bt, L, H); A: (H,); B, C: (Bt, L, G, N)."""
    Bt, L, H, Pd = x.shape
    G, N = B.shape[2], B.shape[3]
    chunk = max(min(chunk, L), 1)
    while L % chunk:
        chunk -= 1
    rep = H // G
    kernel = functools.partial(_ssd_kernel, chunk=chunk, rep=rep)
    return pl.pallas_call(
        kernel,
        grid=(Bt, L // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, H, Pd), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, chunk, H), lambda b, c: (b, c, 0)),
            pl.BlockSpec((H,), lambda b, c: (0,)),
            pl.BlockSpec((1, chunk, G, N), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, chunk, G, N), lambda b, c: (b, c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, H, Pd), lambda b, c: (b, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Bt, L, H, Pd), x.dtype),
        scratch_shapes=[pltpu.VMEM((H, Pd, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C)
