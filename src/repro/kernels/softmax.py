"""Fused row softmax — P1's hot plaintext op in Pi_PPSM (DESIGN.md §4).

One pass per row block: rows live in VMEM, max/exp/sum/normalize fused
(vs 4 HBM round-trips unfused).  Rows up to ~1M fp32 elements fit VMEM
at bm=1; ops.py picks bm so bm * N * 4B stays under the VMEM budget."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _softmax_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    s = jnp.sum(e, axis=-1, keepdims=True)
    o_ref[...] = (e / s).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def softmax_p(x, *, bm: int = 8, interpret: bool = True):
    """Softmax over the last axis.  x: (..., M, N) flattened to (M', N)."""
    orig_shape = x.shape
    n = orig_shape[-1]
    x2 = x.reshape(-1, n)
    m = x2.shape[0]
    bm = max(min(bm, m), 1)
    while m % bm:
        bm -= 1
    out = pl.pallas_call(
        _softmax_kernel,
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(x2)
    return out.reshape(orig_shape)
