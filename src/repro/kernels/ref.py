"""Pure-jnp oracles for every Pallas kernel (the ground truth for the
shape/dtype sweep tests).  Deliberately naive implementations."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ring_matmul32_ref(a, b):
    """(M,K) x (K,N) int32 mod 2^32: int64 accumulate then truncate."""
    wide = a.astype(jnp.int64) @ b.astype(jnp.int64)  # wraps mod 2^64
    return jax.lax.convert_element_type(
        jnp.bitwise_and(wide, jnp.int64(0xFFFFFFFF)).astype(jnp.uint32),
        jnp.int32)


def ring_matmul_wide_ref(a, b):
    """Exact signed int32 GEMM accumulated mod 2^64 (int64 wraparound)."""
    return a.astype(jnp.int64) @ b.astype(jnp.int64)


def ring64_matmul_ref(a64, b64):
    """Z_{2^64} GEMM: native int64 matmul (wraparound is the ring op)."""
    return a64 @ b64


def softmax_ref(x, axis=-1):
    return jax.nn.softmax(x.astype(jnp.float32), axis=axis).astype(x.dtype)


def rmsnorm_ref(x, gamma, eps=1e-6):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (gamma.astype(jnp.float32) * xf
            * jax.lax.rsqrt(ms + eps)).astype(x.dtype)


def layernorm_ref(x, gamma, beta, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (gamma.astype(jnp.float32) * y
            + beta.astype(jnp.float32)).astype(x.dtype)


def flash_attention_ref(q, k, v, causal=True):
    """q,k,v: (B, H, S, D) -> (B, H, S, D)."""
    S, T = q.shape[2], k.shape[2]
    scores = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(
                            jnp.asarray(q.shape[-1], jnp.float32))
    if causal:
        mask = jnp.arange(T)[None, :] <= jnp.arange(S)[:, None]
        scores = jnp.where(mask[None, None], scores, -jnp.inf)
    p = jax.nn.softmax(scores, -1)
    return jnp.einsum("bhst,bhtd->bhsd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def ssd_scan_ref(x, dt, A, B, C):
    """Sequential SSD recurrence (obviously-correct oracle).

    x: (Bt, L, H, P); dt: (Bt, L, H); A: (H,); B, C: (Bt, L, G, N)."""
    Bt, L, H, Pd = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    Bh = jnp.repeat(B, rep, axis=2).astype(jnp.float32)
    Ch = jnp.repeat(C, rep, axis=2).astype(jnp.float32)

    def step(state, inp):
        xt, dtt, bt, ct = inp       # (Bt,H,P), (Bt,H), (Bt,H,N), (Bt,H,N)
        decay = jnp.exp(dtt * A)    # (Bt,H)
        upd = jnp.einsum("bh,bhp,bhn->bhpn", dtt,
                         xt.astype(jnp.float32), bt)
        state = state * decay[:, :, None, None] + upd
        y = jnp.einsum("bhpn,bhn->bhp", state, ct)
        return state, y

    init = jnp.zeros((Bt, H, Pd, N), jnp.float32)
    xs = (x.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
          Bh.transpose(1, 0, 2, 3), Ch.transpose(1, 0, 2, 3))
    _, ys = jax.lax.scan(step, init, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype)
