"""Jit'd public wrappers over the Pallas kernels.

`interpret` defaults to True on CPU (this container) and False on real
TPU; the composition logic (e.g. ring64_matmul out of narrow+wide
passes) is backend-independent.

`core.ring.ring_matmul` routes share GEMMs to `ring64_matmul` on TPU
for 2-D MXU-tileable operands (DESIGN.md §3); the host int64 matmul
covers everything else."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_p
from .ring_matmul import ring_matmul_p
from .rmsnorm import norm_p
from .softmax import softmax_p
from .ssd_scan import ssd_scan_p


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def ring_matmul32(a, b, **kw):
    """Z_{2^32} GEMM on the MXU (10 int8 digit dots)."""
    kw.setdefault("interpret", _default_interpret())
    return ring_matmul_p(a, b, wide=False, **kw)


def ring_matmul_wide(a, b, **kw):
    """Exact signed-int32 GEMM accumulated mod 2^64 (16 digit dots)."""
    kw.setdefault("interpret", _default_interpret())
    return ring_matmul_p(a, b, wide=True, **kw)


def ring64_matmul(a64, b64, **kw):
    """Z_{2^64} GEMM from 32-bit halves (DESIGN.md §3):

        x = lo(x) + 2^32 hi(x)   with lo = signed low word
        x.y mod 2^64 = wide(lo,lo') + 2^32 (lo.hi' + hi.lo')

    one wide pass (16 int8 dots) + two narrow passes (10 each)."""
    a_lo = jax.lax.convert_element_type(a64, jnp.int32)
    b_lo = jax.lax.convert_element_type(b64, jnp.int32)
    a_hi = jax.lax.convert_element_type(
        jnp.right_shift(a64 - a_lo.astype(jnp.int64), 32), jnp.int32)
    b_hi = jax.lax.convert_element_type(
        jnp.right_shift(b64 - b_lo.astype(jnp.int64), 32), jnp.int32)
    wide = ring_matmul_wide(a_lo, b_lo, **kw)
    cross = (ring_matmul32(a_lo, b_hi, **kw).astype(jnp.int64)
             + ring_matmul32(a_hi, b_lo, **kw).astype(jnp.int64))
    return wide + jnp.left_shift(cross, 32)


def softmax(x, **kw):
    kw.setdefault("interpret", _default_interpret())
    return softmax_p(x, **kw)


def rmsnorm(x, gamma, eps=1e-6, **kw):
    kw.setdefault("interpret", _default_interpret())
    return norm_p(x, gamma, eps=eps, layernorm=False, **kw)


def layernorm(x, gamma, beta, eps=1e-5, **kw):
    kw.setdefault("interpret", _default_interpret())
    return norm_p(x, gamma, beta, eps=eps, layernorm=True, **kw)


def flash_attention(q, k, v, causal=True, **kw):
    kw.setdefault("interpret", _default_interpret())
    return flash_attention_p(q, k, v, causal=causal, **kw)


def ssd_scan(x, dt, A, B, C, chunk=64, **kw):
    kw.setdefault("interpret", _default_interpret())
    return ssd_scan_p(x, dt, A, B, C, chunk=chunk, **kw)
