"""Fused RMSNorm / LayerNorm — P1's Pi_PPLN plaintext evaluation.

Row-blocked: statistics and affine fused in VMEM (one HBM read + one
write per element instead of ~4)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _norm_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float,
                 subtract_mean: bool):
    x = x_ref[...].astype(jnp.float32)
    if subtract_mean:
        mu = jnp.mean(x, axis=-1, keepdims=True)
        x = x - mu
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps) * g_ref[...].astype(jnp.float32)
    if b_ref is not None:
        y = y + b_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "layernorm", "bm",
                                             "interpret"))
def norm_p(x, gamma, beta=None, *, eps: float = 1e-6,
           layernorm: bool = False, bm: int = 8, interpret: bool = True):
    """RMSNorm (default) or LayerNorm over the last axis."""
    orig_shape = x.shape
    n = orig_shape[-1]
    x2 = x.reshape(-1, n)
    m = x2.shape[0]
    bm = max(min(bm, m), 1)
    while m % bm:
        bm -= 1
    has_beta = beta is not None
    kernel = functools.partial(
        _norm_kernel if has_beta else
        (lambda xr, gr, orf, **kw: _norm_kernel(xr, gr, None, orf, **kw)),
        eps=eps, subtract_mean=layernorm)
    in_specs = [pl.BlockSpec((bm, n), lambda i: (i, 0)),
                pl.BlockSpec((n,), lambda i: (0,))]
    args = [x2, gamma]
    if has_beta:
        in_specs.append(pl.BlockSpec((n,), lambda i: (0,)))
        args.append(beta)
    out = pl.pallas_call(
        kernel,
        grid=(m // bm,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(*args)
    return out.reshape(orig_shape)
