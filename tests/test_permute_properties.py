"""Hypothesis property tests for core/permute.py — the index-vector
algebra every Centaur protocol rests on (inverse composition, arbitrary
-axis roundtrips, and equivalence with the paper's dense-matrix form).

Exactness note: dot-product checks use small integer-valued operands so
float reassociation cannot blur the comparison — the claims are
algebraic, not approximate."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis "
                           "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import permute  # noqa: E402

dims = st.integers(min_value=1, max_value=48)
seeds = st.integers(min_value=0, max_value=2 ** 30)


def _int_arr(seed, shape, lo=-8, hi=8):
    """Integer-valued float32 array: exact under matmul/permutation."""
    return jax.random.randint(jax.random.key(seed), shape, lo,
                              hi).astype(jnp.float32)


@settings(max_examples=25, deadline=None)
@given(dims, seeds)
def test_inv_perm_composes_to_identity(n, seed):
    p = np.asarray(permute.gen_perm(jax.random.key(seed), n))
    inv = np.asarray(permute.inv_perm(jnp.asarray(p)))
    np.testing.assert_array_equal(p[inv], np.arange(n))
    np.testing.assert_array_equal(inv[p], np.arange(n))


@settings(max_examples=25, deadline=None)
@given(dims, seeds, st.integers(min_value=0, max_value=2),
       st.booleans())
def test_apply_perm_roundtrip_on_arbitrary_axis(n, seed, axis,
                                                inv_first):
    shape = [3, 4, 5]
    shape[axis] = n
    x = _int_arr(seed, tuple(shape))
    p = permute.gen_perm(jax.random.key(seed + 1), n)
    if inv_first:
        y = permute.apply_perm(permute.apply_inv_perm(x, p, axis), p,
                               axis)
    else:
        y = permute.apply_inv_perm(permute.apply_perm(x, p, axis), p,
                                   axis)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


@settings(max_examples=25, deadline=None)
@given(dims, seeds)
def test_perm_matrix_matches_gather(n, seed):
    """X @ Pi == apply_perm(X, p, -1) — the dense 0/1 matrix of the
    paper and the O(n) gather are the same linear map."""
    p = permute.gen_perm(jax.random.key(seed), n)
    x = _int_arr(seed + 1, (4, n))
    pi = permute.perm_matrix(p, jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(x @ pi),
        np.asarray(permute.apply_perm(x, p, axis=-1)))
    # a permutation matrix is orthogonal: Pi @ Pi^T = I
    np.testing.assert_array_equal(np.asarray(pi @ pi.T), np.eye(n))


@settings(max_examples=25, deadline=None)
@given(dims, dims, seeds)
def test_permute_linear_equals_matrix_form(n_in, n_out, seed):
    """permute_linear's gathered W' reproduces the permuted linear map:
    apply_perm(x W^T + b, p_out) == apply_perm(x, p_in) W'^T + b'."""
    k = jax.random.key(seed)
    w = _int_arr(seed, (n_out, n_in))
    b = _int_arr(seed + 1, (n_out,))
    p_in = permute.gen_perm(jax.random.fold_in(k, 0), n_in)
    p_out = permute.gen_perm(jax.random.fold_in(k, 1), n_out)
    x = _int_arr(seed + 2, (2, n_in))

    wp, bp = permute.permute_linear(w, b, p_in, p_out)
    lhs = permute.apply_perm(x, p_in, -1) @ wp.T + bp
    rhs = permute.apply_perm(x @ w.T + b, p_out, -1)
    np.testing.assert_array_equal(np.asarray(lhs), np.asarray(rhs))
    # bias-free layers permute the same way
    wp2, bp2 = permute.permute_linear(w, None, p_in, p_out)
    assert bp2 is None
    np.testing.assert_array_equal(np.asarray(wp2), np.asarray(wp))
