"""Chaos sweep + crash-safe scheduler coverage (DESIGN.md §11).

Every fault class x {centaur, smpc} x {exact, chunked, decode-targeted}:
under injection the engine must either (a) deliver a request
token-identical to the fault-free run, or (b) mark it failed /
quarantined and deliver nothing for it — never a corrupted output,
never a stuck slot — while per-request comm stats stay EXACTLY
sum-conserving (partial ticks of failed attempts included).

Value-corruption plans need concrete arrays, so the sweep runs eager
(decode_jit=False); the jit-path transport seam is unit-tested in
tests/test_fault_injection.py via comm.replay."""
import jax
import numpy as np
import pytest

from repro.configs.paper_models import GPT2_TINY
from repro.core import comm
from repro.models.registry import get_api
from repro.runtime import faults
from repro.runtime.fault_tolerance import PreemptionGuard
from repro.serving.engine import PrivateServingEngine

KEY = jax.random.key(7)
PROMPTS = [[1, 2, 3], [4, 5, 6, 7]]
NNEW, MAXLEN, SLOTS = 2, 12, 2

MODES = ("centaur", "smpc")
#: serving path -> engine kwargs ("decode" = exact path, decode-phase
#: fault targeting)
PATHS = {"exact": {}, "chunked": {"chunk_size": 4}, "decode": {}}


@pytest.fixture(scope="module")
def params():
    return get_api(GPT2_TINY).init_params(GPT2_TINY, jax.random.key(3))


def _engine(params, mode, path, **kw):
    kw = {"integrity": "paranoid", **PATHS[path], **kw}
    return PrivateServingEngine(
        GPT2_TINY, params, KEY, mode=mode, max_slots=SLOTS,
        max_len=MAXLEN, decode_jit=False, **kw)


def _serve(params, mode, path, injector=None, prompts=PROMPTS, **kw):
    eng = _engine(params, mode, path, **kw)
    rids = [eng.submit(p, max_new_tokens=NNEW) for p in prompts]
    with comm.ledger() as led:
        if injector is None:
            outs, stats = eng.run_to_completion()
        else:
            with faults.inject(injector):
                outs, stats = eng.run_to_completion()
    return rids, outs, stats, led, eng


_BASE = {}


def _baseline(params, mode, path):
    if (mode, path) not in _BASE:
        rids, outs, stats, led, eng = _serve(params, mode, path)
        assert all(stats[r]["status"] == "ok" for r in rids)
        _BASE[(mode, path)] = {r: outs[r] for r in rids}
    return _BASE[(mode, path)]


def _plan(kind: str, path: str) -> faults.FaultPlan:
    """One representative plan per fault class, targeted at the sweep
    cell's phase.  Prefill plans pin rid=0 where the hook knows the
    request; decode plans hit the shared batched tick."""
    phase = "decode" if path == "decode" else "prefill"
    pre = phase == "prefill"
    if kind in ("corrupt_open", "ring_wrap"):
        return faults.FaultPlan(kind, phase=phase,
                                rid=0 if pre else None, index=2)
    if kind == "pool_exhaust":
        return faults.FaultPlan(kind, phase=phase, index=3, persist=True)
    if kind == "dealer_fault":
        return faults.FaultPlan(kind, phase=phase, index=1)
    if kind == "transport_drop":
        return faults.FaultPlan(kind, phase=phase,
                                rid=0 if pre else None, index=4)
    return faults.FaultPlan("nan_logits", phase=phase, rid=0)


def _assert_contract(mode, rids, outs, stats, led, eng, base):
    # 1. no corrupted outputs: every delivered request is either
    #    bit-identical to the fault-free run or was never delivered
    #    (failed / quarantined).  Exact modes (centaur) are
    #    randomness-independent, so even RETRIED requests must match;
    #    smpc carries +-1LSB truncation noise under the retry's shifted
    #    key stream, so only untouched requests are pinned there.
    for r in rids:
        st = stats[r]
        if st["status"] in ("failed", "quarantined"):
            assert r not in outs
            assert st["retries"] >= 1
            continue
        assert st["status"] in ("ok", "retried")
        if st["status"] == "ok":
            assert st["retries"] == 0
            assert outs[r] == base[r], f"unaffected rid {r} diverged"
        elif mode == "centaur":
            assert outs[r] == base[r], f"retried rid {r} diverged"
    # 2. exact sum-conservation, failed attempts' partial comm included
    assert sum(s["rounds"] for s in stats.values()) == led.total_rounds()
    assert sum(s["online_bits"] for s in stats.values()) \
        == led.total_bits()
    assert sum(s["offline_bits"] for s in stats.values()) \
        == led.total_bits(False) - led.total_bits()
    # 3. no stuck slots, nothing left queued, engine still schedulable
    assert all(s is None for s in eng.slots)
    assert not eng.queue
    assert not eng.step()


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("path", tuple(PATHS))
@pytest.mark.parametrize("kind", faults.FAULT_KINDS)
def test_chaos_sweep(params, mode, path, kind):
    base = _baseline(params, mode, path)
    inj = faults.FaultInjector(_plan(kind, path))
    rids, outs, stats, led, eng = _serve(params, mode, path, inj)
    assert inj.fired, f"{kind} plan never fired on {mode}/{path}"
    _assert_contract(mode, rids, outs, stats, led, eng, base)
    # survived faults are visible in telemetry whenever the scheduler
    # had to intervene (some corruptions are absorbed harmlessly, e.g.
    # landing on a dummy slot row — then the log stays empty)
    h = eng.health()
    if any(stats[r]["status"] != "ok" for r in rids):
        assert eng.fault_log and h["faults"]
    assert h["slots"]["active"] == 0 and h["queue_depth"] == 0


def test_chaos_runs_are_bit_reproducible(params):
    """Same plans, same engine, same seed => same fired log, same
    outputs, same stats — chaos runs are debuggable replays."""
    runs = []
    for _ in range(2):
        inj = faults.FaultInjector(_plan("corrupt_open", "exact"),
                                   _plan("transport_drop", "decode"))
        rids, outs, stats, led, eng = _serve(params, "centaur", "exact",
                                             inj)
        runs.append((inj.fired, outs, stats,
                     led.total_bits(False), led.total_rounds(False)))
    assert runs[0] == runs[1]


def test_quarantine_frees_slots_for_new_traffic(params):
    """A persistently-poisoned request quarantines; the engine then
    serves a fresh clean request token-identically to a fresh engine."""
    base = _baseline(params, "centaur", "exact")
    inj = faults.FaultInjector(
        faults.FaultPlan("transport_drop", phase="prefill", rid=0,
                         index=1, persist=True))
    eng = _engine(params, "centaur", "exact", max_retries=1,
                  retry_backoff=0)
    r0 = eng.submit(PROMPTS[0], max_new_tokens=NNEW)
    r1 = eng.submit(PROMPTS[1], max_new_tokens=NNEW)
    with faults.inject(inj):
        outs, stats = eng.run_to_completion()
    assert stats[r0]["status"] == "quarantined"
    assert stats[r0]["retries"] == 2          # max_retries + 1 attempts
    assert [q.rid for q in eng.quarantined] == [r0]
    assert outs[r1] == base[1], "healthy request disturbed"
    # partial comm of the two failed attempts stayed billed
    assert stats[r0]["online_bits"] > 0
    # the engine is NOT poisoned: clean traffic completes exactly
    r2 = eng.submit(PROMPTS[0], max_new_tokens=NNEW)
    outs, stats = eng.run_to_completion()
    assert stats[r2]["status"] == "ok" and outs[r2] == base[0]
    assert eng.health()["quarantined"] == [r0]


def test_retry_recovers_token_identical(params):
    """A one-shot prefill fault retries with backoff and finishes
    token-identical (exact mode is randomness-independent)."""
    base = _baseline(params, "centaur", "exact")
    inj = faults.FaultInjector(
        faults.FaultPlan("nan_logits", phase="prefill", rid=0))
    rids, outs, stats, led, eng = _serve(params, "centaur", "exact", inj)
    assert stats[rids[0]]["status"] == "retried"
    assert stats[rids[0]]["retries"] == 1
    assert outs[rids[0]] == base[rids[0]]
    assert outs[rids[1]] == base[rids[1]]
    assert [e.outcome for e in eng.fault_log] == ["retried"]


def test_persistent_decode_outage_fails_fleet_engine_survives(params):
    inj = faults.FaultInjector(
        faults.FaultPlan("pool_exhaust", phase="decode", index=0,
                         persist=True))
    rids, outs, stats, led, eng = _serve(params, "centaur", "decode",
                                         inj)
    assert all(stats[r]["status"] == "failed" for r in rids)
    assert sorted(f.rid for f in eng.failed) == sorted(rids)
    assert all(s is None for s in eng.slots)
    # conservation holds even when every request failed mid-decode
    assert sum(s["online_bits"] for s in stats.values()) \
        == led.total_bits()
    assert not eng.step()


def test_preemption_guard_drains_gracefully(params):
    guard = PreemptionGuard()
    eng = _engine(params, "centaur", "exact", preemption=guard)
    r0 = eng.submit(PROMPTS[0], max_new_tokens=4)
    r1 = eng.submit(PROMPTS[1], max_new_tokens=4)
    eng.step()                      # r0, r1 admitted and decoding
    guard.request()                 # preemption arrives mid-flight
    r2 = eng.submit(PROMPTS[0], max_new_tokens=4)
    outs, stats = eng.drain()
    assert eng.draining
    # active requests ran to their natural finish...
    assert len(outs[r0]) == 4 and len(outs[r1]) == 4
    assert stats[r0]["status"] == "ok"
    # ...and the queued request was never admitted, but not lost
    assert r2 not in outs
    assert [q.rid for q in eng.queue] == [r2]


def test_health_snapshot_shape(params):
    eng = _engine(params, "centaur", "exact")
    eng.submit(PROMPTS[0], max_new_tokens=1)
    eng.run_to_completion()
    h = eng.health()
    assert h["all_alive"] is True
    assert set(h["parties"]) == {"p0", "p1", "dealer"}
    assert set(h["parties"].values()) == {"alive"}
    assert h["pool"] is not None and h["pool"]["taken"]
    assert h["slots"] == {"total": SLOTS, "active": 0}
    assert h["quarantined"] == [] and h["failed"] == []
    assert h["faults"] == {} and h["ticks"] >= 1


def test_engine_config_validation_is_typed(params):
    with pytest.raises(faults.EngineConfigError):
        _engine(params, "centaur", "exact", max_retries=-1)
    with pytest.raises(faults.EngineConfigError):
        _engine(params, "centaur", "exact", retry_backoff=-1)
    with pytest.raises(faults.EngineConfigError):
        _engine(params, "centaur", "exact", integrity="sloppy")
    with pytest.raises(faults.EngineConfigError):
        PrivateServingEngine(GPT2_TINY, params, KEY, mode="telepathy")
    with pytest.raises(faults.EngineConfigError):
        PrivateServingEngine(GPT2_TINY, params, KEY, max_slots=0)
    with pytest.raises(faults.EngineConfigError):
        PrivateServingEngine(GPT2_TINY, params, KEY, max_len=1)


def test_submit_validation_is_typed(params):
    eng = _engine(params, "centaur", "exact")
    with pytest.raises(faults.InvalidRequest):
        eng.submit([], max_new_tokens=2)
    with pytest.raises(faults.InvalidRequest):
        eng.submit([1, 2], max_new_tokens=0)


def test_paranoid_guards_change_no_tokens_and_no_comm(params):
    """integrity="paranoid" must be a pure observer on a clean run:
    identical tokens, identical ledger totals."""
    eng_off = PrivateServingEngine(GPT2_TINY, params, KEY,
                                   max_slots=SLOTS, max_len=MAXLEN,
                                   decode_jit=False, integrity="off")
    rids = [eng_off.submit(p, max_new_tokens=NNEW) for p in PROMPTS]
    with comm.ledger() as led_off:
        outs_off, _ = eng_off.run_to_completion()
    rids2, outs_on, _, led_on, _ = _serve(params, "centaur", "exact")
    assert [outs_off[r] for r in rids] == [outs_on[r] for r in rids2]
    assert led_off.total_bits(False) == led_on.total_bits(False)
    assert led_off.total_rounds(False) == led_on.total_rounds(False)
