"""Transport runtime (DESIGN.md §14): the comm seam as a byte mover.

Parity contract: the default `LoopbackTransport` is bit-exact with the
pre-transport runtime, and the cross-process `SocketTransport` serves
end-to-end with IDENTICAL tokens and bit-exact online ledgers on every
servable mode and serving path — the wire carries the same shares the
SPMD simulation reconstructs, so moving real bytes changes nothing but
wall-clock.  The dealer-process pool (`dealer_proc=True`) is likewise
token- and ledger-identical: the service generates through the same
`beaver.gen_batch` on the same shipped PRG keys, and the async request
stream is deterministic.  Crash paths are exercised for real: a killed
dealer process surfaces `PoolExhausted` (§11), misses heartbeats, and
the engine survives on the degraded in-process pool; an injected
`transport_drop` over the socket is a genuine wire timeout.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_models import GPT2_TINY
from repro.core import beaver, comm
from repro.models.registry import get_api
from repro.runtime import faults
from repro.runtime.dealer_service import DealerClient, make_async_pool
from repro.runtime.transport import (LoopbackTransport, SocketTransport,
                                     make_transport)
from repro.serving.engine import PrivateServingEngine

SERVABLE = ("centaur", "smpc", "mpcformer", "secformer")
MAXLEN = 12
PROMPT = [1, 2, 3, 4, 5]

# exact / chunked / paged serving paths (decode runs in all of them)
PATHS = {
    "exact": {},
    "chunked": dict(chunk_size=4),
    "paged": dict(chunk_size=4, paged=True, page_size=4),
}


@pytest.fixture(scope="module")
def params():
    return get_api(GPT2_TINY).init_params(GPT2_TINY, jax.random.key(3))


def _events(led, online_only=True):
    return [(e.protocol, e.rounds, e.bits, e.tag, e.online)
            for e in led.events if e.online or not online_only]


def _serve(params, mode, *, max_new=2, decode_jit=False, **kw):
    eng = PrivateServingEngine(GPT2_TINY, params, jax.random.key(0),
                               mode=mode, max_slots=2, max_len=MAXLEN,
                               decode_jit=decode_jit, **kw)
    rid = eng.submit(list(PROMPT), max_new_tokens=max_new)
    with comm.ledger() as led:
        outs, _ = eng.run_to_completion()
    health = eng.health()
    eng.close()
    return outs[rid], _events(led), health


# =============================================================================
# transport unit seams
# =============================================================================

def test_loopback_exchange_is_identity_and_counts():
    t = LoopbackTransport()
    a = jnp.arange(6, dtype=jnp.int64).reshape(2, 3)
    out = t.exchange("matmul", (a,))
    assert out[0] is a
    t.exchange("reveal", (a,), reply=False)
    t.push("matmul", rounds=1, bits=128)
    s = t.stats()
    assert s["kind"] == "loopback" and not s["real"]
    assert s["messages"] == 3
    # echo counts both directions; one-way counts one; push bits//8
    assert s["bytes_moved"] == 2 * a.nbytes + a.nbytes + 16


def test_socket_exchange_roundtrip_bit_exact_and_wire_accounting():
    t = SocketTransport()
    try:
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.integers(-2**62, 2**62, (3, 4)), jnp.int64)
        b = jnp.asarray(rng.integers(-2**62, 2**62, (7,)), jnp.int64)
        ra, rb = t.exchange("matmul", (a, b))
        # the values came back off the wire, bit-for-bit
        assert ra.dtype == a.dtype and rb.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(ra), np.asarray(a))
        np.testing.assert_array_equal(np.asarray(rb), np.asarray(b))
        assert t.bytes_moved == 2 * (a.nbytes + b.nbytes)
        before = t.bytes_moved
        t.exchange("reveal", (a,), reply=False)      # one-way
        assert t.bytes_moved == before + a.nbytes
        t.push("softmax", rounds=2, bits=256)        # replayed event
        assert t.bytes_moved == before + a.nbytes + 2 * (256 // 16)
        assert t.stats()["peer_alive"]
    finally:
        t.close()
    assert not t.stats()["peer_alive"]


def test_make_transport_resolution():
    assert isinstance(make_transport(None), LoopbackTransport)
    assert isinstance(make_transport("loopback"), LoopbackTransport)
    t = LoopbackTransport()
    assert make_transport(t) is t
    with pytest.raises(faults.EngineConfigError):
        make_transport("carrier-pigeon")


def test_eager_open_values_and_ledger_survive_the_wire():
    """One eager matmul + reveal: values and billed events identical
    across no transport / loopback / socket, and the socket's wire
    bytes equal the billed bits exactly."""
    from repro.core import sharing

    rng = np.random.default_rng(1)
    k = jax.random.key(0)
    ka, kb, kd = jax.random.split(k, 3)
    x = sharing.share_float(ka, rng.standard_normal((4, 6)))
    y = sharing.share_float(kb, rng.standard_normal((6, 3)))

    results = {}
    for name, t in (("none", None), ("loopback", LoopbackTransport()),
                    ("socket", SocketTransport())):
        dealer = beaver.TripleDealer(kd)      # fresh: same triples
        with comm.transported(t), comm.ledger() as led:
            z = beaver.matmul(x, y, dealer)
            v = sharing.reveal(z)
        results[name] = (np.asarray(v), _events(led))
        if t is not None:
            t.close()
    for name in ("loopback", "socket"):
        np.testing.assert_array_equal(results[name][0],
                                      results["none"][0])
        assert results[name][1] == results["none"][1], name


# =============================================================================
# engine parity: loopback (default) vs socket, every mode x path
# =============================================================================

@pytest.mark.parametrize("mode", SERVABLE)
@pytest.mark.parametrize("path", sorted(PATHS))
def test_socket_engine_parity(params, mode, path):
    """Cross-process serving is bit-exact with the loopback default:
    identical tokens AND identical online ledgers (eager decode — the
    per-open exchange path)."""
    kw = PATHS[path]
    base_toks, base_ev, base_h = _serve(params, mode, **kw)
    sock_toks, sock_ev, sock_h = _serve(params, mode,
                                        transport="socket", **kw)
    assert sock_toks == base_toks, \
        f"{mode}/{path}: socket transport changed the decoded tokens"
    assert sock_ev == base_ev, \
        f"{mode}/{path}: socket transport changed the online ledger"
    assert base_h["transport"]["kind"] == "loopback"
    ts = sock_h["transport"]
    assert ts["kind"] == "socket" and ts["real"]
    assert ts["bytes_moved"] > 0 and ts["drops"] == 0


def test_socket_engine_parity_jit_replay(params):
    """The jit path (captured schedules, `comm.replay` -> push) over
    the socket: tokens identical to the loopback jit engine, and the
    replayed events move size-faithful bytes on the wire."""
    base_toks, base_ev, _ = _serve(params, "centaur", decode_jit=True)
    sock_toks, sock_ev, h = _serve(params, "centaur", decode_jit=True,
                                   transport="socket")
    assert sock_toks == base_toks
    assert sock_ev == base_ev
    assert h["transport"]["bytes_moved"] > 0


def test_socket_rtt_shaping_blocks_on_the_wire(params):
    """Injected RTT is realized as wall-clock spent inside the
    transport: wire_s >= rounds * rtt."""
    eng = PrivateServingEngine(GPT2_TINY, params, jax.random.key(0),
                               mode="centaur", max_slots=1,
                               max_len=MAXLEN, decode_jit=True,
                               transport="socket", rtt_ms=2.0)
    eng.submit(list(PROMPT), max_new_tokens=2)
    eng.run_to_completion()
    ts = eng.transport.stats()
    eng.close()
    assert ts["rounds"] > 0
    assert ts["wire_s"] >= ts["rounds"] * 0.002


# =============================================================================
# dealer process
# =============================================================================

def test_dealer_service_gen_batch_roundtrip_bit_exact():
    """The service generates through the same `beaver.gen_batch` on
    the shipped key: remote triples are bit-identical to local ones."""
    spec = beaver._canon_spec(("matmul", (4, 6), (6, 3)))
    key = jax.random.key(7)
    local = beaver.gen_batch(spec, key, 3)
    client = DealerClient.spawn()
    try:
        client.request(list(spec), jax.random.key_data(key), 3)
        deadline = time.monotonic() + 30.0
        got = []
        while not got and time.monotonic() < deadline:
            client.wait(0.1)
            got = client.pop_delivered()
        assert got, "dealer never delivered"
        rspec, remote = got[0]
        assert rspec == spec and len(remote) == 3
        for lt, rt in zip(local, remote):
            for ll, rl in zip(jax.tree.leaves(lt), jax.tree.leaves(rt)):
                np.testing.assert_array_equal(np.asarray(ll),
                                              np.asarray(rl))
    finally:
        client.close()


@pytest.mark.parametrize("mode", ("centaur", "smpc"))
def test_dealer_proc_engine_parity(params, mode):
    """dealer_proc=True serves token- and ledger-identically to the
    in-process pool: the async pool draws the same PRG stream and the
    service's generation is bit-exact."""
    base_toks, base_ev, _ = _serve(params, mode)
    dp_toks, dp_ev, h = _serve(params, mode, dealer_proc=True)
    assert dp_toks == base_toks, \
        f"{mode}: dealer process changed the decoded tokens"
    assert dp_ev == base_ev, \
        f"{mode}: dealer process changed the online ledger"
    pool = h["pool"]
    assert pool["dealer"]["alive"] and not pool["degraded"]
    assert pool["dealer"]["deliveries"] > 0
    assert h["parties"]["dealer"] == "alive"


def test_dealer_crash_mid_stream_quarantine_and_survival(params):
    """Kill the dealer process mid-stream: the in-flight take drains
    the pool and surfaces `PoolExhausted` (§11 — the engine retries /
    quarantines per policy), the dealer's heartbeat goes dead for
    real, and the engine survives to serve NEW traffic on the
    degraded in-process pool with correct tokens."""
    base_toks, _, _ = _serve(params, "centaur")
    eng = PrivateServingEngine(GPT2_TINY, params, jax.random.key(0),
                               mode="centaur", max_slots=2,
                               max_len=MAXLEN, decode_jit=False,
                               dealer_proc=True,
                               heartbeat_timeout=0.05)
    try:
        r0 = eng.submit(list(PROMPT), max_new_tokens=2)
        outs, _ = eng.run_to_completion()
        assert outs[r0] == base_toks
        # crash the producer between requests; the next take discovers
        # the dead stream (any prefetched stock drains first)
        eng.pm.dealer.dealer_client().kill()
        r1 = eng.submit(list(PROMPT), max_new_tokens=2)
        outs, stats = eng.run_to_completion()
        time.sleep(0.06)
        h = eng.health()
        assert h["parties"]["dealer"] == "dead", \
            "killed dealer process still heartbeating"
        assert h["pool"]["degraded"]
        # §11: the faulted request retried (or quarantined) and the
        # engine survived — the degraded pool serves the same tokens
        assert outs.get(r1) == base_toks
        assert stats[r1]["status"] in ("ok", "retried")
        if stats[r1]["retries"]:
            assert any(f.error == "PoolExhausted"
                       for f in eng.fault_log)
        # fresh traffic on the degraded pool
        r2 = eng.submit(list(PROMPT), max_new_tokens=2)
        outs, _ = eng.run_to_completion()
        assert outs[r2] == base_toks
        assert all(s is None for s in eng.slots)
    finally:
        eng.close()


def test_injected_dealer_fault_kills_real_process(params):
    """An injected dealer_fault against a real producer is a GENUINE
    crash: the process is killed, the engine retries on the degraded
    pool, and serving completes."""
    base_toks, _, _ = _serve(params, "centaur")
    eng = PrivateServingEngine(GPT2_TINY, params, jax.random.key(0),
                               mode="centaur", max_slots=1,
                               max_len=MAXLEN, decode_jit=False,
                               dealer_proc=True)
    try:
        client = eng.pm.dealer.dealer_client()
        inj = faults.FaultInjector(
            faults.FaultPlan("dealer_fault", phase="prefill"))
        rid = eng.submit(list(PROMPT), max_new_tokens=2)
        with faults.inject(inj):
            outs, stats = eng.run_to_completion()
        assert inj.fired, "dealer_fault never fired"
        assert not client.alive(), \
            "injected dealer fault left the real process running"
        assert eng.pm.dealer.degraded
        assert outs[rid] == base_toks
        assert stats[rid]["status"] == "retried"
    finally:
        eng.close()


# =============================================================================
# genuine transport faults
# =============================================================================

def test_transport_drop_is_a_real_wire_timeout(params):
    """transport_drop over the socket: the peer swallows the frame,
    the sender's bounded recv expires — a genuine TransportFault from
    the wire, driving the §11 retry path; the engine survives."""
    base_toks, _, _ = _serve(params, "centaur", max_new=3)
    eng = PrivateServingEngine(GPT2_TINY, params, jax.random.key(0),
                               mode="centaur", max_slots=1,
                               max_len=MAXLEN, decode_jit=False,
                               transport="socket")
    try:
        inj = faults.FaultInjector(
            faults.FaultPlan("transport_drop", phase="decode", index=2))
        rid = eng.submit(list(PROMPT), max_new_tokens=3)
        with faults.inject(inj):
            outs, stats = eng.run_to_completion()
        assert inj.fired, "transport_drop never fired"
        assert eng.transport.stats()["drops"] == len(inj.fired)
        assert eng.transport.stats()["peer_alive"]
        assert any(f.error == "TransportFault" for f in eng.fault_log)
        assert outs[rid] == base_toks      # retried to the same tokens
        assert all(s is None for s in eng.slots)
    finally:
        eng.close()


# =============================================================================
# pool telemetry (stock / health)
# =============================================================================

def test_pool_stock_watermarks_and_prefetch_counters():
    pool = beaver.TriplePool(jax.random.key(0), batch=4)
    spec = ("matmul", (2, 3), (3, 2))
    pool.reserve([spec, spec], steps=2)     # stock 4 of them
    for _ in range(5):                      # 4 hits + 1 miss-refill
        pool.take(spec)
    st = pool.stock()
    assert st["prefetch"]["hits"] == 4
    assert st["prefetch"]["misses"] == 1
    name, per = next(iter(st["per_spec"].items()))
    assert name.startswith("matmul[")
    assert per["taken"] == 5
    assert per["low_water"] == 0
    assert per["high_water"] >= 4
    # legacy keys survive (tests/launchers read them)
    assert set(st["taken"]) == {"matmul"}


def test_engine_health_surfaces_transport_and_prefetch(params):
    _, _, h = _serve(params, "centaur", chunk_size=4)
    assert "transport" in h and h["transport"]["kind"] == "loopback"
    pf = h["pool"]["prefetch"]
    assert pf["hits"] + pf["misses"] > 0
    assert "per_spec" in h["pool"]
