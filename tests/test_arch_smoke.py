"""Per-architecture smoke tests: reduced config, one forward/train step
on CPU, asserting output shapes and finiteness (assignment deliverable f).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.data.pipeline import make_batch
from repro.models.registry import get_api

KEY = jax.random.key(0)
B, S = 2, 32


def _setup(arch):
    cfg = get_config(arch, reduced=True)
    api = get_api(cfg)
    params = api.init_params(cfg, KEY)
    batch = make_batch(cfg, B, S, step=0)
    return cfg, api, params, batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_loss_finite(arch):
    cfg, api, params, batch = _setup(arch)
    loss = api.train_loss(cfg, params, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss={loss}"
    # loss should be near log(vocab) at init (uniform predictions)
    assert float(loss) < np.log(cfg.vocab_size) * 3


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_grads_finite(arch):
    cfg, api, params, batch = _setup(arch)
    grads = jax.grad(lambda p: api.train_loss(cfg, p, batch))(params)
    flat = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g, np.float32)))
               for g in flat), arch
    # at least one nonzero gradient
    assert any(float(jnp.abs(g.astype(jnp.float32)).max()) > 0
               for g in flat), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_shapes(arch):
    cfg, api, params, _ = _setup(arch)
    batch = make_batch(cfg, B, S, step=0, kind="serve")
    logits, cache, pos = api.prefill(cfg, params, batch, max_len=S + 8)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits)))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache = api.decode_step(cfg, params, cache, tok, pos)
    assert logits2.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits2)))


@pytest.mark.parametrize("arch", ["smollm-360m", "mamba2-130m",
                                  "zamba2-7b", "deepseek-v2-236b"])
def test_decode_matches_full_forward(arch):
    """KV-cache / SSM-state decode must reproduce the uncached forward."""
    cfg, api, params, _ = _setup(arch)
    batch = make_batch(cfg, B, S, step=0, kind="serve")
    toks = batch["tokens"]
    # full forward logits at position S-1 given prefix [0, S-1)
    hidden, _, _ = api.forward(cfg, params, {"tokens": toks})
    from repro.models import layers as L
    full_logits = L.lm_head(cfg, params.get("head", {}), params["embed"],
                            hidden[:, -2, :])
    # prefill on S-1 tokens, then decode token S-1
    pre = {"tokens": toks[:, :-1]}
    _, cache, pos = api.prefill(cfg, params, pre, max_len=S + 8)
    step_logits, _ = api.decode_step(cfg, params, cache,
                                     toks[:, -1:], pos)
    # step_logits predicts token S given prefix [0,S); full fwd at -1 does
    hidden2 = hidden[:, -1, :]
    full_last = L.lm_head(cfg, params.get("head", {}), params["embed"],
                          hidden2)
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(full_last),
                               atol=2e-2, rtol=2e-2)


def test_long_context_flags():
    assert get_config("mamba2-130m").supports_long_context
    assert get_config("zamba2-7b").supports_long_context
    assert not get_config("llama3-405b").supports_long_context


def test_param_count_sane():
    # full configs should land within ~35% of the advertised sizes
    approx = {
        "llama3-405b": 405e9, "minitron-4b": 4e9 * 1.05,
        "deepseek-coder-33b": 33e9, "smollm-360m": 360e6,
        "qwen2-vl-7b": 7e9, "mamba2-130m": 130e6,
        "zamba2-7b": 7e9, "deepseek-moe-16b": 16e9,
        "deepseek-v2-236b": 236e9,
    }
    for arch, want in approx.items():
        got = get_config(arch).param_count()
        assert 0.55 * want < got < 1.45 * want, (arch, got, want)
