"""Unit + property tests for the Centaur protocol core."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis "
                           "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import beaver, comm, nonlinear, permute, protocols, ring
from repro.core.sharing import (ShareTensor, reconstruct, reconstruct_float,
                                share, share_float)

KEY = jax.random.key(0)


def keys(n):
    return jax.random.split(KEY, n)


# ---------- ring -------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.floats(min_value=-1e3, max_value=1e3, allow_nan=False))
def test_ring_encode_decode_roundtrip(x):
    v = ring.decode(ring.encode(jnp.float32(x)))
    assert abs(float(v) - x) <= 2 ** -ring.FRAC_BITS + abs(x) * 1e-6


def test_ring_matmul_wraps_mod_2_64():
    a = jnp.array([[2 ** 62, 3]], dtype=jnp.int64)
    b = jnp.array([[4], [1]], dtype=jnp.int64)
    out = ring.ring_matmul(a, b)
    # 2^64 + 3 mod 2^64 == 3 in two's complement
    assert int(out[0, 0]) == 3


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=1, max_value=64),
       st.integers(min_value=1, max_value=32))
def test_fixed_point_matmul_error_bound(n, m):
    k1, k2 = keys(2)
    a = jax.random.normal(k1, (n, m))
    b = jax.random.normal(k2, (m, n))
    got = ring.decode(ring.fixed_point_matmul(ring.encode(a), ring.encode(b)))
    want = a @ b
    # one truncation: error <= m * encoding error + 1 LSB
    tol = (m + 2) * 2 ** -ring.FRAC_BITS
    np.testing.assert_allclose(got, want, atol=tol)


# ---------- sharing ----------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=1, max_value=100))
def test_share_reconstruct_identity(n):
    x = jax.random.normal(jax.random.key(n), (n,))
    st_ = share_float(jax.random.key(n + 1), x)
    np.testing.assert_allclose(reconstruct_float(st_), x,
                               atol=2 ** -ring.FRAC_BITS)


def test_share_is_uniformly_masked():
    x = jnp.zeros((4096,))
    s = share_float(KEY, x)
    # individual shares look uniform over the ring: huge std
    assert float(jnp.std(s.s0.astype(jnp.float64))) > 2 ** 60


def test_share_add_sub_public():
    k1, k2 = keys(2)
    x = jax.random.normal(k1, (8, 8))
    s = share_float(k2, x)
    y = reconstruct_float(s + ring.encode(1.5) - ShareTensor(
        jnp.zeros((8, 8), jnp.int64), jnp.zeros((8, 8), jnp.int64)))
    np.testing.assert_allclose(y, x + 1.5, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(st.floats(min_value=-30, max_value=30, allow_nan=False),
       st.floats(min_value=-30, max_value=30, allow_nan=False))
def test_local_truncation_error_one_lsb(a, c):
    """CrypTen local truncation: error <= 1 LSB after a public multiply."""
    s = share_float(jax.random.key(3), jnp.float32(a))
    prod = s.mul_public(ring.encode(jnp.float32(c)))
    got = float(reconstruct_float(prod))
    # encoding error of each operand is amplified by the other's magnitude
    tol = 2 ** -ring.FRAC_BITS * (3 + abs(a) + abs(c)) + abs(a * c) * 1e-4
    assert abs(got - a * c) <= tol


# ---------- beaver -----------------------------------------------------------

def test_beaver_matmul_matches_plaintext():
    k1, k2, k3, k4 = keys(4)
    x = jax.random.normal(k1, (6, 16)) * 2
    y = jax.random.normal(k2, (16, 5))
    dealer = beaver.TripleDealer(k3)
    with comm.ledger() as led:
        z = beaver.matmul(share_float(k4, x), share_float(k1, y), dealer)
    got = reconstruct_float(z)
    np.testing.assert_allclose(got, x @ y, atol=18 * 2 ** -ring.FRAC_BITS)
    # online cost: 1 round, 2*(6*16+16*5)*64 bits
    assert led.total_rounds() == 1
    assert led.total_bits() == 2 * (6 * 16 + 16 * 5) * 64


def test_beaver_matmul_square_matches_paper_table1():
    n = 12
    k1, k2, k3 = keys(3)
    x = share_float(k1, jax.random.normal(k1, (n, n)))
    y = share_float(k2, jax.random.normal(k2, (n, n)))
    with comm.ledger() as led:
        beaver.matmul(x, y, beaver.TripleDealer(k3))
    assert led.total_bits() == 256 * n * n  # Table 1: Pi_MatMul
    assert led.total_rounds() == 1


def test_beaver_elementwise_mul():
    k1, k2, k3 = keys(3)
    x = jax.random.normal(k1, (4, 7))
    y = jax.random.normal(k2, (4, 7))
    z = beaver.mul(share_float(k1, x), share_float(k2, y),
                   beaver.TripleDealer(k3))
    np.testing.assert_allclose(reconstruct_float(z), x * y, atol=3e-4)


def test_beaver_batched_matmul():
    k1, k2, k3 = keys(3)
    x = jax.random.normal(k1, (3, 4, 8))
    y = jax.random.normal(k2, (3, 8, 5))
    z = beaver.matmul(share_float(k1, x), share_float(k2, y),
                      beaver.TripleDealer(k3))
    np.testing.assert_allclose(reconstruct_float(z),
                               jnp.matmul(x, y), atol=1e-3)


# ---------- permutations ------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=2, max_value=64))
def test_perm_gather_equals_dense_matmul(n):
    p = permute.gen_perm(jax.random.key(n), n)
    x = jax.random.normal(jax.random.key(n + 1), (3, n))
    dense = x @ permute.perm_matrix(p).astype(x.dtype)
    np.testing.assert_allclose(permute.apply_perm(x, p, -1), dense)


def test_perm_inverse():
    p = permute.gen_perm(KEY, 17)
    x = jax.random.normal(KEY, (5, 17))
    np.testing.assert_allclose(
        permute.apply_inv_perm(permute.apply_perm(x, p), p), x)


def test_permute_linear_correctness():
    k1, k2, k3, k4 = keys(4)
    w = jax.random.normal(k1, (10, 8))
    b = jax.random.normal(k2, (10,))
    p_in = permute.gen_perm(k3, 8)
    p_out = permute.gen_perm(k4, 10)
    x = jax.random.normal(k1, (4, 8))
    wp, bp = permute.permute_linear(w, b, p_in, p_out)
    y = x @ w.T + b
    yp = permute.apply_perm(x, p_in, -1) @ wp.T + bp
    np.testing.assert_allclose(yp, permute.apply_perm(y, p_out, -1),
                               rtol=1e-5, atol=1e-5)


def test_brute_force_space_matches_paper():
    # paper §2.3: n=1280 -> 1/1280! ~ 2^-11372
    assert abs(permute.log2_brute_force_space(1280) - 11372) < 40


# ---------- protocols ---------------------------------------------------------

def test_scal_mul_is_free_and_correct():
    k1, k2 = keys(2)
    w = jax.random.normal(k1, (12, 8))
    x = jax.random.normal(k2, (5, 8))
    with comm.ledger() as led:
        y = protocols.linear(ring.encode(w), ring.encode(jnp.zeros(12)),
                             share_float(k1, x))
    np.testing.assert_allclose(reconstruct_float(y), x @ w.T, atol=1e-3)
    assert led.total_bits() == 0 and led.total_rounds() == 0


def test_ppp_gather_equals_exact_beaver_protocol():
    """Pi_PPP fast path (gather) must be bit-exact vs Algorithm 6."""
    n = 16
    k1, k2, k3, k4 = keys(4)
    x = share_float(k1, jax.random.normal(k2, (6, n)))
    p = permute.gen_perm(k3, n)
    fast = protocols.pp_permute(x, p, axis=-1)
    p_shared = share(k4, permute.perm_matrix(p))
    exact = protocols.pp_permute_exact(x, p_shared, beaver.TripleDealer(k4))
    np.testing.assert_array_equal(np.asarray(reconstruct(fast)),
                                  np.asarray(reconstruct(exact)))


def test_ppp_cost_matches_paper_table1():
    n = 20
    x = share_float(KEY, jax.random.normal(KEY, (n, n)))
    p = permute.gen_perm(KEY, n)
    with comm.ledger() as led:
        protocols.pp_permute(x, p)
    assert led.total_bits() == 256 * n * n
    assert led.total_rounds() == 1


# ---------- nonlinear ----------------------------------------------------------

def test_ppsm_exact_softmax_and_cost():
    n = 10
    k1, k2 = keys(2)
    x = jax.random.normal(k1, (n, n)) * 3
    p = permute.gen_perm(k2, n)
    xp = permute.apply_perm(x, p, -1)
    with comm.ledger() as led:
        y = nonlinear.pp_softmax(share_float(k1, xp), k2)
    got = reconstruct_float(y)
    want = permute.apply_perm(jax.nn.softmax(x, -1), p, -1)
    np.testing.assert_allclose(got, want, atol=5e-4)
    assert led.total_bits() == 128 * n * n  # Table 1: Pi_PPSM
    assert led.total_rounds() == 2


def test_ppgelu_exact():
    k1, k2 = keys(2)
    x = jax.random.normal(k1, (4, 32)) * 4
    y = nonlinear.pp_gelu(share_float(k1, x), k2)
    np.testing.assert_allclose(reconstruct_float(y),
                               jax.nn.gelu(x, approximate=False), atol=5e-4)


def test_ppln_permutation_equivariant():
    d = 24
    k1, k2, k3 = keys(3)
    x = jax.random.normal(k1, (6, d)) * 2 + 1
    gamma = jax.random.normal(k2, (d,)) + 1
    beta = jax.random.normal(k3, (d,))
    p = permute.gen_perm(k1, d)
    xp = permute.apply_perm(x, p, -1)
    y = nonlinear.pp_layernorm(share_float(k2, xp),
                               permute.apply_perm(gamma, p),
                               permute.apply_perm(beta, p), k3)
    mu = x.mean(-1, keepdims=True)
    var = x.var(-1, keepdims=True)
    want = gamma * (x - mu) / np.sqrt(var + 1e-5) + beta
    np.testing.assert_allclose(reconstruct_float(y),
                               permute.apply_perm(want, p, -1), atol=2e-3)


def test_pp_topk_router_under_expert_permutation():
    E, k = 16, 4
    k1, k2 = keys(2)
    logits = jax.random.normal(k1, (12, E))
    pe = permute.gen_perm(k2, E)
    gates, idx = nonlinear.pp_topk_router(
        share_float(k1, permute.apply_perm(logits, pe, -1)), k)
    probs = jax.nn.softmax(logits, -1)
    want_gates, want_idx = jax.lax.top_k(jax.nn.softmax(
        permute.apply_perm(logits, pe, -1), -1), k)
    want_gates = want_gates / want_gates.sum(-1, keepdims=True)
    np.testing.assert_allclose(gates, want_gates, atol=5e-4)
    # indices point at *permuted* experts — P1 never learns true ids
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(want_idx))


def test_comm_tags_breakdown():
    k1, k2 = keys(2)
    x = share_float(k1, jax.random.normal(k1, (8, 8)))
    with comm.ledger() as led:
        with comm.tag("softmax"):
            nonlinear.pp_softmax(x, k2)
        with comm.tag("linear"):
            protocols.scal_mul(ring.encode(jnp.eye(8)), x)
    tags = led.by_tag()
    assert tags["softmax"]["bits"] == 128 * 64
    assert tags["linear"]["bits"] == 0
