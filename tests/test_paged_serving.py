"""Paged share-domain KV cache serving (DESIGN.md §13).

The tentpole contracts:

* **Parity** — a paged engine produces bit-identical tokens to the
  dense slot-cache engine in every servable mode, under mixed prompt
  lengths, staggered admissions and page-reuse churn (a pool small
  enough that admissions defer and recycled pages get rewritten).
* **Batched admission** — one batched chunk tick per chunk index for a
  whole admission wave produces the same tokens as sequential
  admission, with exact sum-conserving per-request comm attribution.
* **Zero-on-free** — a page returned to the free list is zeroed across
  every layer, so a recycled page can never replay a prior request's
  open-mask (ek, bk) pairing.
* **Capacity, not faults** — page exhaustion defers admission and
  truncates decode growth; it never raises through the engine.
"""
import jax
import numpy as np
import pytest

from repro.configs.paper_models import GPT2_TINY
from repro.core import comm
from repro.models.registry import get_api
from repro.runtime import faults
from repro.serving.engine import PrivateServingEngine
from repro.serving.paging import PageAllocator

MAXLEN = 12
SERVABLE = ("centaur", "smpc", "mpcformer", "secformer")
# mixed lengths: sub-chunk, page-straddling, multi-page
MIXED = ([1, 2, 3, 4, 5], [6, 7], [8, 9, 10, 11, 12, 13, 14])


@pytest.fixture(scope="module")
def params():
    return get_api(GPT2_TINY).init_params(GPT2_TINY, jax.random.key(3))


def _engine(params, mode="centaur", **kw):
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_len", MAXLEN)
    kw.setdefault("decode_jit", False)
    kw.setdefault("chunk_size", 4)
    return PrivateServingEngine(GPT2_TINY, params, jax.random.key(7),
                                mode=mode, **kw)


def _serve_staggered(eng, prompts, max_new=2):
    """Deterministic staggered arrival schedule: two requests up
    front, one more every second tick — admissions overlap decodes of
    earlier requests, and (for a small page pool) force deferral and
    page recycling mid-run."""
    arrivals = list(prompts)
    for _ in range(min(2, len(arrivals))):
        eng.submit(arrivals.pop(0), max_new_tokens=max_new)
    steps = 0
    while (arrivals or eng.queue
           or any(s is not None for s in eng.slots)):
        eng.step()
        steps += 1
        if arrivals and steps % 2 == 0:
            eng.submit(arrivals.pop(0), max_new_tokens=max_new)
        assert steps < 300, "serving did not converge"
    return {r.rid: r.out for r in eng.finished}


# =============================================================================
# parity: paged == dense tokens, every servable mode
# =============================================================================

@pytest.mark.parametrize("mode", SERVABLE)
def test_paged_matches_dense_tokens(params, mode):
    dense = _engine(params, mode)
    out_d = _serve_staggered(dense, MIXED)
    # 5 allocatable pages < 2 slots * 3 pages: admissions defer and
    # freed pages are recycled mid-run
    paged = _engine(params, mode, paged=True, page_size=4, num_pages=6)
    out_p = _serve_staggered(paged, MIXED)
    assert out_d == out_p, \
        f"{mode}: paged tokens diverge from the dense slot cache"
    # eager page return: nothing live after the last eviction
    assert paged.alloc.used == 0
    assert paged.alloc.free_count == paged.alloc.total
    assert paged.alloc.high_water <= paged.alloc.total


def test_batched_prefill_matches_sequential(params):
    """4 simultaneous arrivals through one batched prefill per chunk
    index == one-request-at-a-time admission, token for token; the
    batched run's per-request stats stay exactly sum-conserving
    against the global ledger."""
    prompts = MIXED + ([2, 4, 6, 8],)

    def run(batch):
        eng = _engine(params, "centaur", paged=True, page_size=4,
                      batch_admission=batch, integrity="paranoid")
        for p in prompts:
            eng.submit(p, max_new_tokens=2)
        with comm.ledger() as led:
            out, stats = eng.run_to_completion()
        return out, stats, led

    out_s, _, _ = run(batch=False)
    out_b, stats, led = run(batch=True)
    assert out_s == out_b, "batched prefill changed tokens"
    # exact conservation: per-request bills sum to the global ledger
    billed = sum(s["online_bits"] + s["offline_bits"]
                 for s in stats.values())
    assert billed == led.total_bits(False), \
        "batched attribution broke sum-conservation"


def test_prefix_hit_tokens_match_no_prefix(params):
    """COW prefix reuse is a pure optimization: hit requests produce
    the same tokens as an engine with nothing registered, the hits are
    counted, and eviction drops the COW refs back to the registered
    baseline (the prefix itself stays cached)."""
    prefix = [5, 6, 7, 8]
    prompts = (prefix + [1, 2], prefix + [3], [9, 10])
    base = _engine(params, "centaur", paged=True, page_size=4)
    out_base = _serve_staggered(base, prompts)
    eng = _engine(params, "centaur", paged=True, page_size=4)
    assert eng.register_prefix(prefix) == 1
    out_hit = _serve_staggered(eng, prompts)
    assert out_base == out_hit, "prefix-cache hit changed tokens"
    assert eng.prefix_hits == 2
    assert eng.prefix_bits > 0
    # after every eviction only the registered prefix page stays live
    assert eng.alloc.used == 1
    assert int(eng.alloc.ref[eng._prefixes[tuple(prefix)]["pages"][0]]) == 1


# =============================================================================
# zero-on-free: a recycled page never replays a prior open-mask pairing
# =============================================================================

def test_recycled_page_is_zeroed(params):
    """Regression (satellite bugfix): serve a request, let eviction
    free its pages, and assert every freed page reads zero in every
    layer's ek/ev/bk/bv — the exact state of a never-written page, so
    a later request that recycles the page can never see the prior
    request's opened-value/mask pairing."""
    eng = _engine(params, "centaur", max_slots=1, paged=True,
                  page_size=4)
    eng.submit([1, 2, 3, 4, 5], max_new_tokens=2)
    eng.run_to_completion()
    assert eng.alloc.used == 0
    for layer in eng.pools:
        for arr in jax.tree.leaves(layer):
            assert not np.asarray(arr).any(), \
                "freed page left a stale open-mask pairing behind"
    # and the recycled pages serve a fresh request bit-identically
    eng.submit([7, 6, 5, 4, 3, 2], max_new_tokens=2)
    out = eng.run_to_completion()[0]
    fresh = _engine(params, "centaur", max_slots=1, paged=True,
                    page_size=4)
    fresh.submit([7, 6, 5, 4, 3, 2], max_new_tokens=2)
    # rid 0 on the fresh engine == rid 1 on the recycled engine
    assert fresh.run_to_completion()[0][0] == out[1], \
        "recycled pages changed tokens"


def test_decode_growth_exhaustion_truncates(params):
    """Decode needing a page from a dry pool finishes the request
    truncated (slot-capacity eviction class) — never a fault."""
    eng = _engine(params, "centaur", max_slots=1, paged=True,
                  page_size=4, num_pages=2)   # exactly one real page
    eng.submit([1, 2, 3, 4], max_new_tokens=5)
    out, stats = eng.run_to_completion()
    req = eng.finished[0]
    assert req.truncated and len(out[0]) == 1   # prefill token only
    assert not eng.fault_log
    assert eng.alloc.used == 0


# =============================================================================
# configuration + health surface
# =============================================================================

def test_paged_config_validation(params):
    with pytest.raises(faults.EngineConfigError):
        _engine(params, paged=True, chunk_size=None)   # needs chunking
    with pytest.raises(faults.EngineConfigError):
        _engine(params, paged=True, page_size=6)       # % chunk_size
    with pytest.raises(faults.EngineConfigError):
        _engine(params, paged=True, page_size=8)       # max_len % page
    dense = _engine(params)
    with pytest.raises(faults.EngineConfigError):
        dense.register_prefix([1, 2, 3, 4])            # paged-only
    paged = _engine(params, paged=True, page_size=4)
    with pytest.raises(faults.EngineConfigError):
        paged.register_prefix([1, 2])                  # < one page


def test_health_reports_page_census(params):
    eng = _engine(params, paged=True, page_size=4)
    eng.register_prefix([5, 6, 7, 8])
    h = eng.health()["pages"]
    assert h["total"] == 2 * (MAXLEN // 4)
    assert h["used"] == 1 and h["free"] == h["total"] - 1
    assert h["prefix_cached"] == 1 and h["prefix_bits"] > 0
    assert "pages" not in _engine(params).health()


# =============================================================================
# allocator unit tests (host-side, no protocol)
# =============================================================================

def test_allocator_alloc_release_lifo():
    a = PageAllocator(5, 4)
    assert a.total == 4 and a.free_count == 4
    got = a.alloc(3)
    assert got == [1, 2, 3] and a.used == 3 and a.high_water == 3
    assert a.alloc(2) is None and a.used == 3   # all-or-nothing
    assert a.release(2) is True                 # back on the free list
    assert a.alloc(1) == [2], "freed pages must be reused LIFO"
    assert a.high_water == 3


def test_allocator_cow_refcounts():
    a = PageAllocator(4, 2)
    (p,) = a.alloc(1)
    a.retain(p)
    assert a.release(p) is False                # still referenced
    assert a.release(p) is True
    with pytest.raises(faults.EngineConfigError):
        a.release(p)                            # double free
    with pytest.raises(faults.EngineConfigError):
        a.retain(p)                             # retain of free page
    with pytest.raises(faults.EngineConfigError):
        a.retain(0)                             # scratch is untouchable
    assert a.release(0) is False                # scratch no-op


def test_allocator_snapshot_restore():
    a = PageAllocator(6, 4)
    a.alloc(2)
    snap = a.snapshot()
    a.alloc(2)
    a.retain(1)
    a.restore(snap)
    assert a.used == 2 and a.free_count == 3
    assert int(a.ref[1]) == 1
