"""Collective-matmul overlap primitive: correctness on 8 virtual
devices (subprocess so the device-count flag stays isolated)."""
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

PROG = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.training.collective_matmul import tp_matmul_overlapped

mesh = jax.make_mesh((8,), ("model",))
k1, k2 = jax.random.split(jax.random.key(0))
a = jax.random.normal(k1, (64, 32), jnp.float32)
b = jax.random.normal(k2, (32, 48), jnp.float32)
with mesh:
    got = tp_matmul_overlapped(a, b, mesh)
np.testing.assert_allclose(np.asarray(got), np.asarray(a @ b),
                           rtol=2e-5, atol=2e-5)
# the lowered program must use ppermute (the overlap), not all-gather
hlo = jax.jit(lambda x, y: tp_matmul_overlapped(x, y, mesh)).lower(
    a, b).compile().as_text()
assert "collective-permute" in hlo, "expected ring ppermute schedule"
print("OK")
"""


def test_collective_matmul_correct_and_uses_ppermute():
    r = subprocess.run([sys.executable, "-c", PROG], capture_output=True,
                       text=True, env=dict(os.environ, PYTHONPATH=SRC),
                       timeout=300)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout
