"""End-to-end private inference: Centaur output must equal plaintext
within fixed-point tolerance (paper Table 3 claim), baselines must show
their characteristic costs/errors."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.paper_models import BERT_TINY, GPT2_TINY
from repro.core import comm
from repro.core.private_model import build_private_model, private_forward
from repro.models.registry import get_api

KEY = jax.random.key(7)
B, S = 2, 16


def _plain_logits(cfg, params, tokens):
    api = get_api(cfg)
    if cfg.family == "encoder":
        from repro.models.transformer import encoder_classify
        return encoder_classify(cfg, params, {"tokens": tokens})
    hidden, _, _ = api.forward(cfg, params, {"tokens": tokens})
    from repro.models import layers as L
    return L.lm_head(cfg, params.get("head", {}), params["embed"], hidden)


def _setup(cfg):
    api = get_api(cfg)
    params = api.init_params(cfg, KEY)
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    return params, tokens


@pytest.mark.parametrize("cfg", [BERT_TINY, GPT2_TINY], ids=lambda c: c.name)
def test_centaur_equals_plaintext(cfg):
    params, tokens = _setup(cfg)
    pm = build_private_model(cfg, params, KEY, mode="centaur")
    with comm.ledger() as led:
        priv = private_forward(pm, tokens)
    plain = _plain_logits(cfg, params, tokens)
    if cfg.family == "encoder":
        np.testing.assert_allclose(np.asarray(priv), np.asarray(plain),
                                   atol=2e-2)
    else:
        priv_last = np.asarray(priv)[:, -1, :]
        plain_last = np.asarray(plain)[:, -1, :]
        np.testing.assert_allclose(priv_last, plain_last, atol=5e-2)
        # argmax (i.e. generation) must agree
        np.testing.assert_array_equal(priv_last.argmax(-1),
                                      plain_last.argmax(-1))
    assert led.total_bits() > 0 and led.total_rounds() > 0


def test_centaur_llama_style_swiglu_rope_gqa():
    cfg = get_config("smollm-360m", reduced=True)
    params, tokens = _setup(cfg)
    pm = build_private_model(cfg, params, KEY, mode="centaur")
    priv = private_forward(pm, tokens)
    plain = _plain_logits(cfg, params, tokens)
    np.testing.assert_allclose(np.asarray(priv)[:, -1],
                               np.asarray(plain)[:, -1], atol=5e-2)


def test_centaur_moe_expert_permuted():
    cfg = get_config("deepseek-moe-16b", reduced=True)
    params, tokens = _setup(cfg)
    pm = build_private_model(cfg, params, KEY, mode="centaur")
    priv = private_forward(pm, tokens)
    plain = _plain_logits(cfg, params, tokens)
    # MoE plaintext uses capacity dispatch; centaur computes exact top-k.
    # With dropless reduced config these must agree.
    np.testing.assert_allclose(np.asarray(priv)[:, -1],
                               np.asarray(plain)[:, -1], atol=8e-2)


def test_centaur_mamba_ppssd():
    cfg = get_config("mamba2-130m", reduced=True)
    params, tokens = _setup(cfg)
    pm = build_private_model(cfg, params, KEY, mode="centaur")
    priv = private_forward(pm, tokens)
    plain = _plain_logits(cfg, params, tokens)
    np.testing.assert_allclose(np.asarray(priv)[:, -1],
                               np.asarray(plain)[:, -1], atol=5e-2)
    assert "SSD_in" in pm.exposed


def test_smpc_baseline_runs_and_costs_more():
    cfg = BERT_TINY
    params, tokens = _setup(cfg)
    with comm.ledger() as led_c:
        pm = build_private_model(cfg, params, KEY, mode="centaur")
        out_c = private_forward(pm, tokens)
    with comm.ledger() as led_s:
        pm_s = build_private_model(cfg, params, KEY, mode="smpc")
        out_s = private_forward(pm_s, tokens)
    plain = _plain_logits(cfg, params, tokens)
    # smpc approximations stay in the right ballpark
    assert np.all(np.isfinite(np.asarray(out_s)))
    # the paper's headline: centaur communicates several x less
    ratio = led_s.total_bits() / max(led_c.total_bits(), 1)
    assert ratio > 2.0, f"smpc/centaur comm ratio {ratio}"
    assert led_s.total_rounds() > led_c.total_rounds()
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(plain),
                               atol=2e-2)


def test_mpcformer_substitution_differs_from_plaintext():
    cfg = BERT_TINY
    params, tokens = _setup(cfg)
    pm = build_private_model(cfg, params, KEY, mode="mpcformer")
    out = private_forward(pm, tokens)
    plain = _plain_logits(cfg, params, tokens)
    # Quad/2Quad substitution changes the function (Table 3 w/o finetune)
    assert np.max(np.abs(np.asarray(out) - np.asarray(plain))) > 1e-3


def test_permute_mode_exposes_o1_centaur_does_not():
    cfg = BERT_TINY
    params, tokens = _setup(cfg)
    pm_p = build_private_model(cfg, params, KEY, mode="permute")
    out_p = private_forward(pm_p, tokens)
    plain = _plain_logits(cfg, params, tokens)
    # permute-only is plaintext-exact (paper: same performance)...
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(plain),
                               atol=2e-2)
    # ...but leaks O1 = QK^T in the clear
    assert "O1" in pm_p.exposed and "O2" in pm_p.exposed
    pm_c = build_private_model(cfg, params, KEY, mode="centaur")
    private_forward(pm_c, tokens)
    # centaur's recorded O1 is sequence-permuted (key axis): same values
    # per row as plaintext O1, different order
    o1_c = np.asarray(pm_c.exposed["O1"]).reshape(B, cfg.num_heads, S, S)
    o1_p = np.asarray(pm_p.exposed["O1"])
    assert o1_c.shape == o1_p.shape
    assert np.max(np.abs(o1_c - o1_p)) > 1e-2, "pi1 should reorder keys"
    np.testing.assert_allclose(np.sort(o1_c, -1), np.sort(o1_p, -1),
                               atol=2e-2)


def test_centaur_mla_deepseek_v2():
    """Private MLA: latent-permuted projections + paper attention flow."""
    cfg = get_config("deepseek-v2-236b", reduced=True)
    params, tokens = _setup(cfg)
    pm = build_private_model(cfg, params, KEY, mode="centaur")
    priv = private_forward(pm, tokens)
    plain = _plain_logits(cfg, params, tokens)
    np.testing.assert_allclose(np.asarray(priv)[:, -1],
                               np.asarray(plain)[:, -1], atol=8e-2)
    np.testing.assert_array_equal(
        np.asarray(priv)[:, -1].argmax(-1),
        np.asarray(plain)[:, -1].argmax(-1))


def test_centaur_private_kv_decode_matches_full_forward():
    """Private KV-cache decode == private full forward == plaintext."""
    from repro.core.private_model import (centaur_decode_step,
                                          centaur_prefill)
    cfg = GPT2_TINY
    params, tokens = _setup(cfg)
    pm = build_private_model(cfg, params, KEY, mode="centaur")
    logits_pre, caches = centaur_prefill(pm, tokens[:, :-1])
    step_logits, _ = centaur_decode_step(pm, caches, tokens[:, -1:],
                                         S - 1)
    plain = _plain_logits(cfg, params, tokens)
    np.testing.assert_allclose(np.asarray(step_logits)[:, 0],
                               np.asarray(plain)[:, -1], atol=5e-2)
    np.testing.assert_array_equal(
        np.asarray(step_logits)[:, 0].argmax(-1),
        np.asarray(plain)[:, -1].argmax(-1))


def test_centaur_hybrid_zamba2():
    """Private Zamba2: Pi_PPSSD mamba blocks + shared private attention
    block with SwiGLU — matches plaintext (completes private coverage
    of the assigned family pool)."""
    cfg = get_config("zamba2-7b", reduced=True)
    params, tokens = _setup(cfg)
    pm = build_private_model(cfg, params, KEY, mode="centaur")
    priv = private_forward(pm, tokens)
    plain = _plain_logits(cfg, params, tokens)
    np.testing.assert_allclose(np.asarray(priv)[:, -1],
                               np.asarray(plain)[:, -1], atol=8e-2)
    np.testing.assert_array_equal(
        np.asarray(priv)[:, -1].argmax(-1),
        np.asarray(plain)[:, -1].argmax(-1))


def test_centaur_whisper_encdec():
    """Private Whisper backbone: shared frame embeddings enter pi-space
    via Pi_PPP; cross-attention follows the paper's attention flow."""
    from repro.core.private_model import (prepare_whisper_private,
                                          whisper_private_forward)
    from repro.data.pipeline import make_batch
    cfg = get_config("whisper-tiny", reduced=True)
    api = get_api(cfg)
    params = api.init_params(cfg, KEY)
    batch = make_batch(cfg, 1, 16, step=0, kind="serve")
    pm = prepare_whisper_private(cfg, params, KEY)
    priv = whisper_private_forward(pm, batch["embeds"], batch["tokens"])
    from repro.models import whisper as W
    enc = W.encode(cfg, params, batch["embeds"])
    hid, _ = W.decode(cfg, params, batch["tokens"], enc)
    from repro.models import layers as L
    plain = L._dot(hid, params["embed"]["tok"])
    np.testing.assert_allclose(np.asarray(priv)[:, -1],
                               np.asarray(plain, np.float32)[:, -1],
                               atol=8e-2)
