"""Continuous-batching private serving (DESIGN.md §7).

The slot engine must be a pure performance transform over sequential
private serving: identical tokens (and identical to plaintext greedy
decoding), with the one batched ledger entry per tick split across
active requests exactly (per-request stats sum to the global ledger)."""
import jax
import numpy as np
import pytest

from repro.configs.paper_models import GPT2_TINY
from repro.core import comm
from repro.models.registry import get_api
from repro.runtime.faults import InvalidRequest
from repro.serving.engine import PrivateServingEngine, ServingEngine

KEY = jax.random.key(3)
# mixed prompt lengths; more requests than slots -> staggered admissions
PROMPTS = [[1, 2, 3], [7, 8], [9, 10, 11, 12], [3, 1], [5, 5, 5]]
NNEW, MAXLEN = 4, 20


@pytest.fixture(scope="module")
def params():
    return get_api(GPT2_TINY).init_params(GPT2_TINY, KEY)


def _serve(params, slots, decode_jit=True, prompts=PROMPTS):
    eng = PrivateServingEngine(GPT2_TINY, params, KEY, max_slots=slots,
                               max_len=MAXLEN, decode_jit=decode_jit)
    rids = [eng.submit(p, max_new_tokens=NNEW) for p in prompts]
    with comm.ledger() as led:
        outs, stats = eng.run_to_completion()
    return [outs[r] for r in rids], {r: stats[r] for r in rids}, led


def test_batched_tokens_match_sequential_and_plaintext(params):
    toks_b, _, _ = _serve(params, slots=3)   # 5 reqs / 3 slots
    toks_s, _, _ = _serve(params, slots=1)   # sequential baseline
    assert toks_b == toks_s, "continuous batching changed the tokens"

    eng = ServingEngine(GPT2_TINY, params, max_slots=1, max_len=MAXLEN)
    prids = [eng.submit(p, max_new_tokens=NNEW) for p in PROMPTS]
    pouts = eng.run_to_completion()
    assert toks_b == [pouts[r] for r in prids], \
        "private serving diverged from plaintext greedy decoding"


def test_eager_and_jit_slot_decode_agree(params):
    toks_j, _, led_j = _serve(params, slots=3, decode_jit=True)
    toks_e, _, led_e = _serve(params, slots=3, decode_jit=False)
    assert toks_j == toks_e
    # the captured static schedule must bill exactly like eager decode
    assert led_j.total_rounds() == led_e.total_rounds()
    assert led_j.total_bits() == led_e.total_bits()


def test_ledger_conservation_batched(params):
    """Per-request attributed stats sum exactly to the global ledger."""
    _, stats, led = _serve(params, slots=3)
    assert sum(s["rounds"] for s in stats.values()) == led.total_rounds()
    assert sum(s["online_bits"] for s in stats.values()) \
        == led.total_bits()
    assert sum(s["offline_bits"] for s in stats.values()) \
        == led.total_bits(False) - led.total_bits()
    assert all(s["online_bits"] > 0 for s in stats.values())


def test_single_slot_stats_match_isolated_requests(params):
    """With one slot the engine is sequential serving: each request's
    attributed online stats must equal what the same request bills when
    served alone in a fresh engine (comm.attribute with one key is the
    identity)."""
    _, stats_serial, _ = _serve(params, slots=1)
    for prompt, (rid, st) in zip(PROMPTS, sorted(stats_serial.items())):
        _, stats_alone, _ = _serve(params, slots=1, prompts=[prompt])
        alone = next(iter(stats_alone.values()))
        assert st["rounds"] == alone["rounds"], prompt
        assert st["online_bits"] == alone["online_bits"], prompt
        assert st["tokens"] == alone["tokens"], prompt


def test_attribute_is_exact_for_ragged_amounts():
    """comm.attribute conserves rounds/bits for amounts that don't
    divide evenly by the number of active slots."""
    events = [comm.CommEvent("matmul", 3, 1001, "linear", True),
              comm.CommEvent("dealer_triple", 1, 7, "linear", False),
              comm.CommEvent("ppsm", 2, 12345, "softmax", True)]
    per = comm.attribute(events, ["a", "b", "c"])
    for total_fn in (lambda led: led.total_rounds(False),
                     lambda led: led.total_bits(False)):
        split = sum(total_fn(led) for led in per.values())
        ref = total_fn(comm.CommLedger(list(events)))
        assert split == ref
    # online/offline flags survive the split
    assert all(not e.online for led in per.values()
               for e in led.events if e.protocol == "dealer_triple")
    # a single key gets the events back intact
    one = comm.attribute(events, ["only"])["only"]
    assert [(e.rounds, e.bits) for e in one.events] \
        == [(e.rounds, e.bits) for e in events]


def test_slot_engine_reuses_slots(params):
    """More requests than slots: every request finishes, slots turn
    over, and per-request outputs have the requested length."""
    many = PROMPTS + [[2, 4, 6], [8, 9]]
    toks, stats, _ = _serve(params, slots=2, prompts=many)
    assert all(len(t) == NNEW for t in toks)
    assert len(stats) == len(many)
    toks_seq, _, _ = _serve(params, slots=1, prompts=many)
    assert toks == toks_seq


def test_single_token_requests_and_length_cap(params):
    """A max_new_tokens=1 request gets exactly its prefill token (no
    extra decode tick), and requests that hit the length cap truncate
    by the same rule as the plaintext engine."""
    eng = PrivateServingEngine(GPT2_TINY, params, KEY, max_slots=2,
                               max_len=MAXLEN)
    r1 = eng.submit([1, 2, 3], max_new_tokens=1)
    r2 = eng.submit([4, 5], max_new_tokens=50)   # runs into the cap
    outs, stats = eng.run_to_completion()
    assert len(outs[r1]) == 1
    assert stats[r1]["tokens"] == 1

    peng = ServingEngine(GPT2_TINY, params, max_slots=2,
                         max_len=MAXLEN)
    p1 = peng.submit([1, 2, 3], max_new_tokens=1)
    p2 = peng.submit([4, 5], max_new_tokens=50)
    pouts = peng.run_to_completion()
    assert len(pouts[p1]) == 1
    assert outs[r2] == pouts[p2], "length-cap truncation diverged"


def test_overlong_prompt_shared_cap_policy(params):
    """One shared length-cap policy in RequestQueue.submit: a prompt
    longer than max_len - 1 is truncated to its first max_len - 1
    tokens and flagged, identically in the private and plaintext
    engines (the private engine used to crash on an assert; the
    plaintext engine used to overrun its cache silently)."""
    long_prompt = list(range(1, 40))
    outs = {}
    for name, eng in (("private",
                       PrivateServingEngine(GPT2_TINY, params, KEY,
                                            max_slots=2,
                                            max_len=MAXLEN)),
                      ("plain",
                       ServingEngine(GPT2_TINY, params, max_slots=2,
                                     max_len=MAXLEN))):
        rid = eng.submit(long_prompt, max_new_tokens=2)
        res = eng.run_to_completion()
        outs[name] = (res[0] if isinstance(res, tuple) else res)[rid]
        req = eng.finished[0]
        assert req.prompt == long_prompt[:MAXLEN - 1], name
        assert req.prompt_truncated, name
    assert outs["private"] == outs["plain"], \
        "length-cap truncation diverged between engines"
    # an in-cap prompt is never flagged
    eng = PrivateServingEngine(GPT2_TINY, params, KEY, max_slots=1,
                               max_len=MAXLEN)
    eng.submit([1, 2, 3], max_new_tokens=1)
    _, stats = eng.run_to_completion()
    st = next(iter(stats.values()))
    assert not st["prompt_truncated"] and not st["truncated"]
    # an empty prompt is rejected up front (no last-real-token exists;
    # the bucketed path would otherwise serve masked garbage silently)
    with pytest.raises(InvalidRequest):
        eng.submit([], max_new_tokens=1)


def test_truncated_flag_on_slot_capacity_eviction(params):
    """A request evicted at pos == max_len - 1 before reaching
    max_new_tokens is flagged `truncated` (it used to be dropped with
    no signal) and its per-request stats say so; a normally-finished
    request is not flagged."""
    eng = PrivateServingEngine(GPT2_TINY, params, KEY, max_slots=2,
                               max_len=MAXLEN)
    r_cut = eng.submit([4, 5], max_new_tokens=50)   # hits the cap
    r_ok = eng.submit([1, 2, 3], max_new_tokens=2)
    outs, stats = eng.run_to_completion()
    assert len(outs[r_cut]) < 50
    assert stats[r_cut]["truncated"]
    assert not stats[r_cut]["prompt_truncated"]
    assert stats[r_cut]["tokens"] == len(outs[r_cut])
    assert not stats[r_ok]["truncated"]
    # same signal on the plaintext engine's finished Request
    peng = ServingEngine(GPT2_TINY, params, max_slots=2,
                         max_len=MAXLEN)
    p_cut = peng.submit([4, 5], max_new_tokens=50)
    pouts = peng.run_to_completion()
    assert pouts[p_cut] == outs[r_cut]
    assert next(r for r in peng.finished if r.rid == p_cut).truncated


def test_padded_decode_matches_unbatched_private_forward(params):
    """The padded masked decode path reproduces the full private forward
    (and therefore the paper's fixed-point-exactness claim) token by
    token."""
    import jax.numpy as jnp
    from repro.core.private_model import (build_private_model,
                                          centaur_decode_step,
                                          centaur_prefill,
                                          private_forward)
    toks = [1, 2, 3]
    pm = build_private_model(GPT2_TINY, params, KEY, mode="centaur",
                             use_pool=True)
    logits, caches = centaur_prefill(
        pm, jnp.asarray([toks], jnp.int32), max_len=MAXLEN)
    out = [int(np.argmax(np.asarray(logits)[0]))]
    for i in range(2):
        logits, caches = centaur_decode_step(
            pm, caches, jnp.asarray([[out[-1]]], jnp.int32),
            len(toks) + i)
        out.append(int(np.argmax(np.asarray(logits)[0])))

    pm2 = build_private_model(GPT2_TINY, params, KEY, mode="centaur")
    seq = list(toks)
    for _ in range(3):
        full = private_forward(pm2, jnp.asarray([seq], jnp.int32))
        seq.append(int(np.argmax(np.asarray(full)[0, -1])))
    assert out == seq[len(toks):]
