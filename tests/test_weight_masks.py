"""Persistent weight-share masks (DESIGN.md §12).

Static weights are opened ONCE per engine lifetime against a persistent
dealer mask B_w (`beaver.open_weight`, billed under the `weight_open`
protocol); every later GEMM routes through `beaver.matmul_masked_f`, so
only the activation side E = X - A crosses the wire per call.  These
tests pin the protocol algebra (the masked product is the exact ring
product), the ledger contract (opened once, constant in tokens served,
never re-billed while serving), the dealer-seam billing that makes
eager and pooled offline ledgers bit-exact per `maskmul` triple, and
the headline comm win (an smpc decode tick's online bill dropped by
more than the 2x acceptance bar)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_models import GPT2_TINY
from repro.core import beaver, comm, ring
from repro.core.private_model import (build_private_model,
                                      init_chunk_state,
                                      init_slot_caches,
                                      private_decode_step,
                                      private_prefill,
                                      private_prefill_chunk)
from repro.core.sharing import reconstruct, share
from repro.models.registry import get_api
from repro.serving.engine import PrivateServingEngine

KEY = jax.random.key(5)


@pytest.fixture(scope="module")
def params():
    return get_api(GPT2_TINY).init_params(GPT2_TINY, KEY)


def _weight_open_bits(led):
    return sum(e.bits for e in led.events
               if e.protocol == "weight_open")


# =============================================================================
# protocol algebra + billing of the open itself
# =============================================================================

def test_open_weight_roundtrip_and_ledger():
    """F = W - B_w reconstructs the weight exactly (ring identity), and
    the one-time open bills 2*numel*RING_BITS online bits / 1 round
    under the `weight_open` protocol."""
    w = jax.random.normal(jax.random.key(0), (6, 8))
    sh = share(jax.random.key(1), ring.encode(w))
    dealer = beaver.TripleDealer(jax.random.key(2))
    with comm.ledger() as led:
        f, bw = beaver.open_weight(sh, dealer)
    np.testing.assert_array_equal(
        np.asarray(f + reconstruct(bw)),
        np.asarray(ring.encode(w)))
    wo = [e for e in led.events if e.protocol == "weight_open"]
    assert sum(e.bits for e in wo) == 2 * 48 * comm.RING_BITS
    assert sum(e.rounds for e in wo) == 1
    assert all(e.online for e in wo)


def test_masked_product_is_exact_ring_product():
    """matmul_masked_f against an opened weight equals plain Beaver
    matmul on the reconstructed ring value — bit-exact BEFORE
    truncation (rescale=False), fixed-point close after."""
    w = jax.random.normal(jax.random.key(0), (6, 8))
    x = jax.random.normal(jax.random.key(1), (3, 6))
    wsh = share(jax.random.key(2), ring.encode(w))
    xsh = share(jax.random.key(3), ring.encode(x))
    dealer = beaver.TripleDealer(jax.random.key(4))
    f, bw = beaver.open_weight(wsh, dealer)

    raw_m = reconstruct(beaver.matmul_masked_f(xsh, f, bw, dealer,
                                               rescale=False))
    raw_b = reconstruct(beaver.matmul(xsh, wsh, dealer, rescale=False))
    np.testing.assert_array_equal(np.asarray(raw_m), np.asarray(raw_b))

    z = ring.decode(reconstruct(
        beaver.matmul_masked_f(xsh, f, bw, dealer)))
    np.testing.assert_allclose(np.asarray(z), np.asarray(x @ w),
                               atol=1e-3)


def test_maskmul_offline_billing_identical_eager_vs_pool():
    """Satellite-3 seam: the dealer bills A + C = A@B delivery inside
    `maskmul_pair`, so the lazy dealer and the pool's generation-time
    billing are bit-exact per triple — the root cause of the old
    eager-vs-jit offline divergence for matmul_masked_f."""
    a_shape, b_shape = (3, 6), (6, 8)
    with comm.ledger() as led_e:
        beaver.TripleDealer(jax.random.key(0)).maskmul_pair(a_shape,
                                                            b_shape)
    pool = beaver.TriplePool(jax.random.key(0))
    with comm.ledger() as led_p:
        pool.maskmul_pair(a_shape, b_shape)
    eager = led_e.total_bits(False)
    pooled = led_p.total_bits(False)
    assert eager == pooled, (eager, pooled)
    # A (3,6) + C (3,8), both shares crossing the dealer seam
    assert eager == (18 + 24) * comm.RING_BITS * 2
    assert led_e.total_bits() == led_p.total_bits() == 0


# =============================================================================
# engine lifetime: opened once, constant in tokens served
# =============================================================================

@pytest.mark.parametrize("mode", ("smpc", "mpcformer"))
def test_weight_open_billed_once_regardless_of_tokens(params, mode):
    """`weight_open_bits` is charged at build and is constant in tokens
    served; serving itself never re-bills a weight open."""
    def serve(n_new):
        eng = PrivateServingEngine(GPT2_TINY, params, KEY, mode=mode,
                                   max_slots=1, max_len=12,
                                   decode_jit=False)
        with comm.ledger() as led:
            eng.submit([1, 2, 3], max_new_tokens=n_new)
            eng.run_to_completion()
        return eng, led

    eng2, led2 = serve(2)
    eng6, led6 = serve(6)
    assert eng2.weight_open_bits == eng6.weight_open_bits > 0
    assert _weight_open_bits(led2) == _weight_open_bits(led6) == 0, \
        f"{mode}: serving re-billed a persistent weight open"
    assert eng2.health()["weight_open_bits"] == eng2.weight_open_bits


def test_centaur_has_no_weight_opens(params):
    """Permuted-plaintext weights are never opened — the weight-mask
    protocol is an smpc-family concern."""
    eng = PrivateServingEngine(GPT2_TINY, params, KEY, mode="centaur",
                               max_slots=1, max_len=12,
                               decode_jit=False)
    assert eng.weight_open_bits == 0


# =============================================================================
# the measured win: decode-tick online bits
# =============================================================================

def test_smpc_decode_tick_online_bits_dropped_2x(params):
    """The acceptance bar: removing per-tick weight-side opens cuts the
    smpc decode tick's online bill by >= 2x at gpt2-tiny/4 slots.  The
    pre-change bill is reconstructed exactly: the old `matmul` opened
    F = W - B (2*numel(W)*RING_BITS) for every GEMM against a static
    weight, once per opened-weight tree per tick (tied embed/head
    opened twice, once per GEMM)."""
    pm = build_private_model(GPT2_TINY, params, KEY, mode="smpc")
    caches = init_slot_caches(pm, 4, 12)
    tok = jnp.ones((4, 1), jnp.int32)
    with comm.ledger() as led:
        private_decode_step(pm, caches, tok,
                            jnp.zeros((4,), jnp.int32))
    tick = led.total_bits()

    reopen = 0

    def walk(t):
        nonlocal reopen
        if isinstance(t, dict):
            if "f" in t and "m" in t:
                reopen += 2 * comm.numel(t["f"].shape) * comm.RING_BITS
            else:
                for v in t.values():
                    walk(v)
        elif isinstance(t, (list, tuple)):
            for v in t:
                walk(v)

    walk(pm.wp)
    assert reopen >= tick, \
        (f"decode tick {tick} online bits, weight re-opens removed "
         f"{reopen}: drop below the 2x acceptance bar")


# =============================================================================
# chunked prefill: head billed once per request
# =============================================================================

def test_chunk_head_runs_once_per_request(params):
    """Non-final chunks return None and bill NO adaptation-head events;
    the final chunk runs the head exactly once."""
    pm = build_private_model(GPT2_TINY, params, KEY, mode="smpc")
    state = init_chunk_state(pm, 1, 12)
    prompt = [1, 2, 3, 4, 5, 6, 7]
    lens = jnp.asarray([len(prompt)], jnp.int32)
    padded = prompt + [0]
    leds, logits = [], []
    for ci in range(2):
        toks = jnp.asarray([padded[ci * 4:(ci + 1) * 4]], jnp.int32)
        with comm.ledger() as led:
            lg, state = private_prefill_chunk(pm, state, toks, ci * 4,
                                              lens)
        leds.append(led)
        logits.append(lg)
    assert logits[0] is None, "non-final chunk returned head logits"
    assert logits[1] is not None
    head_events = [sum(1 for e in led.events if e.tag == "adaptation")
                   for led in leds]
    assert head_events[0] == 0, \
        "non-final chunk billed the adaptation head"
    assert head_events[1] > 0

    # the head output matches the exact-length prefill's argmax
    pm_x = build_private_model(GPT2_TINY, params, KEY, mode="smpc")
    lx, _ = private_prefill(pm_x, jnp.asarray([prompt], jnp.int32),
                            max_len=12)
    assert np.asarray(logits[1])[0].argmax() \
        == np.asarray(lx)[0].argmax()
