"""Ledger data-independence: the privacy contract behind the comm
accounting (paper §2.2 semi-honest model).

Everything a party could time or measure on the wire — event order,
protocol names, rounds, bits, online/offline flags — must be a function
of PUBLIC shapes only.  Two runs with identical public shapes but
different prompts and different model/share randomness must therefore
produce bit-identical comm ledgers in every servable mode, on every
serving path (exact prefill, bucketed prefill, chunked prefill, slot
decode).  Any data-dependent branch inside a suite (a value-dependent
comparison, an early exit, a content-keyed cache) fails this test."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.paper_models import GPT2_TINY
from repro.core import comm
from repro.core.private_model import (build_private_model,
                                      init_chunk_state,
                                      private_decode_step,
                                      private_forward,
                                      private_prefill,
                                      private_prefill_chunk)
from repro.models.registry import get_api
from repro.runtime import faults
from repro.serving.engine import PrivateServingEngine

SERVABLE = ("centaur", "smpc", "mpcformer", "secformer")
MAXLEN = 12
# identical PUBLIC shapes, different content and different randomness
RUNS = ((jax.random.key(0), [1, 2, 3, 4, 5]),
        (jax.random.key(99), [301, 7, 42, 250, 11]))


@pytest.fixture(scope="module")
def params():
    return get_api(GPT2_TINY).init_params(GPT2_TINY, jax.random.key(3))


def _events(led):
    return [(e.protocol, e.rounds, e.bits, e.tag, e.online)
            for e in led.events]


def _serving_ledger(params, mode, key, prompt):
    """Exact prefill + bucketed prefill + one chunked prefill + one
    batched decode tick, all eager (eager billing is the reference the
    jit capture/replay path is pinned against)."""
    pm = build_private_model(GPT2_TINY, params, key, mode=mode)
    toks = jnp.asarray([prompt], jnp.int32)
    lens = jnp.asarray([len(prompt)], jnp.int32)
    with comm.ledger() as led:
        _, caches = private_prefill(pm, toks, max_len=MAXLEN)
        private_prefill(pm, jnp.asarray([prompt + [0, 0, 0]], jnp.int32),
                        max_len=MAXLEN, lens=lens)
        state = init_chunk_state(pm, 1, MAXLEN)
        private_prefill_chunk(pm, state, toks[:, :4], 0, lens)
        private_decode_step(pm, caches,
                            jnp.asarray([[prompt[0]]], jnp.int32),
                            len(prompt))
    return led


@pytest.mark.parametrize("mode", SERVABLE)
def test_serving_ledger_is_data_independent(params, mode):
    leds = [_serving_ledger(params, mode, key, prompt)
            for key, prompt in RUNS]
    assert _events(leds[0]) == _events(leds[1]), \
        (f"{mode}: comm ledger depends on private data — a "
         f"data-dependent branch leaks through traffic analysis")


@pytest.mark.parametrize("mode", SERVABLE)
def test_serving_ledger_bit_identical_with_guards_on(params, mode):
    """DESIGN.md §11 contract: integrity="paranoid" guards are
    party-local computations on values a party already holds in
    plaintext — they must record ZERO ledger events, so the guarded
    ledger is bit-identical to the unguarded one on every serving
    path."""
    key, prompt = RUNS[0]
    base = _serving_ledger(params, mode, key, prompt)
    with faults.integrity("paranoid"):
        guarded = _serving_ledger(params, mode, key, prompt)
    assert _events(base) == _events(guarded), \
        f"{mode}: integrity guards changed the comm ledger"


@pytest.mark.parametrize("mode", ("centaur", "smpc"))
def test_engine_ledger_independent_and_guard_free(params, mode):
    """Engine-level version of both contracts at once: full serving
    runs with integrity off vs paranoid bill bit-identically, and the
    guarded ledgers stay data-independent across RUNS."""
    def engine_events(key, prompt, integrity):
        eng = PrivateServingEngine(GPT2_TINY, params, key, mode=mode,
                                   max_slots=2, max_len=MAXLEN,
                                   decode_jit=False,
                                   integrity=integrity)
        eng.submit(prompt, max_new_tokens=2)
        with comm.ledger() as led:
            eng.run_to_completion()
        return _events(led)

    guarded = []
    for key, prompt in RUNS:
        off = engine_events(key, prompt, "off")
        par = engine_events(key, prompt, "paranoid")
        assert off == par, f"{mode}: engine guards bill on the ledger"
        guarded.append(par)
    assert guarded[0] == guarded[1], \
        f"{mode}: engine comm ledger depends on private data"


@pytest.mark.parametrize("mode", ("centaur", "smpc"))
def test_paged_engine_ledger_is_data_independent(params, mode):
    """Paged serving (DESIGN.md §13) version of the contract: page
    allocation, page-table gathers and the COW prefix machinery are
    host-side bookkeeping over PUBLIC metadata (lengths, admission
    order), so two paged runs with equal public shapes must bill
    bit-identical ledgers across different prompts and keys — with
    integrity guards changing nothing."""
    def engine_events(key, prompt, integrity):
        eng = PrivateServingEngine(GPT2_TINY, params, key, mode=mode,
                                   max_slots=2, max_len=MAXLEN,
                                   decode_jit=False, chunk_size=4,
                                   paged=True, page_size=4,
                                   integrity=integrity)
        eng.submit(prompt, max_new_tokens=2)
        with comm.ledger() as led:
            eng.run_to_completion()
        return _events(led)

    guarded = []
    for key, prompt in RUNS:
        off = engine_events(key, prompt, "off")
        par = engine_events(key, prompt, "paranoid")
        assert off == par, f"{mode}: paged engine guards bill"
        guarded.append(par)
    assert guarded[0] == guarded[1], \
        f"{mode}: paged engine comm ledger depends on private data"


@pytest.mark.parametrize("mode", ("centaur", "smpc"))
def test_prefix_hit_changes_only_public_metadata(params, mode):
    """A prefix-cache HIT must be indistinguishable on the wire from a
    MISS with the same post-skip chunk count: both engines register the
    SAME prefix (identical pre-run history, incl. dealer-pool state),
    then one serves a prompt that starts with it and one serves a
    prompt that doesn't but runs the same number of chunk ticks.  The
    runs' ledgers must be bit-identical — a hit changes only the chunk
    count, public metadata of exactly the class (prompt length) the
    serving model already reveals."""
    prefix = [5, 6, 7, 8]                 # exactly one page

    def events(prompt, expect_hits):
        eng = PrivateServingEngine(GPT2_TINY, params, jax.random.key(1),
                                   mode=mode, max_slots=1,
                                   max_len=MAXLEN, decode_jit=False,
                                   chunk_size=4, paged=True, page_size=4)
        eng.register_prefix(prefix)       # fill bills OUTSIDE the run
        eng.submit(prompt, max_new_tokens=2)
        with comm.ledger() as led:
            eng.run_to_completion()
        assert eng.prefix_hits == expect_hits
        return _events(led)

    # hit: skips the prefix page, 1 live chunk tick for [1, 2, 3]
    hit = events(prefix + [1, 2, 3], expect_hits=1)
    # miss: no shared start, 1 live chunk tick for [9, 10, 11]
    miss = events([9, 10, 11], expect_hits=0)
    assert hit == miss, \
        (f"{mode}: a prefix hit leaks more than its chunk count — "
         f"hit events differ from an equal-chunk-count miss")


@pytest.mark.parametrize("mode", SERVABLE)
def test_weight_open_ledger_is_data_independent(params, mode):
    """The once-per-engine-lifetime weight-share opens (DESIGN.md §12)
    are wire traffic too: identical public shapes must produce
    bit-identical build-time ledgers — including the `weight_open`
    events — regardless of share/mask randomness, and serving after the
    build must never bill `weight_open` again."""
    leds = []
    for key, prompt in RUNS:
        with comm.ledger() as led:
            pm = build_private_model(GPT2_TINY, params, key, mode=mode)
        leds.append(led)
    assert _events(leds[0]) == _events(leds[1]), \
        f"{mode}: build-time (weight-open) ledger depends on randomness"
    wob = [sum(e.bits for e in led.events
               if e.protocol == "weight_open") for led in leds]
    assert wob[0] == wob[1]
    if mode != "centaur":   # centaur weights are permuted plaintext
        assert wob[0] > 0, f"{mode}: no weight opens billed at build"
    serve_led = _serving_ledger(params, mode, *RUNS[0])
    assert not any(e.protocol == "weight_open"
                   for e in serve_led.events), \
        f"{mode}: serving re-billed a persistent weight open"


@pytest.mark.parametrize("mode", ("centaur", "smpc"))
def test_engine_ledger_is_data_independent_over_real_transport(params,
                                                               mode):
    """DESIGN.md §14: moving the opens over a real socket (payload
    bytes on a TCP wire, peer process echoing shares back) must not
    change WHAT is billed — the online ledger stays bit-identical to
    loopback and stays data-independent across RUNS.  The transport is
    wire metadata only; if billing diverged here, the measured-RTT
    numbers would stop being evidence about the billed schedule."""
    def engine_events(key, prompt, transport):
        eng = PrivateServingEngine(GPT2_TINY, params, key, mode=mode,
                                   max_slots=2, max_len=MAXLEN,
                                   decode_jit=False,
                                   transport=transport)
        try:
            eng.submit(prompt, max_new_tokens=2)
            with comm.ledger() as led:
                eng.run_to_completion()
        finally:
            eng.close()
        return _events(led)

    socket_runs = []
    for key, prompt in RUNS:
        loop = engine_events(key, prompt, "loopback")
        sock = engine_events(key, prompt, "socket")
        assert loop == sock, \
            f"{mode}: the socket transport changed the billed ledger"
        socket_runs.append(sock)
    assert socket_runs[0] == socket_runs[1], \
        f"{mode}: real-transport ledger depends on private data"


@pytest.mark.parametrize("mode", SERVABLE + ("permute",))
def test_forward_ledger_is_data_independent(params, mode):
    """Same contract for the full-sequence forward of every mode
    (permute included: it must bill nothing, identically)."""
    leds = []
    for key, prompt in RUNS:
        pm = build_private_model(GPT2_TINY, params, key, mode=mode)
        with comm.ledger() as led:
            private_forward(pm, jnp.asarray([prompt], jnp.int32))
        leds.append(led)
    assert _events(leds[0]) == _events(leds[1]), \
        f"{mode}: forward ledger depends on private data"
