"""Protocol-suite parity (DESIGN.md §8).

The shared executor must be a pure refactor of every mode's forward:
greedy tokens decoded through the slot KV-cache path equal the mode's
own full-sequence forward (and the plaintext reference where the mode
computes the exact function), on plain MHA and GQA+SwiGLU+RoPE shapes,
and eager vs jitted suite runs bill bit-identical ledgers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.paper_models import GPT2_TINY
from repro.core import comm
from repro.core.private_model import (build_private_model,
                                      private_decode_step,
                                      private_forward, private_prefill)
from repro.models.registry import get_api

KEY = jax.random.key(11)
PROMPT = [1, 2, 3]
N_NEW = 2
MAXLEN = 8
SHARE_MODES = ("centaur", "smpc", "mpcformer", "secformer")


@pytest.fixture(scope="module")
def params():
    return get_api(GPT2_TINY).init_params(GPT2_TINY, KEY)


def _decode_greedy(cfg, params, mode, prompt, n_new, jit=True):
    """Greedy decode through the executor's prefill/decode path."""
    pm = build_private_model(cfg, params, KEY, mode=mode, use_pool=jit)
    toks = jnp.asarray([prompt], jnp.int32)
    logits, caches = private_prefill(pm, toks, max_len=MAXLEN, jit=jit)
    out = [int(np.argmax(np.asarray(logits)[0]))]
    for i in range(n_new - 1):
        logits, caches = private_decode_step(
            pm, caches, jnp.asarray([[out[-1]]], jnp.int32),
            len(prompt) + i, jit=jit)
        out.append(int(np.argmax(np.asarray(logits)[0])))
    return out


def _full_greedy(cfg, params, mode, prompt, n_new):
    """Greedy decode by re-running the full-sequence forward (the
    pre-executor 'legacy' serving strategy)."""
    pm = build_private_model(cfg, params, KEY, mode=mode)
    seq = list(prompt)
    for _ in range(n_new):
        full = private_forward(pm, jnp.asarray([seq], jnp.int32))
        seq.append(int(np.argmax(np.asarray(full)[0, -1])))
    return seq[len(prompt):]


def _plain_greedy(cfg, params, prompt, n_new):
    api = get_api(cfg)
    from repro.models import layers as L
    seq = list(prompt)
    for _ in range(n_new):
        hidden, _, _ = api.forward(
            cfg, params, {"tokens": jnp.asarray([seq], jnp.int32)})
        logits = L.lm_head(cfg, params.get("head", {}),
                           params["embed"], hidden)
        seq.append(int(np.argmax(np.asarray(logits)[0, -1])))
    return seq[len(prompt):]


@pytest.mark.parametrize("mode", SHARE_MODES)
def test_decode_matches_full_forward(params, mode):
    """Executor KV-cache greedy decode == the mode's full-sequence
    forward; exact/near-exact modes also match plaintext greedy.

    The smpc-family decode runs eagerly here (compiling the baselines'
    NR-iteration stacks is minutes of XLA work; the jitted smpc decode
    path is exercised end-to-end by the serving-engine test below,
    and eager==jit billing by the ledger test)."""
    jit = mode == "centaur"
    dec = _decode_greedy(GPT2_TINY, params, mode, PROMPT, N_NEW,
                         jit=jit)
    full = _full_greedy(GPT2_TINY, params, mode, PROMPT, N_NEW)
    assert dec == full, f"{mode}: decode diverged from full forward"
    if mode in ("centaur", "smpc"):
        # centaur computes the exact function; smpc's approximation
        # stays argmax-faithful on this reference workload
        assert dec == _plain_greedy(GPT2_TINY, params, PROMPT, N_NEW), \
            f"{mode}: decode diverged from plaintext greedy"


@pytest.mark.parametrize("mode", ("centaur", "smpc"))
def test_gqa_swiglu_rope_decode_parity(mode):
    """The executor owns GQA head grouping / SwiGLU / RoPE for every
    suite: llama-style shapes decode the same tokens through the cache
    path as through the full forward (centaur also == plaintext)."""
    cfg = get_config("smollm-360m", reduced=True)
    params = get_api(cfg).init_params(cfg, KEY)
    # mixed prompt lengths for the exact mode; one length for the
    # (much slower) approximate baseline
    prompts = [[5, 6], [9, 8, 7]] if mode == "centaur" else [[9, 8, 7]]
    for prompt in prompts:
        dec = _decode_greedy(cfg, params, mode, prompt, N_NEW,
                             jit=mode == "centaur")
        assert dec == _full_greedy(cfg, params, mode, prompt, N_NEW), \
            (mode, prompt)
        if mode == "centaur":
            assert dec == _plain_greedy(cfg, params, prompt, N_NEW), \
                prompt


def test_relu2_act_dispatch_centaur_exact():
    """Squared-ReLU archs (minitron-4b) must run relu2 — not a silent
    silu/gelu substitute — through the suite act dispatch; centaur
    stays plaintext-exact."""
    cfg = get_config("minitron-4b", reduced=True)
    params = get_api(cfg).init_params(cfg, KEY)
    tokens = jax.random.randint(KEY, (1, 8), 0, cfg.vocab_size)
    pm = build_private_model(cfg, params, KEY, mode="centaur")
    out = np.asarray(private_forward(pm, tokens))[0, -1]
    api = get_api(cfg)
    from repro.models import layers as L
    hidden, _, _ = api.forward(cfg, params, {"tokens": tokens})
    plain = np.asarray(L.lm_head(cfg, params.get("head", {}),
                                 params["embed"], hidden))[0, -1]
    np.testing.assert_allclose(out, plain, atol=5e-2)
    assert out.argmax(-1) == plain.argmax(-1)


def test_relu2_smpc_logits_track_plaintext():
    """Regression for the documented relu2 divergence: squared-ReLU
    archs push norm statistics into the hundreds-to-thousands, where
    smpc_inv_sqrt's bare fixed-range NR diverged and produced
    ~1-magnitude logit errors (argmax flips) vs the plaintext/centaur
    reference.  The public-bound power-of-two pre-scale
    (smpc.norm_stat_bound -> smpc_nl.smpc_inv_sqrt) must keep the smpc
    logits close and argmax-faithful."""
    cfg = get_config("minitron-4b", reduced=True)
    params = get_api(cfg).init_params(cfg, KEY)
    tokens = jax.random.randint(KEY, (1, 12), 0, cfg.vocab_size)
    pm = build_private_model(cfg, params, KEY, mode="smpc")
    out = np.asarray(private_forward(pm, tokens))[0, -1]
    api = get_api(cfg)
    from repro.models import layers as L
    hidden, _, _ = api.forward(cfg, params, {"tokens": tokens})
    plain = np.asarray(L.lm_head(cfg, params.get("head", {}),
                                 params["embed"], hidden))[0, -1]
    # was ~1.0 absolute logit error before the pre-scale (logit
    # magnitude ~3); the NR approximation noise now stays well under
    np.testing.assert_allclose(out, plain, atol=0.5)
    # argmax fidelity up to genuine near-ties: noise within the atol
    # above can flip tokens whose plaintext logits sit closer than the
    # noise bound (here top-2 gap ~0.1), so require the smpc pick to
    # be near-optimal under the PLAINTEXT logits — strict argmax
    # equality whenever the top-2 gap exceeds the bound
    assert plain.max() - plain[out.argmax(-1)] < 0.5, \
        (out.argmax(-1), plain.argmax(-1))


@pytest.mark.parametrize("mode", SHARE_MODES)
def test_eager_vs_jit_ledger_bit_exact(params, mode):
    """One executor, two execution strategies, one bill: the captured
    static schedule must reproduce the eager ledger exactly."""
    tokens = jax.random.randint(KEY, (1, 8), 0, GPT2_TINY.vocab_size)
    pm_e = build_private_model(GPT2_TINY, params, KEY, mode=mode)
    with comm.ledger() as led_e:
        private_forward(pm_e, tokens)
    pm_j = build_private_model(GPT2_TINY, params, KEY, mode=mode,
                               use_pool=True)
    with comm.ledger() as led_j:
        private_forward(pm_j, tokens, jit=True)
    assert led_e.total_bits() == led_j.total_bits()
    assert led_e.total_rounds() == led_j.total_rounds()
    # offline (dealer) traffic is intentionally NOT compared: the
    # vectorized pool generates batches ahead of demand, so its
    # generation-time billing legitimately differs from the lazy
    # dealer's exact-demand billing (DESIGN.md §5)


def test_smpc_engine_serves_plaintext_identical_tokens(params):
    """The acceptance bar of the suite refactor: the SMPC baseline,
    served through the SAME slot engine and executor as centaur,
    produces tokens identical to the plaintext greedy reference."""
    from repro.serving.engine import PrivateServingEngine, ServingEngine
    prompts = [[1, 2, 3], [7, 8]]
    eng = PrivateServingEngine(GPT2_TINY, params, KEY, mode="smpc",
                               max_slots=2, max_len=MAXLEN + 4)
    rids = [eng.submit(p, max_new_tokens=N_NEW) for p in prompts]
    outs, stats = eng.run_to_completion()
    peng = ServingEngine(GPT2_TINY, params, max_slots=2,
                         max_len=MAXLEN + 4)
    prids = [peng.submit(p, max_new_tokens=N_NEW) for p in prompts]
    pouts = peng.run_to_completion()
    assert [outs[r] for r in rids] == [pouts[r] for r in prids]
    # attribution still sum-conserving under the smpc suite
    assert all(s["online_bits"] > 0 for s in stats.values())
