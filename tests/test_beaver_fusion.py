"""Fused Beaver online phase, triple pool, ring-GEMM backend wiring and
the jitted private forward (DESIGN.md §3-§6).

The fusion contract: given the SAME dealer key (hence the same
triples), the fused block-stacked combine must produce bit-identical
shares to the unfused 5-GEMM reference, and the comm ledger (rounds and
bits, online and offline) must be unchanged."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import beaver, comm, ring
from repro.core.sharing import reconstruct_float, share, share_float

KEY = jax.random.key(0)


def _run_both(op, key, *mk_args):
    """Run op fused and unfused from identical dealer keys; return
    (fused ShareTensor, unfused ShareTensor, fused ledger, unfused
    ledger)."""
    outs, leds = [], []
    for fused in (True, False):
        with comm.ledger() as led:
            outs.append(op(beaver.TripleDealer(key), fused))
        leds.append(led)
    return outs[0], outs[1], leds[0], leds[1]


# ---- fused == unfused, bit for bit ------------------------------------------

@pytest.mark.parametrize("xs,ys", [
    ((6, 16), (16, 5)),           # plain 2-D
    ((1, 48), (48, 1)),           # degenerate dims
    ((3, 4, 8), (3, 8, 5)),       # batched
    ((2, 3, 4, 8), (2, 3, 8, 5)),  # doubly batched (attention shape)
    ((2, 5, 16), (16, 7)),        # batched lhs, rank-2 rhs (embedding)
])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_fused_matmul_bit_identical(xs, ys, seed):
    k1, k2, k3, k4 = jax.random.split(jax.random.key(seed), 4)
    x = share_float(k1, jax.random.normal(k2, xs) * 3)
    y = share_float(k3, jax.random.normal(k4, ys) * 3)

    zf, zu, lf, lu = _run_both(
        lambda d, fused: beaver.matmul(x, y, d, fused=fused), k1)
    np.testing.assert_array_equal(np.asarray(zf.s0), np.asarray(zu.s0))
    np.testing.assert_array_equal(np.asarray(zf.s1), np.asarray(zu.s1))
    for online_only in (True, False):
        assert lf.total_bits(online_only) == lu.total_bits(online_only)
        assert lf.total_rounds(online_only) == lu.total_rounds(online_only)


@pytest.mark.parametrize("seed", [0, 3])
def test_fused_mul_and_square_bit_identical(seed):
    k1, k2, k3 = jax.random.split(jax.random.key(seed), 3)
    x = share_float(k1, jax.random.normal(k2, (5, 9)) * 2)
    y = share_float(k2, jax.random.normal(k3, (5, 9)) * 2)

    zf, zu, lf, lu = _run_both(
        lambda d, fused: beaver.mul(x, y, d, fused=fused), k3)
    np.testing.assert_array_equal(np.asarray(zf.s0), np.asarray(zu.s0))
    np.testing.assert_array_equal(np.asarray(zf.s1), np.asarray(zu.s1))

    sf, su, lf, lu = _run_both(
        lambda d, fused: beaver.square(x, d, fused=fused), k3)
    np.testing.assert_array_equal(np.asarray(sf.s0), np.asarray(su.s0))
    np.testing.assert_array_equal(np.asarray(sf.s1), np.asarray(su.s1))
    assert lf.total_bits() == lu.total_bits()
    assert lf.total_bits(False) == lu.total_bits(False)


def test_fused_online_gemm_dispatch_counts():
    """Fused: ONE leading-dim-2 dispatch (2 block GEMMs, E@F folded).
    "stack" form: 2 dispatches (block stack + E@F).  Reference: 5."""
    k1, k2 = jax.random.split(KEY)
    a, b, c = beaver.TripleDealer(k1).matmul_triple((32, 32), (32, 32))
    e = ring.rand_ring(k1, (32, 32))
    f = ring.rand_ring(k2, (32, 32))

    def count(fused):
        before = ring.matmul_dispatches
        jax.eval_shape(
            lambda e_, f_: beaver.matmul_online(e_, f_, a, b, c, fused),
            e, f)
        return ring.matmul_dispatches - before

    assert count(True) == 1
    assert count("stack") == 2
    assert count(False) == 5


def test_fused_stack_variant_bit_identical():
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    x = share_float(k1, jax.random.normal(k2, (6, 16)) * 3)
    y = share_float(k3, jax.random.normal(k4, (16, 5)) * 3)
    zs, zu, _, _ = _run_both(
        lambda d, fused: beaver.matmul(
            x, y, d, fused="stack" if fused else False), k1)
    np.testing.assert_array_equal(np.asarray(zs.s0), np.asarray(zu.s0))
    np.testing.assert_array_equal(np.asarray(zs.s1), np.asarray(zu.s1))


# ---- triple pool ------------------------------------------------------------

def test_triple_pool_triples_are_valid():
    pool = beaver.TriplePool(KEY, batch=3)
    from repro.core.sharing import reconstruct
    a, b, c = pool.matmul_triple((8, 16), (16, 4))
    np.testing.assert_array_equal(
        np.asarray(ring.ring_matmul(reconstruct(a), reconstruct(b))),
        np.asarray(reconstruct(c)))
    a, b, c = pool.mul_triple((7,))
    np.testing.assert_array_equal(
        np.asarray(reconstruct(a) * reconstruct(b)),
        np.asarray(reconstruct(c)))
    a, c = pool.square_triple((5, 5))
    np.testing.assert_array_equal(
        np.asarray(reconstruct(a) * reconstruct(a)),
        np.asarray(reconstruct(c)))


def test_triple_pool_offline_billing_matches_dealer():
    """Pool offline bits for n triples == n lazy-dealer triples."""
    shapes = ((6, 16), (16, 5))
    with comm.ledger() as led_pool:
        pool = beaver.TriplePool(KEY, batch=4)
        pool.prefetch([("matmul", *shapes)] * 4)
    with comm.ledger() as led_lazy:
        d = beaver.TripleDealer(KEY)
        for _ in range(4):
            d.matmul_triple(*shapes)
    assert led_pool.total_bits(False) == led_lazy.total_bits(False)
    assert led_pool.total_bits() == led_lazy.total_bits() == 0
    # vectorized: ONE offline event for the whole batch
    assert len(led_pool.events) == 1 and len(led_lazy.events) == 4


def test_beaver_matmul_with_pool_dealer():
    k1, k2 = jax.random.split(KEY)
    x = jax.random.normal(k1, (6, 16))
    y = jax.random.normal(k2, (16, 5))
    pool = beaver.TriplePool(k2, batch=2)
    z = beaver.matmul(share_float(k1, x), share_float(k2, y), pool)
    np.testing.assert_allclose(reconstruct_float(z), x @ y,
                               atol=18 * 2 ** -ring.FRAC_BITS)
    # demand-proportional miss generation: one-shot shapes (e.g.
    # KV-decode GEMMs) generate exactly what they use ...
    spec = ("matmul", (6, 16), (16, 5))
    assert pool.size(spec) == 0
    beaver.matmul(share_float(k2, x), share_float(k1, y), pool)
    assert pool.size(spec) == 0
    # ... hot recurring shapes ramp up to batch-ahead generation
    beaver.matmul(share_float(k1, x), share_float(k2, y), pool)
    assert pool.size(spec) == 1


# ---- ring GEMM backend wiring ----------------------------------------------

def test_ring_matmul_pallas_backend_parity():
    """Forced pallas backend (interpret mode on CPU) must be
    bit-identical to the host int64 matmul on tile-eligible shapes."""
    k1, k2 = jax.random.split(KEY)
    a = ring.rand_ring(k1, (16, 64))
    b = ring.rand_ring(k2, (64, 32))
    host = ring.ring_matmul(a, b)
    ast = ring.rand_ring(k1, (2, 16, 32))  # fused-online party stack
    bst = ring.rand_ring(k2, (2, 32, 16))
    abig = ring.rand_ring(k1, (5, 8, 8))   # too deep a stack: host path
    bbig = ring.rand_ring(k2, (5, 8, 8))
    prev = ring.set_matmul_backend("pallas")
    try:
        pallas = ring.ring_matmul(a, b)
        stacked = ring.ring_matmul(ast, bst)
        batched = ring.ring_matmul(abig, bbig)
    finally:
        ring.set_matmul_backend(prev)
    np.testing.assert_array_equal(np.asarray(host), np.asarray(pallas))
    np.testing.assert_array_equal(np.asarray(stacked),
                                  np.asarray(jnp.matmul(ast, bst)))
    np.testing.assert_array_equal(np.asarray(batched),
                                  np.asarray(jnp.matmul(abig, bbig)))


@pytest.mark.parametrize("gen", ["rand", "extremes", "allones"])
def test_ring_matmul_f64_digit_exact(gen):
    """The host f64-digit GEMM must be bit-identical to the int64
    reference on all ring values (digit dots stay inside the f64
    mantissa — DESIGN.md §3)."""
    k1, k2 = jax.random.split(KEY)
    mk = {
        "rand": lambda k, s: ring.rand_ring(k, s),
        "extremes": lambda k, s: jnp.where(
            jax.random.bernoulli(k, 0.5, s),
            jnp.int64(-2 ** 63), jnp.int64(2 ** 63 - 1)),
        "allones": lambda k, s: jnp.full(s, -1, jnp.int64),
    }[gen]
    a = mk(k1, (96, 200))
    b = mk(k2, (200, 64))
    np.testing.assert_array_equal(
        np.asarray(ring._f64_digit_matmul(a, b)),
        np.asarray(jnp.matmul(a, b)))
    # batched form
    ab = mk(k1, (2, 3, 16, 40))
    bb = mk(k2, (2, 3, 40, 8))
    np.testing.assert_array_equal(
        np.asarray(ring._f64_digit_matmul(ab, bb)),
        np.asarray(jnp.matmul(ab, bb)))


def test_ring_matmul_auto_equals_forced_host():
    k1, k2 = jax.random.split(KEY)
    a = ring.rand_ring(k1, (64, 64))  # above the f64 MAC threshold
    b = ring.rand_ring(k2, (64, 64))
    auto = ring.ring_matmul(a, b)
    prev = ring.set_matmul_backend("host")
    try:
        host = ring.ring_matmul(a, b)
    finally:
        ring.set_matmul_backend(prev)
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(host))


def test_ring_matmul_pallas_eligibility():
    z = jnp.zeros
    assert ring._pallas_eligible(z((128, 256)), z((256, 128)))
    assert ring._pallas_eligible(z((16, 64)), z((64, 32)))
    assert not ring._pallas_eligible(z((200, 128)), z((128, 128)))
    assert not ring._pallas_eligible(z((2, 128, 128)), z((128, 128)))
    # the fused-online party stack (small equal leading dim) is served
    assert ring._pallas_eligible(z((2, 128, 256)), z((2, 256, 128)))
    assert not ring._pallas_eligible(z((8, 128, 128)), z((8, 128, 128)))
    # zero-sized dims fall through without dividing by zero
    assert not ring._pallas_eligible(z((0, 128)), z((128, 128)))


# ---- jitted private forward -------------------------------------------------

@pytest.mark.parametrize("mode", ["centaur", "smpc"])
def test_jit_forward_matches_eager_and_ledger_exact(mode):
    from repro.configs.paper_models import BERT_TINY as cfg
    from repro.core.private_model import build_private_model, \
        private_forward
    from repro.models.registry import get_api
    api = get_api(cfg)
    params = api.init_params(cfg, KEY)
    tokens = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)

    pm_e = build_private_model(cfg, params, KEY, mode=mode)
    with comm.ledger() as led_e:
        out_e = private_forward(pm_e, tokens)
    pm_j = build_private_model(cfg, params, KEY, mode=mode)
    with comm.ledger() as led_j:
        out_j = private_forward(pm_j, tokens, jit=True)
        # second call reuses the compiled layer and bills identically
        private_forward(pm_j, tokens, jit=True)

    atol = 5e-3 if mode == "centaur" else 5e-2
    np.testing.assert_allclose(np.asarray(out_e), np.asarray(out_j),
                               atol=atol)
    # ledger is exact: two jit forwards were billed => totals are 2x eager
    assert led_j.total_bits() == 2 * led_e.total_bits()
    assert led_j.total_rounds() == 2 * led_e.total_rounds()
    assert led_j.total_bits(False) == 2 * led_e.total_bits(False)


def test_jit_forward_share_is_fresh_random():
    """The jitted path reshares with fresh keys — outputs agree with
    eager semantics but shares differ call to call (masking intact)."""
    from repro.configs.paper_models import GPT2_TINY as cfg
    from repro.core.private_model import build_private_model, \
        centaur_forward_jit
    from repro.models.registry import get_api
    api = get_api(cfg)
    params = api.init_params(cfg, KEY)
    tokens = jax.random.randint(KEY, (1, 8), 0, cfg.vocab_size)
    pm = build_private_model(cfg, params, KEY, mode="centaur")
    o1 = centaur_forward_jit(pm, tokens)
    o2 = centaur_forward_jit(pm, tokens)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-3)
