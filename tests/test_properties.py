"""Hypothesis property tests on the system's core invariants:
permutation equivariance (the algebraic fact Centaur rests on), share
homomorphism, and the fixed-point error model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis "
                           "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import beaver, comm, permute, ring
from repro.core.sharing import ShareTensor, reconstruct_float, share_float

dims = st.integers(min_value=2, max_value=48)
seeds = st.integers(min_value=0, max_value=2 ** 30)


def _arr(seed, shape, scale=3.0):
    return jax.random.normal(jax.random.key(seed), shape,
                             jnp.float32) * scale


# ---- permutation equivariance (paper Eq. 7 generalized) ---------------------

@settings(max_examples=20, deadline=None)
@given(dims, seeds)
def test_softmax_permutation_equivariant(n, seed):
    x = _arr(seed, (3, n))
    p = permute.gen_perm(jax.random.key(seed + 1), n)
    lhs = jax.nn.softmax(permute.apply_perm(x, p, -1), -1)
    rhs = permute.apply_perm(jax.nn.softmax(x, -1), p, -1)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(dims, seeds)
def test_norm_stats_permutation_invariant(n, seed):
    """LayerNorm/RMSNorm statistics are invariant along the permuted
    axis — the reason Pi_PPLN works."""
    x = _arr(seed, (4, n))
    p = permute.gen_perm(jax.random.key(seed + 1), n)
    xp = permute.apply_perm(x, p, -1)
    np.testing.assert_allclose(np.asarray(x.mean(-1)),
                               np.asarray(xp.mean(-1)), atol=1e-5)
    np.testing.assert_allclose(np.asarray(jnp.var(x, -1)),
                               np.asarray(jnp.var(xp, -1)), atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(dims, seeds)
def test_gated_product_shares_single_permutation(n, seed):
    """SwiGLU invariance: (silu(a) * b) pi == silu(a pi) * (b pi)."""
    a, b = _arr(seed, (2, n)), _arr(seed + 1, (2, n))
    p = permute.gen_perm(jax.random.key(seed + 2), n)
    lhs = permute.apply_perm(jax.nn.silu(a) * b, p, -1)
    rhs = jax.nn.silu(permute.apply_perm(a, p, -1)) \
        * permute.apply_perm(b, p, -1)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(dims, dims, seeds)
def test_permuted_linear_roundtrip(n, m, seed):
    """The paper's core identity: X pi (W pi)^T == X W^T for any pi."""
    x = _arr(seed, (3, n))
    w = _arr(seed + 1, (m, n))
    p = permute.gen_perm(jax.random.key(seed + 2), n)
    wp, _ = permute.permute_linear(w, None, p, jnp.arange(m))
    lhs = permute.apply_perm(x, p, -1) @ wp.T
    rhs = x @ w.T
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               rtol=2e-4, atol=2e-4)


# ---- share homomorphism ------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(dims, seeds)
def test_share_addition_homomorphic(n, seed):
    x, y = _arr(seed, (n,)), _arr(seed + 1, (n,))
    sx = share_float(jax.random.key(seed + 2), x)
    sy = share_float(jax.random.key(seed + 3), y)
    np.testing.assert_allclose(np.asarray(reconstruct_float(sx + sy)),
                               np.asarray(x + y), atol=1e-4)
    np.testing.assert_allclose(np.asarray(reconstruct_float(sx - sy)),
                               np.asarray(x - y), atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 16), st.integers(2, 16), st.integers(2, 16), seeds)
def test_beaver_matmul_associates_with_plaintext(m, k, n, seed):
    x, y = _arr(seed, (m, k), 1.0), _arr(seed + 1, (k, n), 1.0)
    d = beaver.TripleDealer(jax.random.key(seed + 2))
    z = beaver.matmul(share_float(jax.random.key(seed + 3), x),
                      share_float(jax.random.key(seed + 4), y), d)
    np.testing.assert_allclose(np.asarray(reconstruct_float(z)),
                               np.asarray(x @ y),
                               atol=(k + 2) * 2 ** -14)


@settings(max_examples=15, deadline=None)
@given(dims, seeds)
def test_reshare_preserves_value_randomizes_shares(n, seed):
    from repro.core.sharing import reshare
    x = _arr(seed, (n,))
    s1 = share_float(jax.random.key(seed + 1), x)
    with comm.ledger():
        s2 = reshare(jax.random.key(seed + 2),
                     ring.encode(np.asarray(reconstruct_float(s1))))
    np.testing.assert_allclose(np.asarray(reconstruct_float(s2)),
                               np.asarray(x), atol=1e-3)
    assert not np.array_equal(np.asarray(s1.s0), np.asarray(s2.s0))


# ---- comm ledger algebra -------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(2, 32), st.integers(2, 32), seeds)
def test_matmul_comm_formula_any_shape(m, n, seed):
    """Pi_MatMul bits == 2*(numel(X)+numel(Y))*64 for any shapes."""
    k = 8
    x = share_float(jax.random.key(seed), _arr(seed, (m, k), 1.0))
    y = share_float(jax.random.key(seed + 1), _arr(seed + 1, (k, n), 1.0))
    with comm.ledger() as led:
        beaver.matmul(x, y, beaver.TripleDealer(jax.random.key(seed + 2)))
    assert led.total_bits() == 2 * (m * k + k * n) * 64
