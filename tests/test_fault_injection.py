"""Unit coverage for the fault-injection layer and integrity guards
(runtime.faults + the seams in core.{comm,beaver,sharing,nonlinear}).

Everything here is protocol-level (no serving engine): deterministic
plan matching, exact corruption semantics, hook/capture interactions,
and the party-local guards.  The engine-level chaos sweep lives in
tests/test_serving_faults.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import beaver, comm, nonlinear, ring
from repro.core.sharing import reveal, share
from repro.core.suites import masking
from repro.runtime import faults


# ---- typed hierarchy --------------------------------------------------------

def test_exception_hierarchy():
    assert issubclass(faults.PoolExhausted, faults.DealerFault)
    assert issubclass(faults.DealerFault, faults.ServingFault)
    assert issubclass(faults.ProtocolIntegrityError, faults.ServingFault)
    assert issubclass(faults.TransportFault, faults.ServingFault)
    # validation errors double as ValueError for idiomatic callers
    assert issubclass(faults.InvalidRequest, ValueError)
    assert issubclass(faults.EngineConfigError, ValueError)


def test_unknown_fault_kind_rejected():
    with pytest.raises(faults.EngineConfigError):
        faults.FaultPlan("meteor_strike")


def test_envelope_tracks_masking_constant():
    # nonlinear hardcodes the limit (importing masking there would be a
    # core<->suites cycle); this pin keeps the two in lockstep
    assert nonlinear.OPEN_ENVELOPE == 4.0 * masking.MASK_MAGNITUDE


# ---- hooks are no-ops without an injector ----------------------------------

def test_hooks_inert_without_injector():
    v = jnp.arange(4, dtype=jnp.int64)
    assert faults.on_open("matmul", v) is v
    faults.on_record("matmul", 1, 64)          # does not raise
    faults.on_take(("matmul", (2, 2), (2, 2)))
    faults.on_dealer("matmul")
    row = np.ones(3)
    assert faults.on_logits(0, row) is row


# ---- deterministic plan matching -------------------------------------------

def test_corrupt_open_hits_exact_row_and_call():
    plan = faults.FaultPlan("corrupt_open", site="matmul", index=1,
                            row=1, magnitude=100.0)
    inj = faults.FaultInjector(plan)
    v = jnp.zeros((3, 2), jnp.int64)
    with faults.inject(inj):
        a = faults.on_open("matmul", v)      # call 0: no fire
        b = faults.on_open("ppsm", v)        # site mismatch: not counted
        c = faults.on_open("matmul", v)      # call 1: fires
        d = faults.on_open("matmul", v)      # call 2: one-shot, done
    assert (np.asarray(a) == 0).all() and (np.asarray(b) == 0).all()
    assert (np.asarray(d) == 0).all()
    expect = 100 << ring.FRAC_BITS
    assert np.asarray(c)[1].tolist() == [expect, expect]
    assert (np.asarray(c)[[0, 2]] == 0).all()
    assert inj.fired == [("corrupt_open", "open", "matmul", "*", None, 1)]


def test_ring_wrap_flips_sign_bit():
    inj = faults.FaultInjector(faults.FaultPlan("ring_wrap", row=0))
    v = jnp.zeros((2,), jnp.int64)
    with faults.inject(inj):
        out = faults.on_open("matmul", v)
    # +2^63 mod 2^64 == int64 min: the canonical half-ring wrap
    assert int(np.asarray(out)[0]) == np.iinfo(np.int64).min
    assert int(np.asarray(out)[1]) == 0


def test_persist_keeps_firing():
    inj = faults.FaultInjector(
        faults.FaultPlan("transport_drop", index=2, persist=True))
    with faults.inject(inj):
        faults.on_record("matmul", 1, 64)
        faults.on_record("matmul", 1, 64)
        for _ in range(3):
            with pytest.raises(faults.TransportFault):
                faults.on_record("matmul", 1, 64)
    assert len(inj.fired) == 3


def test_phase_and_rid_filters():
    plan = faults.FaultPlan("nan_logits", phase="prefill", rid=7)
    inj = faults.FaultInjector(plan)
    row = np.ones(4)
    with faults.inject(inj):
        assert np.isfinite(faults.on_logits(7, row)).all()  # phase "*"
        with faults.phase("decode", rid=7):
            assert np.isfinite(faults.on_logits(7, row)).all()
        with faults.phase("prefill", rid=7):
            assert np.isfinite(faults.on_logits(3, row)).all()  # rid
            assert np.isnan(faults.on_logits(7, row)).all()     # fires
    # filtered-out calls must not advance the counter
    assert inj.fired[0][5] == 0


def test_injector_reset_reproduces():
    inj = faults.FaultInjector(
        faults.FaultPlan("transport_drop", index=1))
    log = []
    for _ in range(2):
        with faults.inject(inj):
            faults.on_record("matmul", 1, 64)
            with pytest.raises(faults.TransportFault):
                faults.on_record("matmul", 1, 64)
        log.append(list(inj.fired))
        inj.reset()
    assert log[0] == log[1]


# ---- seam integration -------------------------------------------------------

def test_transport_fault_bills_before_raising():
    """The failed message's bits are already in every ledger (the bytes
    crossed, then the ack never came) — partial accounting stays
    sum-conserving."""
    inj = faults.FaultInjector(faults.FaultPlan("transport_drop"))
    with comm.ledger() as led, faults.inject(inj):
        with pytest.raises(faults.TransportFault):
            comm.record("matmul", rounds=1, bits=128)
    assert led.total_bits() == 128
    assert led.total_rounds() == 1


def test_replay_transport_fault_bills_prefix():
    """The jit path (comm.replay of a captured schedule) drops at the
    same event an eager run would, with the prefix billed."""
    with comm.capture() as sched:
        comm.record("matmul", 1, 64)
        comm.record("ppsm", 2, 32)
        comm.record("matmul", 1, 64)
    inj = faults.FaultInjector(
        faults.FaultPlan("transport_drop", site="ppsm"))
    with comm.ledger() as led, faults.inject(inj):
        with pytest.raises(faults.TransportFault):
            comm.replay(sched.events)
    assert [e.protocol for e in led.events] == ["matmul", "ppsm"]
    assert led.total_bits() == 96


def test_open_masked_corruption_changes_reconstruction():
    key = jax.random.key(0)
    x = share(key, jnp.zeros((2, 2), jnp.int64))
    a = share(jax.random.split(key)[0], jnp.zeros((2, 2), jnp.int64))
    clean = beaver._open_masked(x, a, "matmul")
    inj = faults.FaultInjector(
        faults.FaultPlan("corrupt_open", site="matmul", row=1))
    with faults.inject(inj):
        dirty = beaver._open_masked(x, a, "matmul")
    assert (np.asarray(dirty)[0] == np.asarray(clean)[0]).all()
    assert (np.asarray(dirty)[1] != np.asarray(clean)[1]).all()


def test_reveal_seam_fires():
    st = share(jax.random.key(1), jnp.zeros((3,), jnp.int64))
    inj = faults.FaultInjector(
        faults.FaultPlan("corrupt_open", site="reveal", row=2))
    with faults.inject(inj):
        out = reveal(st)
    assert int(np.asarray(out)[2]) != 0


def test_pool_take_exhaustion_and_stock():
    pool = beaver.TriplePool(jax.random.key(2))
    spec = ("matmul", (2, 2), (2, 2))
    pool.take(spec)
    census = pool.stock()
    assert census["taken"] == {"matmul": 1}
    assert census["specs"] == 1
    inj = faults.FaultInjector(
        faults.FaultPlan("pool_exhaust", persist=True))
    with faults.inject(inj):
        with pytest.raises(faults.PoolExhausted):
            pool.take(spec)


def test_dealer_fault_on_triple_generation():
    dealer = beaver.TripleDealer(jax.random.key(3))
    inj = faults.FaultInjector(faults.FaultPlan("dealer_fault"))
    with faults.inject(inj):
        with pytest.raises(faults.DealerFault):
            dealer.matmul_triple((2, 2), (2, 2))


def test_dealer_hooks_skip_capture_traces():
    """A RecordingDealer discovering triple demand under comm.capture
    (the jit-layer build path) must never trip a plan counter."""
    dealer = beaver.TripleDealer(jax.random.key(4))
    inj = faults.FaultInjector(faults.FaultPlan("dealer_fault"))
    with faults.inject(inj):
        with comm.capture():
            dealer.matmul_triple((2, 2), (2, 2))   # no raise
        assert inj.fired == []
        with pytest.raises(faults.DealerFault):
            dealer.matmul_triple((2, 2), (2, 2))


def test_on_open_skips_tracers():
    """Corrupting a traced value would bake the fault into a cached
    compiled program — tracers pass through uncounted."""
    inj = faults.FaultInjector(
        faults.FaultPlan("corrupt_open", persist=True))
    with faults.inject(inj):
        out = jax.jit(lambda v: faults.on_open("matmul", v))(
            jnp.zeros((2,), jnp.int64))
        assert (np.asarray(out) == 0).all()
        assert inj.fired == []


# ---- integrity guards -------------------------------------------------------

def test_check_envelope_off_by_default():
    faults.check_envelope(np.array([1e30, np.nan]), 1.0, "x")  # inert


def test_check_envelope_paranoid():
    with faults.integrity("paranoid"):
        faults.check_envelope(np.array([1.0, -3.0]), 10.0, "x")
        with pytest.raises(faults.ProtocolIntegrityError):
            faults.check_envelope(np.array([1e9]), 10.0, "x")
        with pytest.raises(faults.ProtocolIntegrityError):
            faults.check_envelope(np.array([np.nan]), 10.0, "x")


def test_check_envelope_skips_tracers():
    with faults.integrity("paranoid"):
        jax.eval_shape(
            lambda v: (faults.check_envelope(v, 1.0, "x"), v)[1],
            jax.ShapeDtypeStruct((4,), jnp.float32))


def test_pp_apply_guard_catches_corrupted_decode():
    """End-to-end: a corrupted opened share decodes past the envelope
    and trips at the very next reveal-compute seam."""
    huge = ring.encode(jnp.full((2, 2), 1e7), ring.FRAC_BITS)
    st = share(jax.random.key(5), huge)
    with faults.integrity("paranoid"):
        with pytest.raises(faults.ProtocolIntegrityError):
            nonlinear.pp_apply(lambda v: v, st, jax.random.key(6),
                               "ppsm")
    # guards record ZERO ledger events
    with comm.ledger() as led:
        with faults.integrity("paranoid"):
            nonlinear.pp_apply(
                lambda v: v,
                share(jax.random.key(7),
                      ring.encode(jnp.ones((2, 2)), ring.FRAC_BITS)),
                jax.random.key(8), "ppsm")
    with comm.ledger() as led_off:
        nonlinear.pp_apply(
            lambda v: v,
            share(jax.random.key(7),
                  ring.encode(jnp.ones((2, 2)), ring.FRAC_BITS)),
            jax.random.key(8), "ppsm")
    assert [(e.protocol, e.rounds, e.bits) for e in led.events] \
        == [(e.protocol, e.rounds, e.bits) for e in led_off.events]


def test_check_tree_match():
    ref = [{"k": jnp.zeros((2, 3)), "v": jnp.zeros((2, 3))}]
    faults.check_tree_match(
        [{"k": jnp.ones((2, 3)), "v": jnp.ones((2, 3))}], ref, "x")
    with pytest.raises(faults.ProtocolIntegrityError):
        faults.check_tree_match(
            [{"k": jnp.ones((2, 4)), "v": jnp.ones((2, 3))}], ref, "x")
    with pytest.raises(faults.ProtocolIntegrityError):
        faults.check_tree_match(
            [{"k": jnp.ones((2, 3), jnp.int32),
              "v": jnp.ones((2, 3))}], ref, "x")
    with pytest.raises(faults.ProtocolIntegrityError):
        faults.check_tree_match([{"k": jnp.ones((2, 3))}], ref, "x")


def test_integrity_stack_nests():
    assert not faults.paranoid()
    with faults.integrity("paranoid"):
        assert faults.paranoid()
        with faults.integrity("off"):
            assert not faults.paranoid()
        assert faults.paranoid()
    assert not faults.paranoid()
    with pytest.raises(faults.EngineConfigError):
        with faults.integrity("brave"):
            pass
