"""§Perf levers must not change model semantics."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import make_batch
from repro.models.registry import get_api

KEY = jax.random.key(2)
B, S = 2, 48


@pytest.mark.parametrize("arch", ["smollm-360m", "deepseek-v2-236b",
                                  "zamba2-7b"])
def test_flash_attention_matches_naive(arch):
    cfg = get_config(arch, reduced=True)
    api = get_api(cfg)
    params = api.init_params(cfg, KEY)
    batch = make_batch(cfg, B, S, step=0)
    base = api.train_loss(cfg, params, batch)
    flash = api.train_loss(cfg.replace(attention_impl="flash",
                                       flash_block=16), params, batch)
    np.testing.assert_allclose(float(base), float(flash), rtol=2e-4)


def test_flash_decode_matches_naive():
    cfg = get_config("smollm-360m", reduced=True)
    api = get_api(cfg)
    params = api.init_params(cfg, KEY)
    toks = make_batch(cfg, B, S, step=0, kind="serve")["tokens"]
    outs = {}
    for impl in ("naive", "flash"):
        c = cfg.replace(attention_impl=impl, flash_block=16)
        _, cache, pos = api.prefill(c, params, {"tokens": toks[:, :-1]},
                                    max_len=S + 8)
        logits, _ = api.decode_step(c, params, cache, toks[:, -1:], pos)
        outs[impl] = np.asarray(logits)
    np.testing.assert_allclose(outs["naive"], outs["flash"],
                               atol=1e-3, rtol=1e-3)


def test_moe_sort_ranks_match_cumsum():
    cfg = get_config("deepseek-moe-16b", reduced=True)
    api = get_api(cfg)
    params = api.init_params(cfg, KEY)
    batch = make_batch(cfg, B, S, step=0)
    a = api.train_loss(cfg, params, batch)
    b = api.train_loss(cfg.replace(moe_rank_impl="sort"), params, batch)
    np.testing.assert_allclose(float(a), float(b), rtol=1e-5)
