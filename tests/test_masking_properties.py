"""Hypothesis property tests for core/suites/masking.py and the
serving engine's bucket ladder — the index algebra the §7/§9/§10
masking contracts rest on.

The claims are exact boolean-algebraic, so every check is equality on
numpy bool arrays (no tolerances)."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis "
                           "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.suites import masking  # noqa: E402
from repro.serving.engine import pow2_buckets  # noqa: E402

caps = st.integers(min_value=2, max_value=48)


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 8), st.integers(0, 24), st.integers(1, 16),
       st.integers(0, 16))
def test_chunk_valid_is_tril_slice(C, pos, L_extra, len_extra):
    """A chunk whose rows are all real (lens >= pos + C) sees exactly
    the corresponding row-slice of the full causal tril over the padded
    key axis — the rectangular mask is the full-prefill mask, sliced."""
    L = pos + C + L_extra
    lens = pos + C + min(len_extra, L - pos - C + 1)
    q_pos = jnp.asarray([pos + np.arange(C)])
    v = np.asarray(masking.chunk_valid(q_pos, jnp.asarray([lens]), L))
    tril = np.tril(np.ones((L, L), bool))
    np.testing.assert_array_equal(v[0], tril[pos:pos + C])


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 8), st.integers(0, 4), st.integers(1, 40),
       st.integers(8, 40))
def test_chunk_valid_tail_invariants(C, n_prior, lens, L):
    """Tail-chunk invariants for any (lens, chunk schedule): columns
    >= lens are dead for EVERY query row (padded/garbage K stays at
    zero softmax mass), every row keeps >= 1 live column (no all-dead
    softmax), and live columns are exactly the causal real tokens."""
    pos = n_prior * C
    lens = min(lens, L - 1)
    if pos >= lens:        # chunk fully past the prompt: not scheduled
        pos = max(0, ((lens - 1) // C) * C)
    L = max(L, pos + C)
    q_pos = jnp.asarray([pos + np.arange(C)])
    v = np.asarray(masking.chunk_valid(q_pos, jnp.asarray([lens]), L))[0]
    t = np.arange(L)
    assert not v[:, t >= lens].any(), "columns past lens must be dead"
    assert (v.sum(-1) >= 1).all(), "every query row needs a live column"
    for s in range(C):
        expect = (t <= pos + s) & (t < lens)
        np.testing.assert_array_equal(v[s], expect)


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 24), st.integers(0, 12))
def test_prefill_valid_matches_chunk_valid_single_chunk(lens, pad):
    """Bucketed prefill is the one-chunk special case: prefill_valid
    over a padded bucket equals chunk_valid at chunk offset 0 with the
    bucket as both chunk size and cache width."""
    S = lens + pad
    v_p = np.asarray(masking.prefill_valid(jnp.asarray([lens]), S))
    q_pos = jnp.asarray([np.arange(S)])
    v_c = np.asarray(masking.chunk_valid(q_pos, jnp.asarray([lens]), S))
    np.testing.assert_array_equal(v_p, v_c)


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 24), st.integers(0, 12))
def test_prefill_valid_zero_mass_invariant(lens, pad):
    """§9's masking contract: padded prompt columns are dead for every
    query row, real rows see exactly their causal prefix, and no row is
    all-dead (the softmax stays well-defined on padded query rows)."""
    S = lens + pad
    v = np.asarray(masking.prefill_valid(jnp.asarray([lens]), S))[0]
    t = np.arange(S)
    assert not v[:, t >= lens].any()
    assert (v.sum(-1) >= 1).all()
    tril = np.tril(np.ones((S, S), bool))
    np.testing.assert_array_equal(v[:lens], tril[:lens, :S] &
                                  (t < lens)[None, :])


@settings(max_examples=50, deadline=None)
@given(caps)
def test_pow2_buckets_monotone_and_coverage(max_len):
    """Ladder invariants: strictly increasing, capped by max_len,
    topped exactly at max_len, and every admissible prompt length
    (<= max_len - 1 after the shared cap) has a bucket — the smallest
    covering bucket above the ladder's floor pads by less than 2x
    (doubling steps), except possibly the max_len-capped top rung."""
    b = pow2_buckets(max_len)
    assert list(b) == sorted(set(b))
    assert b[-1] == max_len and all(x <= max_len for x in b)
    for length in range(1, max_len):
        cover = next(x for x in b if x >= length)
        assert cover >= length
        if b[0] < cover < max_len:
            assert cover < 2 * length, (length, b)


@settings(max_examples=25, deadline=None)
@given(caps, st.integers(0, 40))
def test_slot_valid_is_occupancy_prefix(L, pos):
    """§7 decode validity: exactly the first pos+1 columns are live."""
    pos = min(pos, L - 1)
    v = np.asarray(masking.slot_valid(jnp.asarray([[pos]]), L))[0, 0]
    np.testing.assert_array_equal(v, np.arange(L) <= pos)
