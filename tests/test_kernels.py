"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret
mode — the kernel body executes in Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis "
                           "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

import repro.core.ring  # noqa: F401  (enables x64 before int64 use)
from repro.kernels import ops, ref

KEY = jax.random.key(0)


def _randi(key, shape, dtype=jnp.int32):
    bits = jax.random.bits(key, shape, dtype=jnp.uint32)
    return jax.lax.bitcast_convert_type(bits, jnp.int32).astype(dtype)


# ---- ring matmul -------------------------------------------------------------

@pytest.mark.parametrize("m,k,n,bm,bk,bn", [
    (8, 16, 8, 8, 8, 8),
    (16, 32, 24, 8, 16, 8),
    (128, 128, 128, 64, 64, 64),
    (32, 256, 16, 32, 128, 16),
])
def test_ring_matmul32_exact(m, k, n, bm, bk, bn):
    k1, k2 = jax.random.split(KEY)
    a = _randi(k1, (m, k))
    b = _randi(k2, (k, n))
    got = ops.ring_matmul32(a, b, bm=bm, bk=bk, bn=bn, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.ring_matmul32_ref(a, b)))


def test_ring_matmul_wide_exact():
    k1, k2 = jax.random.split(KEY)
    a = _randi(k1, (32, 64))
    b = _randi(k2, (64, 16))
    got = ops.ring_matmul_wide(a, b, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.ring_matmul_wide_ref(a, b)))


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 4), st.integers(1, 6), st.integers(1, 4),
       st.integers(0, 2 ** 31 - 1))
def test_ring64_matmul_matches_int64(mm, kk, nn, seed):
    k1, k2 = jax.random.split(jax.random.key(seed))
    m, k, n = 8 * mm, 8 * kk, 8 * nn
    a = jax.lax.bitcast_convert_type(
        jax.random.bits(k1, (m, k), dtype=jnp.uint64), jnp.int64)
    b = jax.lax.bitcast_convert_type(
        jax.random.bits(k2, (k, n), dtype=jnp.uint64), jnp.int64)
    got = ops.ring64_matmul(a, b, bm=8, bk=8, bn=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.ring64_matmul_ref(a, b)))


def test_ring64_matmul_fixed_point_semantics():
    """The kernel path must agree with the engine's jnp int64 path."""
    from repro.core import ring
    k1, k2 = jax.random.split(KEY)
    a = ring.encode(jax.random.normal(k1, (16, 24)))
    b = ring.encode(jax.random.normal(k2, (24, 8)))
    got = ring.decode(ring.truncate(ops.ring64_matmul(a, b, interpret=True)))
    want = ring.decode(ring.fixed_point_matmul(a, b))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-6)


# ---- softmax / norms ----------------------------------------------------------

@pytest.mark.parametrize("shape,dtype", [
    ((4, 64), jnp.float32), ((3, 5, 128), jnp.float32),
    ((2, 8, 256), jnp.bfloat16), ((16, 1024), jnp.float32),
    ((7, 96), jnp.float32),
])
def test_softmax_sweep(shape, dtype):
    x = (jax.random.normal(KEY, shape, jnp.float32) * 5).astype(dtype)
    got = ops.softmax(x, interpret=True)
    want = ref.softmax_ref(x)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=2e-2 if dtype == jnp.bfloat16 else 1e-6)


@pytest.mark.parametrize("shape,dtype", [
    ((6, 64), jnp.float32), ((2, 9, 128), jnp.float32),
    ((4, 256), jnp.bfloat16),
])
def test_rmsnorm_and_layernorm_sweep(shape, dtype):
    k1, k2, k3 = jax.random.split(KEY, 3)
    x = (jax.random.normal(k1, shape, jnp.float32) * 2 + 0.5).astype(dtype)
    g = jax.random.normal(k2, shape[-1:], jnp.float32) + 1.0
    b = jax.random.normal(k3, shape[-1:], jnp.float32)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(ops.rmsnorm(x, g, interpret=True), np.float32),
        np.asarray(ref.rmsnorm_ref(x, g), np.float32), atol=tol)
    np.testing.assert_allclose(
        np.asarray(ops.layernorm(x, g, b, interpret=True), np.float32),
        np.asarray(ref.layernorm_ref(x, g, b), np.float32), atol=tol)


# ---- flash attention -----------------------------------------------------------

@pytest.mark.parametrize("B,H,S,T,D,causal,bq,bk", [
    (1, 2, 64, 64, 32, True, 32, 32),
    (2, 1, 128, 128, 64, True, 64, 32),
    (1, 2, 32, 96, 32, False, 16, 32),   # cross attention (prefill kv)
    (1, 1, 256, 256, 16, True, 128, 128),
])
def test_flash_attention_sweep(B, H, S, T, D, causal, bq, bk):
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (B, H, S, D), jnp.float32)
    k = jax.random.normal(k2, (B, H, T, D), jnp.float32)
    v = jax.random.normal(k3, (B, H, T, D), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=causal, bq=bq, bk=bk,
                              interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_bf16():
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (1, 2, 64, 32), jnp.float32).astype(jnp.bfloat16)
    k = jax.random.normal(k2, (1, 2, 64, 32), jnp.float32).astype(jnp.bfloat16)
    v = jax.random.normal(k3, (1, 2, 64, 32), jnp.float32).astype(jnp.bfloat16)
    got = ops.flash_attention(q, k, v, interpret=True)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=3e-2)


# ---- SSD scan -------------------------------------------------------------------

@pytest.mark.parametrize("Bt,L,H,P,G,N,chunk", [
    (1, 32, 2, 16, 1, 8, 8),
    (2, 64, 4, 8, 1, 16, 16),
    (1, 48, 4, 16, 2, 8, 12),
    (2, 128, 2, 32, 1, 32, 64),
])
def test_ssd_scan_sweep(Bt, L, H, P, G, N, chunk):
    ks = jax.random.split(KEY, 5)
    f32 = jnp.float32  # x64 mode makes random.normal default to f64
    x = jax.random.normal(ks[0], (Bt, L, H, P), f32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bt, L, H), f32))
    A = -jnp.exp(jax.random.normal(ks[2], (H,), f32) * 0.5)
    B = jax.random.normal(ks[3], (Bt, L, G, N), f32)
    C = jax.random.normal(ks[4], (Bt, L, G, N), f32)
    got = ops.ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=True)
    want = ref.ssd_scan_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=2e-4)


def test_ssd_kernel_matches_model_ssd():
    """Kernel must agree with the model-layer chunked implementation."""
    from repro.models.mamba2 import ssd_chunked
    ks = jax.random.split(KEY, 5)
    f32 = jnp.float32
    Bt, L, H, P, N = 2, 64, 4, 16, 16
    x = jax.random.normal(ks[0], (Bt, L, H, P), f32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bt, L, H), f32))
    A = -jnp.exp(jax.random.normal(ks[2], (H,), f32) * 0.5)
    B = jax.random.normal(ks[3], (Bt, L, 1, N), f32)
    C = jax.random.normal(ks[4], (Bt, L, 1, N), f32)
    got = ops.ssd_scan(x, dt, A, B, C, chunk=16, interpret=True)
    want = ssd_chunked(x, dt, A, B, C, chunk=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=2e-4)
