"""Training loop, checkpoint/restart, fault tolerance, serving engine."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.configs.paper_models import GPT2_TINY
from repro.data.pipeline import DataPipeline
from repro.models.registry import get_api
from repro.runtime.fault_tolerance import (ElasticPlan, HeartbeatMonitor,
                                           PreemptionGuard, StragglerMonitor,
                                           StragglerPolicy)
from repro.serving.engine import ServingEngine
from repro.training.optimizer import OptConfig, compress_int8
from repro.training.train_loop import run_training

CFG = get_config("smollm-360m", reduced=True)


def test_training_loss_decreases():
    pipe = DataPipeline(CFG, global_batch=8, seq_len=32)
    res = run_training(CFG, OptConfig(lr=3e-3, warmup_steps=5), pipe,
                       num_steps=30, log_every=1)
    first = np.mean([l for _, l in res.losses[:3]])
    last = np.mean([l for _, l in res.losses[-3:]])
    assert last < first - 0.2, res.losses


def test_microbatch_accumulation_matches_full_batch():
    from repro.training.train_loop import build_train_step
    from repro.training.optimizer import init_opt_state
    api = get_api(CFG)
    params = api.init_params(CFG, jax.random.key(0))
    opt = OptConfig(lr=1e-3)
    state = init_opt_state(params, opt)
    pipe = DataPipeline(CFG, global_batch=8, seq_len=32)
    batch = next(pipe)
    s1 = build_train_step(CFG, opt, num_microbatches=1)
    s4 = build_train_step(CFG, opt, num_microbatches=4)
    p1, _, m1 = s1(params, state, batch)
    p4, _, m4 = s4(params, state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-4)
    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        p1, p4)
    assert max(jax.tree.leaves(diffs)) < 5e-3


def test_checkpoint_save_restore_resume_exact(tmp_path):
    pipe = DataPipeline(CFG, global_batch=4, seq_len=16)
    mgr = CheckpointManager(str(tmp_path / "ckpt"), async_write=False)
    res_a = run_training(CFG, OptConfig(lr=1e-3), pipe, num_steps=6,
                         checkpoint_mgr=mgr, ckpt_every=3, log_every=1)
    # fresh run restores from step 6 checkpoint and continues to 10
    pipe2 = DataPipeline(CFG, global_batch=4, seq_len=16)
    res_b = run_training(CFG, OptConfig(lr=1e-3), pipe2, num_steps=10,
                         checkpoint_mgr=mgr, ckpt_every=100, log_every=1)
    assert res_a.step == 6
    assert res_b.losses[0][0] == 6  # resumed, not restarted

    # straight 10-step run with same seeds must match the resumed run
    pipe3 = DataPipeline(CFG, global_batch=4, seq_len=16)
    res_c = run_training(CFG, OptConfig(lr=1e-3), pipe3, num_steps=10,
                         log_every=1)
    np.testing.assert_allclose(res_b.losses[-1][1], res_c.losses[-1][1],
                               rtol=1e-4)


def test_preemption_saves_and_exits(tmp_path):
    pipe = DataPipeline(CFG, global_batch=4, seq_len=16)
    mgr = CheckpointManager(str(tmp_path / "c"), async_write=True)
    guard = PreemptionGuard()
    guard.request()
    res = run_training(CFG, OptConfig(), pipe, num_steps=50,
                       checkpoint_mgr=mgr, ckpt_every=1000,
                       preemption=guard)
    assert res.step == 1            # stopped after first step
    assert mgr.list_steps() == [1]  # checkpoint written on the way out


def test_elastic_restore_across_mesh_shapes(tmp_path):
    """Host arrays are mesh-agnostic: restore under a different sharding."""
    api = get_api(CFG)
    params = api.init_params(CFG, jax.random.key(1))
    mgr = CheckpointManager(str(tmp_path / "e"), async_write=False)
    mgr.save(5, {"params": params})
    devs = jax.devices()
    sharding = jax.sharding.SingleDeviceSharding(devs[0])
    shardings = jax.tree.map(lambda _: sharding, {"params": params})
    out = mgr.restore(5, like={"params": params}, shardings=shardings)
    same = jax.tree.map(lambda a, b: np.allclose(np.asarray(a),
                                                 np.asarray(b)),
                        out["params"], params)
    assert all(jax.tree.leaves(same))


def test_heartbeat_and_straggler_monitors():
    hb = HeartbeatMonitor(timeout=10.0, clock=lambda: 100.0)
    hb.beat(0, at=95.0)
    hb.beat(1, at=80.0)
    assert hb.dead_hosts() == [1]

    sm = StragglerMonitor(StragglerPolicy(threshold=1.5,
                                          min_observations=3,
                                          action="evict"))
    for step in range(6):
        for host in range(4):
            sm.observe(host, step, 1.0 if host != 2 else 3.0)
    acts = sm.check()
    assert acts and acts[0]["host"] == 2 and acts[0]["action"] == "evict"


def test_straggler_policy_not_shared_across_monitors():
    # regression: a shared default StragglerPolicy instance aliased
    # policy mutations across every monitor in the process
    a, b = StragglerMonitor(), StragglerMonitor()
    a.policy.action = "evict"
    assert b.policy.action == "alert"


def test_straggler_observe_drops_stale_steps():
    sm = StragglerMonitor(StragglerPolicy(min_observations=1))
    sm.observe(0, step=5, duration=1.0)
    sm.observe(0, step=5, duration=100.0)   # re-delivered beat
    sm.observe(0, step=3, duration=100.0)   # out-of-order arrival
    assert sm.counts[0] == 1 and sm.times[0] == 1.0
    sm.observe(0, step=6, duration=2.0)
    assert sm.counts[0] == 2


def test_preemption_guard_chains_prior_sigterm_handler():
    import signal

    seen = []
    prev = signal.signal(signal.SIGTERM, lambda s, f: seen.append(s))
    try:
        guard = PreemptionGuard(install_signal=True)
        signal.raise_signal(signal.SIGTERM)
        assert guard.should_stop()
        # the pre-existing handler (a supervisor's checkpointer) still
        # ran after the flag was raised
        assert seen == [signal.SIGTERM]
    finally:
        signal.signal(signal.SIGTERM, prev)


def test_elastic_plan_shrinks_mesh():
    plan = ElasticPlan(global_batch=256, model_parallel=16)
    full = plan.plan(alive_hosts=64, chips_per_host=4)
    assert full == {"data": 16, "model": 16, "chips_used": 256}
    degraded = plan.plan(alive_hosts=60, chips_per_host=4)
    assert degraded["data"] == 8 and degraded["chips_used"] == 128


def test_grad_compression_error_feedback():
    g = jnp.array([1.0, -2.0, 0.003, 100.0])
    err = jnp.zeros_like(g)
    total_in, total_out = jnp.zeros_like(g), jnp.zeros_like(g)
    for _ in range(50):
        deq, err = compress_int8(g, err)
        total_in = total_in + g
        total_out = total_out + deq
    # error feedback: accumulated compressed updates track the truth
    np.testing.assert_allclose(np.asarray(total_out),
                               np.asarray(total_in), rtol=0.02, atol=1.0)


def test_serving_engine_continuous_batching():
    cfg = GPT2_TINY
    api = get_api(cfg)
    params = api.init_params(cfg, jax.random.key(0))
    eng = ServingEngine(cfg, params, max_slots=3, max_len=64)
    rids = [eng.submit([1, 2, 3, 4], max_new_tokens=5) for _ in range(5)]
    outs = eng.run_to_completion()
    assert set(outs) == set(rids)
    assert all(len(v) >= 5 for v in outs.values())
    # determinism: same prompt -> same continuation
    assert outs[rids[0]] == outs[rids[1]]


def test_serving_matches_offline_greedy():
    cfg = GPT2_TINY
    api = get_api(cfg)
    params = api.init_params(cfg, jax.random.key(0))
    prompt = [5, 6, 7]
    eng = ServingEngine(cfg, params, max_slots=2, max_len=32)
    rid = eng.submit(prompt, max_new_tokens=4)
    outs = eng.run_to_completion()
    # offline greedy reference
    toks = jnp.asarray(prompt, jnp.int32)[None]
    logits, cache, pos = api.prefill(cfg, params, {"tokens": toks},
                                     max_len=32)
    ref = [int(jnp.argmax(logits[0]))]
    for _ in range(3):
        logits, cache = api.decode_step(
            cfg, params, cache, jnp.asarray([[ref[-1]]], jnp.int32), pos)
        pos += 1
        ref.append(int(jnp.argmax(logits[0])))
    assert outs[rid][:4] == ref
