"""Dry-run machinery tests.

The mesh tests run in a subprocess so the fake-device XLA flag never
pollutes this test process (smoke tests must see 1 device)."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_dryrun(args, devices="16"):
    env = dict(os.environ, PYTHONPATH=SRC, REPRO_DRYRUN_DEVICES=devices)
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args],
        capture_output=True, text=True, env=env, timeout=560)


def test_dryrun_compiles_reduced_arch(tmp_path):
    r = _run_dryrun(["--arch", "smollm-360m", "--shape", "train_4k",
                     "--mesh", "4x4", "--reduced", "--out",
                     str(tmp_path)])
    assert r.returncode == 0, r.stdout + r.stderr
    out = json.load(open(tmp_path / "smollm-360m_train_4k_4x4.json"))
    assert out["hlo_flops"] > 0
    assert out["terms"]["compute_s"] > 0
    assert out["bottleneck"] in ("compute_s", "memory_s", "collective_s")


def test_dryrun_multipod_axes(tmp_path):
    r = _run_dryrun(["--arch", "mamba2-130m", "--shape", "decode_32k",
                     "--mesh", "2x2x4", "--reduced", "--out",
                     str(tmp_path)])
    assert r.returncode == 0, r.stdout + r.stderr
    out = json.load(open(tmp_path / "mamba2-130m_decode_32k_2x2x4.json"))
    assert out["chips"] == 16


def test_dryrun_skips_long_context_for_full_attention(tmp_path):
    r = _run_dryrun(["--arch", "llama3-405b", "--shape", "long_500k",
                     "--mesh", "2x2", "--out", str(tmp_path)])
    assert r.returncode == 0, r.stdout + r.stderr
    out = json.load(open(tmp_path / "llama3-405b_long_500k_2x2.json"))
    assert "skipped" in out


def test_hlo_stats_trip_counts():
    """analyze_hlo must recover scan trip counts == num_layers."""
    import jax
    import jax.numpy as jnp
    from repro.launch.hlo_stats import analyze_hlo

    L = 7

    def f(ws, x):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    ws = jnp.zeros((L, 16, 16))
    x = jnp.zeros((4, 16))
    hlo = jax.jit(f).lower(ws, x).compile().as_text()
    st = analyze_hlo(hlo)
    assert L in st["trips"].values()
    assert st["flops"] > 0, "analyze_hlo missed every dot"
    # 7 iterations x (2 * 4 * 16 * 16) flops
    assert abs(st["flops"] - L * 2 * 4 * 16 * 16) / st["flops"] < 0.01


def test_production_mesh_shapes():
    """make_production_mesh contract (validated without building)."""
    import inspect
    from repro.launch import mesh as M
    src = inspect.getsource(M.make_production_mesh)
    assert "(2, 16, 16)" in src and "(16, 16)" in src
    assert '"pod", "data", "model"' in src


def test_all_assigned_cells_recorded():
    """The committed dry-run results must cover every assigned cell."""
    d = os.path.join(os.path.dirname(__file__), "..", "experiments",
                     "dryrun")
    if not os.path.isdir(d):
        pytest.skip("dry-run sweep not present")
    from repro.configs import ARCH_IDS
    from repro.models.config import SHAPES
    missing, errors = [], []
    for arch in ARCH_IDS:
        for shape in SHAPES:
            for mesh in ("16x16", "2x16x16"):
                p = os.path.join(d, f"{arch}_{shape}_{mesh}.json")
                if not os.path.exists(p):
                    missing.append((arch, shape, mesh))
                    continue
                r = json.load(open(p))
                if "error" in r:
                    errors.append((arch, shape, mesh))
    assert not missing, f"missing cells: {missing}"
    assert not errors, f"failed cells: {errors}"
