"""Chunked prefill (DESIGN.md §10).

Chunking must be a pure compile-count/comm transform: consuming a
prompt as ceil(S/C) fixed-shape chunks against the slot cache changes
neither the decoded tokens in any servable mode nor the online
ledger's eager/jit agreement — while compiling ONE chunk program per
(C, max_len), billing each chunk tick to its request exactly, and
undercutting the bucket ladder's padded-S^2 online bill at long prompt
lengths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.paper_models import GPT2_TINY
from repro.core import comm, ring
from repro.core.private_model import (build_private_model,
                                      chunk_state_caches,
                                      init_chunk_state,
                                      private_decode_step,
                                      private_prefill,
                                      private_prefill_chunk)
from repro.core.sharing import reconstruct, share
from repro.core.suites import get_suite, masking
from repro.models.registry import get_api
from repro.runtime.faults import EngineConfigError
from repro.serving.engine import PrivateServingEngine, ServingEngine

KEY = jax.random.key(3)
C, MAXLEN = 4, 24
# mixed lengths incl. multi-chunk prompts; more requests than slots
PROMPTS = [list(range(1, 18)), [7, 8], list(range(2, 21)),
           [3, 1, 4, 1, 5, 9, 2, 6], [5, 4, 3]]
NNEW = 3
LONG = list(range(1, 20))        # S=19: lands in the top pow2 bucket


@pytest.fixture(scope="module")
def params():
    return get_api(GPT2_TINY).init_params(GPT2_TINY, KEY)


def _serve(params, mode, slots=3, prompts=PROMPTS, n_new=NNEW,
           decode_jit=True, **kw):
    eng = PrivateServingEngine(GPT2_TINY, params, KEY, mode=mode,
                               max_slots=slots, max_len=MAXLEN,
                               decode_jit=decode_jit, **kw)
    rids = [eng.submit(p, max_new_tokens=n_new) for p in prompts]
    with comm.ledger() as led:
        outs, stats = eng.run_to_completion()
    return [outs[r] for r in rids], stats, eng, led


def _chunk_ledgers(params, mode, prompt, jit, chunk=C, max_len=MAXLEN):
    """Run a full chunked prefill; returns (per-chunk ledgers incl. the
    init tick, final logits).  lookahead=1 on the jit path keeps the
    pool's generation == each tick's consumption, so offline bits are
    comparable per chunk too (DESIGN.md §12)."""
    pm = build_private_model(GPT2_TINY, params, KEY, mode=mode,
                             use_pool=jit)
    S = len(prompt)
    n = -(-S // chunk)
    padded = prompt + [0] * (n * chunk - S)
    leds = []
    with comm.ledger() as led0:
        state = init_chunk_state(pm, 1, max_len)
    leds.append(led0)
    logits = None
    for ci in range(n):
        toks = jnp.asarray([padded[ci * chunk:(ci + 1) * chunk]],
                           jnp.int32)
        with comm.ledger() as led:
            lg, state = private_prefill_chunk(
                pm, state, toks, ci * chunk,
                jnp.asarray([S], jnp.int32), jit=jit, lookahead=1)
        if lg is not None:
            logits = lg
        leds.append(led)
    return leds, np.asarray(logits), state, pm


def test_chunk_valid_mask_contents():
    """Rectangular causal-against-cache AND real-token: the chunk's
    rows are the corresponding slice of the full tril, with columns
    >= lens dead for every query row."""
    q_pos = jnp.asarray([[2, 3], [2, 3]])
    v = np.asarray(masking.chunk_valid(q_pos, jnp.asarray([4, 3]), 6))
    # request 0: lens=4 covers the chunk -> pure tril slice rows 2..3
    assert v[0].tolist() == [[1, 1, 1, 0, 0, 0], [1, 1, 1, 1, 0, 0]]
    # request 1: lens=3 -> column 3 (the padded tail row's own K) dead
    # even for the padded query row, which keeps its live real columns
    assert v[1].tolist() == [[1, 1, 1, 0, 0, 0], [1, 1, 1, 0, 0, 0]]


def test_chunked_tokens_match_exact_bucketed_and_plaintext(params):
    """Exact-protocol serving: chunked prefill + decode == bucketed ==
    exact-length == plaintext greedy, token for token, under a
    mixed-length staggered workload — with exactly 1 compiled chunk
    program + 1 decode program."""
    toks_c, _, eng, _ = _serve(params, "centaur", chunk_size=C)
    toks_e, _, _, _ = _serve(params, "centaur")
    toks_b, _, _, _ = _serve(params, "centaur", buckets="pow2")
    assert toks_c == toks_e, \
        "centaur: chunked prefill changed the decoded tokens"
    assert toks_c == toks_b, \
        "centaur: chunked and bucketed serving disagree"
    cs = eng.compile_stats()
    assert cs["chunk_programs"] == 1, cs
    assert cs["prefill_programs"] == 1, cs
    assert cs["decode_programs"] == 1, cs
    assert cs["chunk_ticks"] == sum(-(-len(p) // C) for p in PROMPTS)
    peng = ServingEngine(GPT2_TINY, params, max_slots=3,
                         max_len=MAXLEN)
    prids = [peng.submit(p, max_new_tokens=NNEW) for p in PROMPTS]
    pouts = peng.run_to_completion()
    assert toks_c == [pouts[r] for r in prids], \
        "centaur: chunked serving diverged from plaintext greedy"


def test_chunked_tokens_match_exact_smpc(params):
    """The share-softmax baseline end-to-end through the chunk path
    (eager: compiling the baselines' NR stacks is minutes of XLA;
    jit-vs-eager parity is pinned by the ledger test below)."""
    lite = [[1, 2, 3], list(range(2, 13))]
    toks_c, _, _, _ = _serve(params, "smpc", slots=1, prompts=lite,
                             n_new=2, decode_jit=False, chunk_size=C)
    toks_e, _, _, _ = _serve(params, "smpc", slots=1, prompts=lite,
                             n_new=2, decode_jit=False)
    assert toks_c == toks_e, \
        "smpc: chunked prefill changed the decoded tokens"


@pytest.mark.parametrize("mode", ("mpcformer", "secformer"))
def test_chunked_prefill_logits_close_per_softmax_variant(params, mode):
    """The masking contract per softmax variant (2Quad included):
    chunk-padded dead columns must carry exactly zero mass, so chunked
    and exact-length prefill logits agree up to the protocols' own
    fixed-point noise."""
    prompt = [1, 2, 3, 4, 5, 6]
    _, lc, _, _ = _chunk_ledgers(params, mode, prompt, jit=False)
    pm_e = build_private_model(GPT2_TINY, params, KEY, mode=mode)
    le, _ = private_prefill(pm_e, jnp.asarray([prompt], jnp.int32),
                            max_len=MAXLEN)
    np.testing.assert_allclose(lc, np.asarray(le), atol=0.06)


def test_gqa_chunked_decode_parity():
    """The chunk path owns GQA head grouping / SwiGLU / RoPE like the
    rest of the executor: llama-style shapes decode the same tokens
    after a chunked prefill as after an exact-length prefill."""
    cfg = get_config("smollm-360m", reduced=True)
    params = get_api(cfg).init_params(cfg, KEY)
    prompt, n_new, chunk, max_len = [9, 8, 7, 6, 5, 4, 3], 3, 4, 16

    def greedy(chunked):
        pm = build_private_model(cfg, params, KEY, mode="centaur")
        toks = jnp.asarray([prompt], jnp.int32)
        if chunked:
            state = init_chunk_state(pm, 1, max_len)
            S = len(prompt)
            n = -(-S // chunk)
            padded = prompt + [0] * (n * chunk - S)
            for ci in range(n):
                logits, state = private_prefill_chunk(
                    pm, state,
                    jnp.asarray([padded[ci * chunk:(ci + 1) * chunk]],
                                jnp.int32),
                    ci * chunk, jnp.asarray([S], jnp.int32))
            caches = chunk_state_caches(state)
        else:
            logits, caches = private_prefill(pm, toks, max_len=max_len)
        out = [int(np.argmax(np.asarray(logits)[0]))]
        for i in range(n_new - 1):
            logits, caches = private_decode_step(
                pm, caches, jnp.asarray([[out[-1]]], jnp.int32),
                len(prompt) + i)
            out.append(int(np.argmax(np.asarray(logits)[0])))
        return out

    assert greedy(chunked=True) == greedy(chunked=False), \
        "GQA: chunked prefill changed the decoded tokens"


@pytest.mark.parametrize("mode", ("centaur", "smpc"))
def test_chunk_ledger_eager_vs_jit_bit_exact(params, mode):
    """Per-chunk eager-vs-jit ledger bit-exactness — online AND offline
    bits: every chunk tick (and the init tick) must bill identically
    under capture/replay and eager execution.  Offline exactness is the
    §12 fix: `matmul_masked_f`'s C = A@B delivery is billed at the
    dealer seam (the `maskmul` spec), so the lazy dealer and the pool's
    generation-time billing agree per triple; with lookahead=1 the
    pool generates exactly each tick's demand."""
    prompt = [1, 2, 3, 4, 5, 6, 7]
    leds_e, le, _, _ = _chunk_ledgers(params, mode, prompt, jit=False)
    leds_j, lj, _, _ = _chunk_ledgers(params, mode, prompt, jit=True)
    assert len(leds_e) == len(leds_j)
    for i, (a, b) in enumerate(zip(leds_e, leds_j)):
        assert a.total_bits() == b.total_bits(), f"chunk {i}"
        assert a.total_rounds() == b.total_rounds(), f"chunk {i}"
        assert a.total_bits(False) == b.total_bits(False), \
            f"chunk {i}: offline bits diverge eager-vs-jit"
    if mode == "centaur":
        assert le[0].argmax() == lj[0].argmax()


@pytest.mark.parametrize("mode", ("centaur", "smpc"))
def test_chunked_below_bucketed_bits_at_long_prompts(params, mode):
    """The comm trade chunking exists for: at long prompt lengths the
    chunked online bill (incl. the per-request π1 setup and the
    once-per-request head program) sits strictly below the bucket
    ladder's padded-S^2 bill, and both sit above exact-length (chunking
    is near-exact, not free: scores still span the padded cache width).
    The smpc case is the previously-impossible assertion: persistent
    weight masks (DESIGN.md §12) removed the per-chunk weight re-opens
    that used to dominate the baselines' chunk bill."""
    leds, _, _, _ = _chunk_ledgers(params, mode, LONG, jit=False)
    chunk_bits = sum(led.total_bits() for led in leds)
    bucket = 24   # pow2_buckets(24) puts S=19 in the top bucket
    pm_b = build_private_model(GPT2_TINY, params, KEY, mode=mode)
    toks = jnp.asarray([LONG + [0] * (bucket - len(LONG))], jnp.int32)
    with comm.ledger() as led_b:
        private_prefill(pm_b, toks, max_len=MAXLEN,
                        lens=jnp.asarray([len(LONG)], jnp.int32))
    pm_x = build_private_model(GPT2_TINY, params, KEY, mode=mode)
    with comm.ledger() as led_x:
        private_prefill(pm_x, jnp.asarray([LONG], jnp.int32),
                        max_len=MAXLEN)
    assert led_x.total_bits() < chunk_bits < led_b.total_bits(), \
        (led_x.total_bits(), chunk_bits, led_b.total_bits())


def test_chunk_attribution_conservation(params):
    """A prefill spanning several chunk ticks stays exact and
    sum-conserving: per-request attributed stats (chunk ticks + shared
    decode ticks) sum to the global ledger, and every multi-chunk
    request billed more than one tick's worth of prefill."""
    toks, stats, eng, led = _serve(params, "centaur", chunk_size=C)
    assert sum(s["online_bits"] for s in stats.values()) \
        == led.total_bits()
    assert sum(s["rounds"] for s in stats.values()) \
        == led.total_rounds()
    assert sum(s["offline_bits"] for s in stats.values()) \
        == led.total_bits(False) - led.total_bits()
    assert all(s["online_bits"] > 0 for s in stats.values())
    # single-request engine == isolated bill (attribution identity)
    _, stats_one, _, led_one = _serve(params, "centaur", slots=1,
                                      prompts=[PROMPTS[0]],
                                      chunk_size=C)
    one = next(iter(stats_one.values()))
    assert one["online_bits"] == led_one.total_bits()
    assert one["rounds"] == led_one.total_rounds()


def test_chunk_size_validation(params):
    # typed config errors (not bare asserts: they must survive -O)
    with pytest.raises(EngineConfigError):
        PrivateServingEngine(GPT2_TINY, {}, KEY, max_len=20,
                             chunk_size=8)     # 20 % 8 != 0
    with pytest.raises(EngineConfigError):
        PrivateServingEngine(GPT2_TINY, {}, KEY, max_len=24,
                             chunk_size=4, buckets="pow2")
    with pytest.raises(EngineConfigError):
        PrivateServingEngine(GPT2_TINY, {}, KEY, max_len=24,
                             chunk_size=0)


@pytest.mark.parametrize("mode", ("centaur", "smpc", "permute"))
def test_rectangular_mask_and_softmax_per_suite(params, mode):
    """Every suite's mask + softmax path must handle rectangular
    prefill-against-cache scores: dead key columns carry exactly zero
    mass and live rows stay normalized, on (B, hk, g, C, L) shapes."""
    pm = build_private_model(GPT2_TINY, params, KEY, mode=mode)
    suite = get_suite(pm)
    B, hk, g, Cq, L = 2, 2, 1, 3, 8
    q_pos = jnp.asarray([[3, 4, 5], [2, 3, 4]])
    lens = jnp.asarray([6, 4])
    valid = masking.chunk_valid(q_pos, lens, L)
    raw = jax.random.normal(jax.random.key(0), (B, hk, g, Cq, L))
    if mode == "permute":
        scores = jnp.asarray(raw, jnp.float32)
    else:
        scores = share(jax.random.key(1), ring.encode(raw))
    masked = suite.mask(scores, valid[:, None, None])
    if mode == "permute":
        probs = suite.softmax_pair(masked, None, per_slot=False)[0]
    else:
        probs = suite.softmax_chunk(masked, suite.chunk_perm_state(B, L)
                                    if mode == "centaur" else None)
        probs = ring.decode(reconstruct(probs), dtype=jnp.float32)
    probs = np.asarray(probs)
    assert probs.shape == (B, hk, g, Cq, L)
    dead = ~np.asarray(valid)[:, None, None]
    # share modes represent the exact-zero mass in fixed point, where
    # local truncation leaves +-2 LSB (2^-15) of noise around zero
    tol = 1e-6 if mode == "permute" else 2 ** -15 + 1e-9
    assert np.abs(probs[np.broadcast_to(dead, probs.shape)]).max() \
        <= tol, f"{mode}: dead columns carry softmax mass"
    np.testing.assert_allclose(probs.sum(-1), 1.0, atol=1e-3)
