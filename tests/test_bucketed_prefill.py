"""Length-bucketed padded prefill (DESIGN.md §9).

Bucketing must be a pure compile-count transform: padding a prompt to
its power-of-two bucket (masked dead columns, last-REAL-token logits)
changes neither the decoded tokens in any servable mode nor the online
ledger's eager/jit agreement — while capping the engine's compiled
programs at len(buckets) prefill + 1 decode under arbitrary length
mixes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_models import GPT2_TINY
from repro.core import comm
from repro.core.private_model import build_private_model, private_prefill
from repro.core.suites import masking
from repro.models.registry import get_api
from repro.runtime.faults import EngineConfigError
from repro.serving.engine import (PrivateServingEngine, ServingEngine,
                                  pow2_buckets)

KEY = jax.random.key(3)
# >= 4 distinct prompt lengths; more requests than slots -> staggered
# admissions; the 11-length prompt exercises the second bucket
PROMPTS = [[1, 2, 3], [7, 8], [9, 10, 11, 12], [3, 1],
           [5, 4, 5, 4, 5, 4, 5], [2, 3, 5, 7, 11, 13, 17, 2, 3, 5, 7]]
# the smpc serving check uses a slim staggered workload hitting both
# buckets: its eager softmax stacks are CPU-heavy, and the full
# mixed-length serving contract is already pinned in centaur mode
# (the bucketed-cache/decode mechanics are share-domain identical)
PROMPTS_LITE = [[1, 2, 3], [2, 3, 5, 7, 11, 13, 17, 2, 3, 5, 7]]
NNEW, MAXLEN = 3, 20
SERVABLE = ("centaur", "smpc", "mpcformer", "secformer")


@pytest.fixture(scope="module")
def params():
    return get_api(GPT2_TINY).init_params(GPT2_TINY, KEY)


def _serve(params, mode, buckets, decode_jit, slots=3, prompts=PROMPTS,
           n_new=NNEW):
    eng = PrivateServingEngine(GPT2_TINY, params, KEY, mode=mode,
                               max_slots=slots, max_len=MAXLEN,
                               decode_jit=decode_jit, buckets=buckets)
    rids = [eng.submit(p, max_new_tokens=n_new) for p in prompts]
    outs, stats = eng.run_to_completion()
    return [outs[r] for r in rids], stats, eng


def test_pow2_bucket_ladder():
    assert pow2_buckets(20) == (8, 16, 20)
    assert pow2_buckets(64) == (8, 16, 32, 64)
    assert pow2_buckets(8) == (8,)


def test_prefill_valid_mask_contents():
    """Causal AND real-token: padded prompt columns are dead for every
    query row; padded query rows keep their live real columns (the
    softmax must stay well-defined)."""
    v = np.asarray(masking.prefill_valid(jnp.asarray([2, 4]), 4))
    # request 0: length 2 of 4 — columns 2,3 dead everywhere
    assert v[0].tolist() == [[1, 0, 0, 0], [1, 1, 0, 0],
                             [1, 1, 0, 0], [1, 1, 0, 0]]
    # request 1: full length — plain causal
    assert v[1].tolist() == np.tril(np.ones((4, 4))).tolist()


def test_bucketed_tokens_match_exact_and_plaintext_centaur(params):
    """Exact-protocol serving: bucketed-padded prefill + decode ==
    exact-length prefill + decode == plaintext greedy, token for token,
    under a mixed-length (>= 4 distinct lengths) staggered workload,
    within the len(buckets) + 1 compiled-program budget."""
    toks_b, _, eng = _serve(params, "centaur", "pow2", decode_jit=True)
    toks_e, _, _ = _serve(params, "centaur", None, decode_jit=True)
    assert toks_b == toks_e, \
        "centaur: bucketed prefill changed the decoded tokens"
    cs = eng.compile_stats()
    assert cs["prefill_programs"] <= len(eng.buckets), cs
    assert cs["decode_programs"] == 1, cs
    peng = ServingEngine(GPT2_TINY, params, max_slots=3,
                         max_len=MAXLEN)
    prids = [peng.submit(p, max_new_tokens=NNEW) for p in PROMPTS]
    pouts = peng.run_to_completion()
    assert toks_b == [pouts[r] for r in prids], \
        "centaur: bucketed serving diverged from plaintext greedy"


def test_bucketed_tokens_match_exact_smpc(params):
    """The share-softmax baseline end-to-end: bucketed serving decodes
    the same tokens as exact-length serving (plaintext identity is the
    exact mode's contract only — the approximate baselines flip
    argmaxes on near-ties of their own accord, bucketed or not).
    Eager: compiling the baselines' NR stacks is minutes of XLA;
    jit-vs-eager parity is pinned by the ledger tests."""
    toks_b, _, _ = _serve(params, "smpc", "pow2", decode_jit=False,
                          slots=1, prompts=PROMPTS_LITE, n_new=2)
    toks_e, _, _ = _serve(params, "smpc", None, decode_jit=False,
                          slots=1, prompts=PROMPTS_LITE, n_new=2)
    assert toks_b == toks_e, \
        "smpc: bucketed prefill changed the decoded tokens"


@pytest.mark.parametrize("mode", ("smpc", "mpcformer", "secformer"))
def test_bucketed_prefill_logits_close_per_softmax_variant(params,
                                                           mode):
    """The masking contract per softmax variant (CrypTen limit-approx
    exp and 2Quad): padded prompt columns must carry exactly zero mass,
    so bucketed and exact-length prefill logits agree up to the
    protocols' own fixed-point noise (a masking bug shifts logits by
    O(1): dead columns at -MASK_MAGNITUDE would dominate the sum)."""
    prompt = [1, 2, 3]
    pm_e = build_private_model(GPT2_TINY, params, KEY, mode=mode)
    le, _ = private_prefill(pm_e, jnp.asarray([prompt], jnp.int32),
                            max_len=MAXLEN)
    pm_b = build_private_model(GPT2_TINY, params, KEY, mode=mode)
    lb, _ = private_prefill(
        pm_b, jnp.asarray([prompt + [0] * 5], jnp.int32),
        max_len=MAXLEN, lens=jnp.asarray([len(prompt)], jnp.int32))
    np.testing.assert_allclose(np.asarray(lb), np.asarray(le),
                               atol=0.05)


def test_compile_budget_under_mixed_lengths(params):
    """The acceptance bar: a mixed-length run (>= 4 distinct lengths)
    compiles at most len(buckets) prefill programs + 1 decode program,
    while the exact-length escape hatch compiles one prefill program
    per distinct length."""
    _, _, eng_b = _serve(params, "centaur", "pow2", decode_jit=True)
    cs = eng_b.compile_stats()
    n_lengths = len({len(p) for p in PROMPTS})
    assert n_lengths >= 4
    assert cs["prefill_programs"] == 2   # buckets 8 and 16 used
    assert cs["decode_programs"] == 1
    assert cs["prefills"] == len(PROMPTS)
    _, _, eng_e = _serve(params, "centaur", None, decode_jit=True)
    assert eng_e.compile_stats()["prefill_programs"] == n_lengths


def _ledger_pair(params, mode, prompt, bucket):
    toks = prompt + [0] * (bucket - len(prompt))
    toks = jnp.asarray([toks], jnp.int32)
    lens = jnp.asarray([len(prompt)], jnp.int32)
    pm_e = build_private_model(GPT2_TINY, params, KEY, mode=mode)
    with comm.ledger() as led_e:
        le, _ = private_prefill(pm_e, toks, max_len=MAXLEN, jit=False,
                                lens=lens)
    pm_j = build_private_model(GPT2_TINY, params, KEY, mode=mode,
                               use_pool=True)
    with comm.ledger() as led_j:
        lj, _ = private_prefill(pm_j, toks, max_len=MAXLEN, jit=True,
                                lens=lens)
    return led_e, led_j, np.asarray(le), np.asarray(lj)


def test_bucketed_prefill_ledger_bit_exact_per_bucket_centaur(params):
    """Per-bucket eager-vs-jit online-ledger bit-exactness: the padded
    path must bill the padded S^2 cost identically under capture/replay
    and eager execution (and centaur's exact protocol must produce the
    same argmax)."""
    for bucket in (4, 8):
        led_e, led_j, le, lj = _ledger_pair(params, "centaur",
                                            [1, 2, 3], bucket)
        assert led_e.total_bits() == led_j.total_bits(), bucket
        assert led_e.total_rounds() == led_j.total_rounds(), bucket
        assert le[0].argmax() == lj[0].argmax(), bucket


def test_bucketed_prefill_ledger_bit_exact_smpc(params):
    """Same contract for the share-softmax family (one bucket: each
    smpc prefill program is tens of seconds of XLA)."""
    led_e, led_j, _, _ = _ledger_pair(params, "smpc", [1, 2, 3], 8)
    assert led_e.total_bits() == led_j.total_bits()
    assert led_e.total_rounds() == led_j.total_rounds()


def test_bucketed_prefill_bills_padded_cost(params):
    """Bucketing is not free: the padded bucket's S^2 attention comm is
    billed (the serving bench reports the overhead), strictly above the
    exact-length bill and growing with the bucket."""
    pm = build_private_model(GPT2_TINY, params, KEY, mode="centaur")
    toks = jnp.asarray([[1, 2, 3]], jnp.int32)
    with comm.ledger() as led_exact:
        private_prefill(pm, toks, max_len=MAXLEN)
    bits = []
    for bucket in (4, 8):
        led_e, _, _, _ = _ledger_pair(params, "centaur", [1, 2, 3],
                                      bucket)
        bits.append(led_e.total_bits())
    assert led_exact.total_bits() < bits[0] < bits[1]


def test_bucket_validation():
    # typed config errors (not bare asserts: they must survive -O)
    with pytest.raises(EngineConfigError):
        PrivateServingEngine(GPT2_TINY, {}, KEY, max_len=16,
                             buckets=(8, 32))      # bucket > max_len
    with pytest.raises(EngineConfigError):
        PrivateServingEngine(GPT2_TINY, {}, KEY, max_len=16,
                             buckets=(4, 8))       # cannot admit cap
