"""Training driver example: train a reduced llama-family model on the
synthetic pipeline with checkpointing, preemption handling, and
straggler telemetry — the full fault-tolerant loop at laptop scale.

    PYTHONPATH=src python examples/train_lm.py [--steps 120]
"""
import argparse
import tempfile

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import DataPipeline
from repro.runtime.fault_tolerance import (PreemptionGuard,
                                           StragglerMonitor)
from repro.training.optimizer import OptConfig
from repro.training.train_loop import run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True).replace(num_layers=4)
    ckpt_dir = args.ckpt or tempfile.mkdtemp(prefix="repro_ckpt_")
    pipe = DataPipeline(cfg, global_batch=16, seq_len=64)
    mgr = CheckpointManager(ckpt_dir)
    straggler = StragglerMonitor()

    print(f"training {cfg.name} (reduced, {cfg.num_layers}L "
          f"d={cfg.d_model}) for {args.steps} steps; ckpt -> {ckpt_dir}")
    res = run_training(cfg, OptConfig(lr=3e-3, warmup_steps=20), pipe,
                       num_steps=args.steps, checkpoint_mgr=mgr,
                       ckpt_every=40, straggler=straggler,
                       preemption=PreemptionGuard(), log_every=10)
    for step, loss in res.losses:
        print(f"  step {step:4d}  loss {loss:.4f}")
    first, last = res.losses[0][1], res.losses[-1][1]
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'NOT improved'})")
    print(f"checkpoints on disk: steps {mgr.list_steps()}")
    assert last < first, "training should reduce loss on synthetic data"


if __name__ == "__main__":
    main()
