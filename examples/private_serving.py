"""End-to-end serving driver (the paper's workload is inference).

1. Plaintext serving: continuous-batching engine over a KV cache,
   several concurrent requests, greedy decoding.
2. Private serving: the same model behind the Centaur protocol —
   each generation step is a full private forward (shares in, permuted
   logits out, client de-permutes and feeds the next token back).
   Comm cost per generated token is reported like paper Fig 8.

    PYTHONPATH=src python examples/private_serving.py
"""
import time

import jax

from repro.configs.paper_models import GPT2_TINY as CFG
from repro.core import comm
from repro.models.registry import get_api
from repro.serving.engine import ServingEngine

NETWORKS = {"LAN(3Gbps,0.8ms)": (3e9, 0.8e-3),
            "WAN(100Mbps,80ms)": (100e6, 80e-3)}


def main():
    key = jax.random.key(0)
    api = get_api(CFG)
    params = api.init_params(CFG, key)

    # ---- 1. plaintext continuous batching --------------------------------
    eng = ServingEngine(CFG, params, max_slots=4, max_len=64)
    prompts = [[1, 2, 3], [7, 8], [9, 10, 11, 12], [3, 1], [5, 5, 5]]
    rids = [eng.submit(p, max_new_tokens=8) for p in prompts]
    t0 = time.monotonic()
    outs = eng.run_to_completion()
    dt = time.monotonic() - t0
    total = sum(len(v) for v in outs.values())
    print(f"[plain] served {len(prompts)} requests, {total} tokens "
          f"in {dt:.2f}s ({total / dt:.1f} tok/s on CPU)")
    for rid in rids[:2]:
        print(f"  req {rid}: {outs[rid]}")

    # ---- 2. private generation (Centaur, share-state KV cache) -----------
    from repro.serving.engine import PrivateServingEngine
    n_new = 3
    peng = PrivateServingEngine(CFG, params, key, max_len=32)
    rid_p = peng.submit([1, 2, 3], max_new_tokens=n_new)
    with comm.ledger() as led:
        outs_p, stats = peng.run_to_completion()
    seq = [1, 2, 3] + outs_p[rid_p]
    st = stats[rid_p]
    print(f"[centaur] generated {n_new} tokens privately: {seq[-n_new:]}")
    print(f"  comm: {st['online_bits'] / 8e6:.1f} MB online "
          f"(+{st['offline_bits'] / 8e6:.1f} MB offline, pooled), "
          f"{st['rounds']} rounds")
    for net, (bw, rtt) in NETWORKS.items():
        t = led.simulate_time(bw, rtt) / n_new
        print(f"  simulated network time/token {net}: {t:.2f}s")

    # plaintext-greedy agreement check
    eng2 = ServingEngine(CFG, params, max_slots=1, max_len=32)
    rid = eng2.submit([1, 2, 3], max_new_tokens=n_new)
    ref = eng2.run_to_completion()[rid][:n_new]
    assert ref == seq[-n_new:], (ref, seq[-n_new:])
    print("  private generation == plaintext greedy decoding ✓")


if __name__ == "__main__":
    main()
