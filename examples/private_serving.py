"""End-to-end serving driver (the paper's workload is inference).

1. Plaintext serving: continuous-batching engine over a KV cache,
   several concurrent requests, greedy decoding.
2. Private serving: the SAME continuous-batching loop behind the
   Centaur protocol — requests admitted into slots of a stacked padded
   share-domain KV cache, one jitted batched private decode step per
   tick, per-request comm attribution (paper Fig 8 style reporting).

    PYTHONPATH=src python examples/private_serving.py
"""
import time

import jax

from repro.configs.paper_models import GPT2_TINY as CFG
from repro.core import comm
from repro.models.registry import get_api
from repro.serving.engine import ServingEngine

NETWORKS = {"LAN(3Gbps,0.8ms)": (3e9, 0.8e-3),
            "WAN(100Mbps,80ms)": (100e6, 80e-3)}

PROMPTS = [[1, 2, 3], [7, 8], [9, 10, 11, 12], [3, 1], [5, 5, 5]]
N_NEW = 4
MAX_LEN = 24


def main():
    key = jax.random.key(0)
    api = get_api(CFG)
    params = api.init_params(CFG, key)

    # ---- 1. plaintext continuous batching --------------------------------
    eng = ServingEngine(CFG, params, max_slots=4, max_len=64)
    rids = [eng.submit(p, max_new_tokens=8) for p in PROMPTS]
    t0 = time.monotonic()
    outs = eng.run_to_completion()
    dt = time.monotonic() - t0
    total = sum(len(v) for v in outs.values())
    print(f"[plain] served {len(PROMPTS)} requests, {total} tokens "
          f"in {dt:.2f}s ({total / dt:.1f} tok/s on CPU)")
    for rid in rids[:2]:
        print(f"  req {rid}: {outs[rid]}")

    # ---- 2. private continuous batching (Centaur slot engine) ------------
    # buckets="pow2": mixed-length prompts compile at most len(buckets)
    # prefill programs + 1 decode program (DESIGN.md §9) instead of one
    # prefill program per distinct length
    from repro.serving.engine import PrivateServingEngine
    peng = PrivateServingEngine(CFG, params, key, max_slots=4,
                                max_len=MAX_LEN, buckets="pow2")
    for p in PROMPTS:                       # warm-up round: jit compiles
        peng.submit(p, max_new_tokens=N_NEW)
    peng.run_to_completion()
    rids_p = [peng.submit(p, max_new_tokens=N_NEW) for p in PROMPTS]
    with comm.ledger() as led:
        t0 = time.monotonic()
        outs_p, stats = peng.run_to_completion()
        dt = time.monotonic() - t0
    total = sum(len(outs_p[r]) for r in rids_p)
    cs = peng.compile_stats()
    print(f"[centaur] continuous batching: {len(PROMPTS)} requests, "
          f"{total} tokens in {dt:.2f}s ({total / dt:.1f} tok/s; "
          f"{len({len(p) for p in PROMPTS})} prompt lengths -> "
          f"{cs['prefill_programs']}+{cs['decode_programs']} compiled "
          f"programs via buckets {peng.buckets})")
    for rid in rids_p[:2]:
        st = stats[rid]
        print(f"  req {rid}: {outs_p[rid]}  "
              f"({st['online_bits'] / 8e6:.1f} MB online "
              f"+{st['offline_bits'] / 8e6:.1f} MB offline, "
              f"{st['rounds']} rounds)")
    # per-request attribution is exact: it sums back to the ledger
    assert sum(stats[r]["online_bits"] for r in rids_p) \
        == led.total_bits()
    for net, (bw, rtt) in NETWORKS.items():
        t = led.simulate_time(bw, rtt) / total
        print(f"  simulated network time/token {net}: {t:.2f}s")

    # sequential baseline: same engine, one slot — bit-identical tokens
    seng = PrivateServingEngine(CFG, params, key, max_slots=1,
                                max_len=MAX_LEN, buckets="pow2")
    for p in PROMPTS:                       # warm-up round: jit compiles
        seng.submit(p, max_new_tokens=N_NEW)
    seng.run_to_completion()
    rids_s = [seng.submit(p, max_new_tokens=N_NEW) for p in PROMPTS]
    t0 = time.monotonic()
    outs_s, _ = seng.run_to_completion()
    dt_s = time.monotonic() - t0
    assert [outs_p[r] for r in rids_p] == [outs_s[r] for r in rids_s]
    print(f"  sequential baseline: {total / dt_s:.1f} tok/s -> "
          f"batched speedup {dt_s / dt:.2f}x, same tokens ✓")

    # plaintext-greedy agreement check
    eng2 = ServingEngine(CFG, params, max_slots=1, max_len=MAX_LEN)
    rids2 = [eng2.submit(p, max_new_tokens=N_NEW) for p in PROMPTS]
    ref = eng2.run_to_completion()
    assert [ref[r] for r in rids2] == [outs_p[r] for r in rids_p]
    print("  private generation == plaintext greedy decoding ✓")

    # ---- 2b. chunked prefill for long prompts (DESIGN.md §10) ------------
    # chunk_size=C consumes each prompt as ceil(len/C) fixed-shape
    # chunks against the slot cache: ONE compiled chunk program for
    # every length mix, and the long-prompt comm bill stays near
    # S*max_len instead of the bucket ladder's padded S^2
    long_prompts = [list(range(1, 20)), list(range(2, 24)),
                    list(range(3, 19))]
    per_eng = {}
    for name, kw in (("chunked", {"chunk_size": 4}),
                     ("bucketed", {"buckets": "pow2"})):
        ceng = PrivateServingEngine(CFG, params, key, max_slots=4,
                                    max_len=MAX_LEN, **kw)
        rids_c = [ceng.submit(p, max_new_tokens=N_NEW)
                  for p in long_prompts]
        with comm.ledger() as led_c:
            outs_c, _ = ceng.run_to_completion()
        per_eng[name] = ([outs_c[r] for r in rids_c],
                         led_c.total_bits(), ceng.compile_stats())
    assert per_eng["chunked"][0] == per_eng["bucketed"][0], \
        "chunked serving changed the decoded tokens"
    cs = per_eng["chunked"][2]
    print(f"[centaur] chunked long prompts: {cs['chunk_programs']}+"
          f"{cs['decode_programs']} compiled programs over "
          f"{cs['chunk_ticks']} chunk ticks, online comm "
          f"{per_eng['chunked'][1] / 8e6:.1f} MB vs "
          f"{per_eng['bucketed'][1] / 8e6:.1f} MB bucketed "
          f"(same tokens ✓)")

    # ---- 2c. paged KV cache + prefix reuse + streaming (DESIGN.md §13) ---
    # paged=True swaps the dense (slots, max_len) caches for a
    # page-table share-domain cache; register_prefix caches a shared
    # system prompt once so hits skip its chunk ticks copy-on-write;
    # on_token streams every token the tick it is committed
    prefix = list(range(1, 9))
    streamed = []
    peng2 = PrivateServingEngine(CFG, params, key, max_slots=4,
                                 max_len=MAX_LEN, chunk_size=4,
                                 paged=True, page_size=8,
                                 on_token=lambda rid, tok:
                                     streamed.append((rid, tok)))
    peng2.register_prefix(prefix)
    rids_g = [peng2.submit(prefix + p, max_new_tokens=N_NEW)
              for p in ([9, 10], [11])] + \
             [peng2.submit([13, 14, 15], max_new_tokens=N_NEW)]
    outs_g, _ = peng2.run_to_completion()
    assert [tok for rid, tok in streamed if rid == rids_g[0]] \
        == outs_g[rids_g[0]], "stream disagrees with final output"
    h = peng2.health()["pages"]
    print(f"[centaur] paged serving: {len(rids_g)} requests, "
          f"{h['prefix_hits']} prefix hits, page high water "
          f"{h['high_water']}/{h['total']} "
          f"({len(streamed)} tokens streamed per tick ✓)")

    # ---- 3. the impossible trinity, end-to-end: SMPC baseline serving ----
    # Same engine, same slots, same executor — only the protocol suite
    # differs (mode="smpc").  The tokens/sec gap is the paper's headline
    # measured under identical continuous-batching conditions: both
    # engines serve the SAME request subset (two EQUAL-LENGTH prompts,
    # so the baseline compiles one prefill + one decode program; the
    # full measurement lives in benchmarks/private_serving_bench.py).
    duel_prompts = [PROMPTS[0], PROMPTS[4]]       # both length 3
    per_mode = {}
    for mode in ("centaur", "smpc"):
        eng3 = PrivateServingEngine(CFG, params, key, mode=mode,
                                    max_slots=4, max_len=MAX_LEN)
        for p in duel_prompts:              # warm-up round: jit compiles
            eng3.submit(p, max_new_tokens=N_NEW)
        eng3.run_to_completion()
        rids_m = [eng3.submit(p, max_new_tokens=N_NEW)
                  for p in duel_prompts]
        with comm.ledger() as led_m:
            t0 = time.monotonic()
            outs_m, _ = eng3.run_to_completion()
            dt_m = time.monotonic() - t0
        tok_m = sum(len(outs_m[r]) for r in rids_m)
        per_mode[mode] = tok_m / dt_m
        print(f"[{mode}] identical workload: {len(rids_m)} requests, "
              f"{tok_m} tokens in {dt_m:.2f}s ({tok_m / dt_m:.1f} tok/s,"
              f" {led_m.total_bytes() / 1e6:.1f} MB online)")
    print(f"  centaur vs smpc under identical serving: "
          f"{per_mode['centaur'] / per_mode['smpc']:.1f}x tokens/sec")


if __name__ == "__main__":
    main()
