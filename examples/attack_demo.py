"""Privacy demo (paper Table 2 / Fig 4): what the cloud sees, and what
an attacker can recover from it, across PPTI designs.

    PYTHONPATH=src python examples/attack_demo.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import numpy as np

from benchmarks.privacy_attack import (_reference_rows,
                                       distance_correlation,
                                       nn_inversion_rate)
from repro.configs.paper_models import BERT_TINY as CFG
from repro.core.permute import log2_brute_force_space
from repro.core.private_model import build_private_model, private_forward
from repro.models import layers as L
from repro.models.registry import get_api

import jax.numpy as jnp


def main():
    key = jax.random.key(0)
    api = get_api(CFG)
    params = api.init_params(CFG, key)
    B, S = 4, 24
    tokens = jax.random.randint(key, (B, S), 0, CFG.vocab_size)
    emb = L.embed(CFG, params["embed"], tokens,
                  positions=jnp.arange(S)[None].repeat(B, 0))

    pm_perm = build_private_model(CFG, params, key, mode="permute")
    private_forward(pm_perm, tokens)          # Yuan et al. STI baseline
    pm_cent = build_private_model(CFG, params, key, mode="centaur")
    private_forward(pm_cent, tokens)

    # per-position candidate rows (the attacker scores every vocab row,
    # plus the positional term, against every observed position)
    ref_rows = _reference_rows(CFG, params, B, S)
    flat = np.asarray(emb, np.float32).reshape(B * S, -1)

    print(f"{'observed by cloud':28s}{'NN token recovery':>20s}"
          f"{'dist. correlation':>20s}")
    for name, obs in [
        ("O4 plaintext (no protection)", np.asarray(pm_perm.exposed["O4"])),
        ("O4 permuted (Centaur)", np.asarray(pm_cent.exposed["O4"])),
        ("random matrix", np.asarray(jax.random.normal(
            key, pm_cent.exposed["O4"].shape))),
    ]:
        r = nn_inversion_rate(obs, ref_rows, tokens)
        d = distance_correlation(flat, obs.reshape(B * S, -1))
        print(f"{name:28s}{r:20.3f}{d:20.3f}")

    print("\nO1 = QK^T exposure (the permutation-only leak, paper Fig 4):")
    o1p = np.asarray(pm_perm.exposed["O1"])
    o1c = np.asarray(pm_cent.exposed["O1"]).reshape(o1p.shape)
    print(f"  Yuan et al. expose O1 in the clear     "
          f"dcor={distance_correlation(flat, o1p.transpose(0, 2, 1, 3).reshape(B * S, -1)):.3f}")
    print(f"  Centaur reveals only pi1-permuted O1   "
          f"dcor={distance_correlation(flat, o1c.transpose(0, 2, 1, 3).reshape(B * S, -1)):.3f}")
    print(f"\nbrute-force space of pi (d={CFG.d_model}): "
          f"2^{log2_brute_force_space(CFG.d_model):.0f} permutations")


if __name__ == "__main__":
    main()
