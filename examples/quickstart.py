"""Quickstart: private inference with Centaur in ~40 lines.

Runs the paper's three-party protocol end-to-end on a tiny GPT-2:
the model developer permutes weights, the client secret-shares tokens,
the two compute parties run ScalMul linears + permuted-state exact
nonlinearities — and the result matches plaintext inference.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_models import GPT2_TINY as CFG
from repro.core import comm
from repro.core.private_model import build_private_model, private_forward
from repro.models import layers as L
from repro.models.registry import get_api


def main():
    key = jax.random.key(0)
    api = get_api(CFG)
    params = api.init_params(CFG, key)                 # developer's model
    tokens = jax.random.randint(key, (1, 24), 0, CFG.vocab_size)  # client

    # --- plaintext reference -------------------------------------------
    hidden, _, _ = api.forward(CFG, params, {"tokens": tokens})
    plain = L.lm_head(CFG, params, params["embed"], hidden)[:, -1]

    # --- Centaur -------------------------------------------------------
    pm = build_private_model(CFG, params, key, mode="centaur")
    with comm.ledger() as led:
        private = private_forward(pm, tokens)[:, -1]

    err = float(np.max(np.abs(np.asarray(private) - np.asarray(plain))))
    print(f"model: {CFG.name} ({CFG.num_layers}L d={CFG.d_model})")
    print(f"max |private - plaintext| logit error: {err:.5f} "
          f"(fixed point, 2^-16 resolution)")
    print(f"argmax agrees: {bool((private.argmax(-1) == plain.argmax(-1)).all())}")
    print(f"online communication: {led.total_bytes() / 1e6:.2f} MB "
          f"in {led.total_rounds()} rounds")
    print("per-layer-kind breakdown (MB):")
    for tag, v in sorted(led.by_tag().items()):
        print(f"  {tag:12s} {v['bits'] / 8e6:9.2f}")


if __name__ == "__main__":
    main()
