"""§Roofline: aggregate the dry-run JSONs into the per-(arch x shape x
mesh) roofline table and nominate the three hillclimb cells."""
from __future__ import annotations

import glob
import json
import os

from .common import emit

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..",
                          "experiments", "dryrun")


def load_cells(directory=DRYRUN_DIR):
    cells = []
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def table(cells, mesh="16x16"):
    rows = []
    for c in cells:
        if c.get("mesh") != mesh or "terms" not in c:
            continue
        if "error" in c or "skipped" in c:
            continue
        t = c["terms"]
        bound = max(t.values())
        frac = t["compute_s"] / bound if bound else 0.0
        rows.append({
            "arch": c["arch"], "shape": c["shape"],
            "compute_s": t["compute_s"], "memory_s": t["memory_s"],
            "collective_s": t["collective_s"],
            "bottleneck": c["bottleneck"],
            "roofline_frac": frac,
            "useful_flops_frac": c.get("useful_flops_frac"),
            "model_flops": c.get("model_flops"),
            "hlo_flops": c.get("hlo_flops"),
        })
    return rows


def nominate_hillclimb(rows):
    """worst roofline fraction, most collective-bound, and the serving
    cell most representative of the paper (private inference = prefill/
    decode of a dense LM).  Trivial cells (< 10 ms of compute: a tiny
    model over-sharded onto 256 chips) are excluded — hillclimbing them
    optimizes launch overhead, not the model."""
    rows = [r for r in rows if r["compute_s"] > 0.01] or rows
    ranked = sorted(rows, key=lambda r: r["roofline_frac"])
    worst = ranked[0] if ranked else None
    coll = sorted(rows, key=lambda r: -(r["collective_s"]
                                        / max(r["compute_s"], 1e-12)))
    most_coll = next((r for r in coll if r is not worst), None)
    serving = [r for r in rows
               if r["shape"] in ("prefill_32k", "decode_32k")
               and r not in (worst, most_coll)]
    rep = sorted(serving, key=lambda r: -r["model_flops"] or 0)[0] \
        if serving else None
    return [r for r in (worst, most_coll, rep) if r]


def run():
    cells = load_cells()
    if not cells:
        emit("roofline/missing", 0.0, "run launch.dryrun first")
        return []
    rows = table(cells)
    for r in rows:
        emit(f"roofline/{r['arch']}/{r['shape']}", 0.0,
             f"compute={r['compute_s']:.2f}s;memory={r['memory_s']:.2f}s;"
             f"collective={r['collective_s']:.2f}s;"
             f"bottleneck={r['bottleneck']};frac={r['roofline_frac']:.3f}")
    picks = nominate_hillclimb(rows)
    for i, r in enumerate(picks):
        emit(f"roofline/hillclimb_{i}", 0.0,
             f"{r['arch']}/{r['shape']}:{r['bottleneck']}")
    return rows


if __name__ == "__main__":
    run()
