"""Kernel microbenchmarks.

CPU wall times are for the *interpret-mode* kernels (Python execution of
the kernel body) so they are correctness artifacts, not perf numbers;
the `derived` column carries the TPU-roofline expectation per call
(bytes/HBM_bw or flops/peak) which is the number that matters."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

from .common import emit, time_call

HBM_BW = 819e9
PEAK = 197e12
KEY = jax.random.key(5)


def run():
    # ring matmul: arithmetic intensity of the 10-dot narrow variant
    m = k = n = 256
    a = jax.lax.bitcast_convert_type(
        jax.random.bits(KEY, (m, k), dtype=jnp.uint32), jnp.int32)
    b = jax.lax.bitcast_convert_type(
        jax.random.bits(KEY, (k, n), dtype=jnp.uint32), jnp.int32)
    us = time_call(lambda: ops.ring_matmul32(a, b, interpret=True),
                   iters=2)
    int8_flops = 10 * 2 * m * n * k      # 10 int8 dots
    emit("kernel/ring_matmul32", us,
         f"int8_dot_flops={int8_flops:.2e};"
         f"tpu_est_us={int8_flops / PEAK * 1e6:.2f}")
    us = time_call(lambda: ops.ring64_matmul(
        a.astype(jnp.int64), b.astype(jnp.int64), interpret=True), iters=2)
    emit("kernel/ring64_matmul", us,
         f"int8_dot_flops={3.6 * 2 * m * n * k:.2e};"
         f"overhead_vs_bf16=36x_dots")

    # softmax / norm: bandwidth bound
    x = jax.random.normal(KEY, (512, 2048))
    us = time_call(lambda: ops.softmax(x, interpret=True), iters=2)
    bytes_ = 2 * x.size * 4
    emit("kernel/softmax", us,
         f"bytes={bytes_:.2e};tpu_est_us={bytes_ / HBM_BW * 1e6:.2f}")
    g = jnp.ones((2048,))
    us = time_call(lambda: ops.rmsnorm(x, g, interpret=True), iters=2)
    emit("kernel/rmsnorm", us,
         f"bytes={bytes_:.2e};tpu_est_us={bytes_ / HBM_BW * 1e6:.2f}")

    # flash attention: S^2 flops, O(S) memory
    Bh, S, D = 4, 512, 64
    q = jax.random.normal(KEY, (1, Bh, S, D), jnp.float32)
    us = time_call(lambda: ops.flash_attention(q, q, q, interpret=True),
                   iters=1)
    fl = 2 * 2 * Bh * S * S * D
    naive_bytes = Bh * S * S * 4 * 2 + 3 * Bh * S * D * 4
    flash_bytes = 4 * Bh * S * D * 4
    emit("kernel/flash_attention", us,
         f"flops={fl:.2e};hbm_bytes_naive={naive_bytes:.2e};"
         f"hbm_bytes_flash={flash_bytes:.2e};"
         f"traffic_reduction={naive_bytes / flash_bytes:.0f}x")

    # ssd scan
    Bt, L, H, P, N = 1, 512, 8, 32, 32
    ks = jax.random.split(KEY, 5)
    xs = jax.random.normal(ks[0], (Bt, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bt, L, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    B = jax.random.normal(ks[3], (Bt, L, 1, N))
    C = jax.random.normal(ks[4], (Bt, L, 1, N))
    us = time_call(lambda: ops.ssd_scan(xs, dt, A, B, C, chunk=64,
                                        interpret=True), iters=1)
    chunk = 64
    fl = 2 * Bt * L * chunk * H * (N + P)  # intra-chunk quadratic part
    emit("kernel/ssd_scan", us, f"flops~={fl:.2e};chunk={chunk}")


if __name__ == "__main__":
    run()
