"""Paper Table 2 / Fig 4 proxies: can the cloud P1 recover inference
data from what it observes?

Attacks runnable without GPU training (stand-ins for SIP/EIA/BRE):

  1. Nearest-neighbour inversion: P1 matches positions of an observed
     intermediate against the (attacker-known) embedding table + learned
     positions by cosine similarity — the optimization-free core of an
     embedding inversion attack.  Reported as token recovery rate
     (ROUGE-1 analog of paper Table 2).
  2. Moment-matching re-alignment: a *stronger* adversary first tries to
     undo the feature permutation by matching per-feature moments of the
     observed data against the public embedding statistics, then runs
     the NN attack.
  3. Distance correlation (paper Eq. 12 quantity).  NOTE: dcor is
     invariant to feature permutations (distances are preserved), so it
     does NOT separate W from W/O — exactly the paper's point that a
     permutation leaks no *more* than the un-permuted projection; the
     empirical separation comes from alignment-based attacks (1, 2),
     which the permutation defeats.

Conditions per paper Table 2: W/O = plaintext intermediates (what no
protection / Yuan et al. exposes), W = Centaur's permuted state,
Rand = random matrix baseline."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_models import BERT_TINY, GPT2_TINY
from repro.core.private_model import build_private_model, private_forward
from repro.models import layers as L
from repro.models.registry import get_api

from .common import emit

KEY = jax.random.key(11)


def distance_correlation(x, y) -> float:
    """Szekely et al. (2007) distance correlation of row samples."""
    x = np.asarray(x, np.float64).reshape(x.shape[0], -1)
    y = np.asarray(y, np.float64).reshape(y.shape[0], -1)

    def centered(a):
        d = np.sqrt(((a[:, None, :] - a[None, :, :]) ** 2).sum(-1))
        return d - d.mean(0) - d.mean(1)[:, None] + d.mean()

    ax, ay = centered(x), centered(y)
    dcov2 = (ax * ay).mean()
    dvx, dvy = (ax * ax).mean(), (ay * ay).mean()
    if dvx <= 0 or dvy <= 0:
        return 0.0
    return float(np.sqrt(max(dcov2, 0.0) / np.sqrt(dvx * dvy)))


def nn_inversion_rate(observed, ref_rows, tokens) -> float:
    """Cosine NN recovery.  observed: (B, S, d); ref_rows: (B, S, V, d)
    candidate embeddings per position (table + positional)."""
    obs = np.asarray(observed, np.float64)
    B, S, d = obs.shape
    ref = np.asarray(ref_rows, np.float64)
    obs_n = obs / (np.linalg.norm(obs, axis=-1, keepdims=True) + 1e-12)
    ref_n = ref / (np.linalg.norm(ref, axis=-1, keepdims=True) + 1e-12)
    sims = np.einsum("bsd,bsvd->bsv", obs_n, ref_n)
    pred = sims.argmax(-1)
    return float((pred == np.asarray(tokens)).mean())


def realign_by_moments(observed, reference) -> np.ndarray:
    """Adversarial de-permutation: sort observed features and reference
    features by (mean, std) and map ranks — the best generic alignment
    an attacker gets without labels."""
    obs = np.asarray(observed, np.float64).reshape(-1, observed.shape[-1])
    ref = np.asarray(reference, np.float64).reshape(-1, reference.shape[-1])
    key_obs = np.lexsort((obs.std(0), obs.mean(0)))
    key_ref = np.lexsort((ref.std(0), ref.mean(0)))
    inv = np.empty_like(key_ref)
    inv[key_ref] = np.arange(len(key_ref))
    perm_guess = key_obs[inv]  # observed feature for each ref feature
    out = np.asarray(observed, np.float64)[..., perm_guess]
    return out


def _reference_rows(cfg, params, batch, seq):
    """Candidate plaintext embeddings per position: W_E[v] (+ pos[s])."""
    table = np.asarray(params["embed"]["tok"], np.float32)  # (V, d)
    V, d = table.shape
    ref = np.broadcast_to(table[None, None], (batch, seq, V, d)).copy()
    if cfg.pos_embed == "learned":
        pos = np.asarray(params["embed"]["pos"], np.float32)[:seq]
        ref = ref + pos[None, :, None, :]
    return ref


def run(cfgs=(BERT_TINY, GPT2_TINY), seq=24, batch=4):
    results = {}
    for cfg in cfgs:
        api = get_api(cfg)
        params = api.init_params(cfg, KEY)
        tokens = jax.random.randint(KEY, (batch, seq), 0, cfg.vocab_size)
        emb = L.embed(cfg, params["embed"], tokens,
                      positions=jnp.arange(seq)[None].repeat(batch, 0)
                      if cfg.pos_embed == "learned" else None)

        pm_c = build_private_model(cfg, params, KEY, mode="centaur")
        private_forward(pm_c, tokens)
        ref = _reference_rows(cfg, params, batch, seq)
        flat_in = np.asarray(emb, np.float32).reshape(batch * seq, -1)

        conds = {
            "W/O(plaintext)": np.asarray(emb, np.float32),
            "W(centaur)": np.asarray(pm_c.exposed["XM"]),
            "Rand": np.asarray(jax.random.normal(
                KEY, emb.shape, jnp.float32)),
        }
        # auxiliary data for the oracle-table re-alignment attacker:
        # different tokens through the same (plaintext) embedding —
        # only available to an adversary holding the unpermuted Theta,
        # which Centaur's threat model explicitly denies P1.
        aux_tokens = jax.random.randint(jax.random.key(99),
                                        (batch, seq), 0, cfg.vocab_size)
        aux = L.embed(cfg, params["embed"], aux_tokens,
                      positions=jnp.arange(seq)[None].repeat(batch, 0)
                      if cfg.pos_embed == "learned" else None)

        rows = {}
        for name, obs in conds.items():
            nn = nn_inversion_rate(obs, ref, tokens)
            # estimated-moments attacker (aux data through plaintext
            # embedding) and the infinite-data limit (victim's own
            # moments) — both require the unpermuted table
            re_est = nn_inversion_rate(
                realign_by_moments(obs, np.asarray(aux, np.float32)),
                ref, tokens)
            re_lim = nn_inversion_rate(
                realign_by_moments(obs, np.asarray(emb, np.float32)),
                ref, tokens)
            dc = distance_correlation(flat_in,
                                      obs.reshape(batch * seq, -1))
            rows[name] = {"nn": nn, "realign_nn": re_est,
                          "realign_limit": re_lim, "dcor": dc}
            emit(f"table2/{cfg.name}/{name}", 0.0,
                 f"nn_recovery={nn:.3f};realign_est={re_est:.3f};"
                 f"realign_limit={re_lim:.3f};dcor={dc:.3f}")
        # the paper's separation, as assertions (attacker without the
        # plaintext parameters, i.e. Centaur's actual threat model):
        assert rows["W/O(plaintext)"]["nn"] > 0.9, rows
        assert rows["W(centaur)"]["nn"] < 0.15, rows
        # beyond-paper observation: an attacker WITH the unpermuted
        # embedding table can partially undo pi by moment matching on
        # un-normalized reveals — reported, not asserted (outside the
        # threat model; see EXPERIMENTS.md §Privacy).
        emit(f"table2/{cfg.name}/oracle_realign_note", 0.0,
             f"est={rows['W(centaur)']['realign_nn']:.3f};"
             f"limit={rows['W(centaur)']['realign_limit']:.3f};"
             "requires_plaintext_params=true")
        results[cfg.name] = rows

        from repro.core.permute import log2_brute_force_space
        emit(f"table2/{cfg.name}/bruteforce", 0.0,
             f"log2_perm_space={log2_brute_force_space(cfg.d_model):.0f}")
    return results


if __name__ == "__main__":
    run()
