"""Continuous-batching private decode benchmark (DESIGN.md §7).

Serves the same request set through the slot-based PrivateServingEngine
at slots ∈ {1, 2, 4} on the tiny dense config and reports warm
tokens/sec — slots=1 is the sequential baseline (same code path, batch
of one).  With the protocol-suite executor every servable PPTI mode
runs the identical serving loop, so `--mode centaur,smpc` (the default)
also measures the paper's headline end-to-end: the centaur-vs-smpc
tokens/sec ratio under identical continuous-batching conditions.

Each engine serves a warm-up round first so jit compiles and
triple-generator programs are excluded from the timed round; token
outputs are cross-checked against the *same-mode* sequential run on
every slot count.

Full runs also serve a mixed-length workload (>= 4 distinct prompt
lengths) through the bucketed prefill path — the first realistic-
traffic number for the impossible-trinity ratio: warm tokens/sec,
compiled-program counts (asserted <= len(buckets) prefill + 1 decode),
and the padded-vs-exact-length online comm bits (bucketing bills the
padded bucket's S^2 attention cost; the overhead is itself measured).

    PYTHONPATH=src python benchmarks/private_serving_bench.py \
        [--smoke] [--mode centaur,smpc] [--mixed-lengths]

Writes BENCH_private_serving.json next to the repo root.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

OUT = os.path.join(os.path.dirname(__file__), "..",
                   "BENCH_private_serving.json")

MODES = ("centaur", "smpc")


def _prompts(n_requests: int, length: int = 3):
    # deterministic varied content at a UNIFORM length: every engine
    # compiles exactly one prefill and one decode program, so the
    # timed warm round measures serving, not jit churn (mixed-length /
    # staggered-admission correctness is pinned by the tests)
    return [[(3 * i + j) % 300 + 1 for j in range(length)]
            for i in range(n_requests)]


MIXED_LENGTHS = (3, 5, 7, 10, 13, 2, 9, 6)


def _mixed_prompts(n_requests: int, max_len: int):
    # deterministic mixed-length traffic (>= 4 distinct lengths): the
    # realistic MLaaS arrival pattern the bucketed prefill path exists
    # for — an exact-length engine compiles one prefill program per
    # distinct length here
    return [[(5 * i + j) % 300 + 1
             for j in range(min(MIXED_LENGTHS[i % len(MIXED_LENGTHS)],
                                max_len - 1))]
            for i in range(n_requests)]


def _speedup_ratio(per_mode: dict) -> float | None:
    """centaur/smpc warm tokens-per-sec ratio at the best slot count
    (None when either mode is missing or degenerate — smoke runs)."""
    try:
        cent = max(r["tokens_per_sec"]
                   for r in per_mode["centaur"]["slots"].values())
        smpc = max(r["tokens_per_sec"]
                   for r in per_mode["smpc"]["slots"].values())
    except KeyError:
        return None
    if smpc <= 0:
        return None
    return round(cent / smpc, 3)


def _timed_rounds(eng, prompts, n_new: int, rounds: int):
    """Serve `prompts` through `eng` `rounds` times (the last round is
    the warm, timed one) and aggregate that round's per-request stats."""
    for _ in range(rounds):
        rids = [eng.submit(p, max_new_tokens=n_new) for p in prompts]
        t0 = time.monotonic()
        outs, stats = eng.run_to_completion()
        dt = time.monotonic() - t0
    tokens = [outs[r] for r in rids]
    per_req = [stats[r] for r in rids]
    total = sum(len(t) for t in tokens)
    return {"tokens": total,
            "time_s": round(dt, 4),
            "tokens_per_sec": round(total / dt, 2),
            "online_bits_total": sum(s["online_bits"] for s in per_req),
            "rounds_total": sum(s["rounds"] for s in per_req),
            }, tokens


def run_mode(mode: str, cfg, params, prompts, slot_counts, n_new: int,
             max_len: int, rounds: int):
    from repro.serving.engine import PrivateServingEngine

    results = {"slots": {}}
    baseline_tokens = None
    for slots in slot_counts:
        eng = PrivateServingEngine(cfg, params, jax.random.key(0),
                                   mode=mode, max_slots=slots,
                                   max_len=max_len)
        res, tokens = _timed_rounds(eng, prompts, n_new, rounds)
        if baseline_tokens is None:
            baseline_tokens = tokens
        assert tokens == baseline_tokens, \
            f"{mode} slots={slots} changed the decoded tokens"
        results["slots"][str(slots)] = res
        print(f"[private-serving] {mode} slots={slots}: "
              f"{res['tokens_per_sec']:.2f} tok/s warm "
              f"({res['tokens']} tokens, {res['time_s']:.2f}s)")

    seq = results["slots"].get("1")
    if seq and seq["tokens_per_sec"] > 0:
        for slots, r in results["slots"].items():
            r["speedup_vs_sequential"] = round(
                r["tokens_per_sec"] / seq["tokens_per_sec"], 3)
        best = max(r["speedup_vs_sequential"]
                   for r in results["slots"].values())
        print(f"[private-serving] {mode} best speedup vs sequential: "
              f"{best}x")
    return results


def run_mixed(mode: str, cfg, params, prompts, slots: int, n_new: int,
              max_len: int, rounds: int):
    """Mixed-length serving through the bucketed prefill path: warm
    tokens/sec, compiled-program counts (the bucketing guarantee:
    <= len(buckets) prefill + 1 decode programs no matter how lengths
    mix), and the comm overhead of padding — bucketed prefill bills the
    padded bucket's S^2 attention cost, so both the padded and the
    exact-length online bits are reported."""
    from repro.serving.engine import PrivateServingEngine

    eng = PrivateServingEngine(cfg, params, jax.random.key(0),
                               mode=mode, max_slots=slots,
                               max_len=max_len, buckets="pow2")
    res, tokens = _timed_rounds(eng, prompts, n_new, rounds)
    cs = eng.compile_stats()
    n_lengths = len({len(p) for p in prompts})
    assert cs["prefill_programs"] <= len(eng.buckets), \
        (f"{mode}: {cs['prefill_programs']} prefill programs for "
         f"{len(eng.buckets)} buckets — per-shape recompile regression")
    assert cs["decode_programs"] <= 1, cs
    padded_bits = res["online_bits_total"]

    # exact-length reference: same workload, exact prefill, eager (no
    # compiles; eager and jit bill bit-identical online ledgers)
    ref = PrivateServingEngine(cfg, params, jax.random.key(0),
                               mode=mode, max_slots=slots,
                               max_len=max_len, buckets=None,
                               decode_jit=False)
    rref = [ref.submit(p, max_new_tokens=n_new) for p in prompts]
    routs, rstats = ref.run_to_completion()
    tokens_match = [routs[r] for r in rref] == tokens
    if mode == "centaur":
        # exact protocol: jit-bucketed vs eager-exact must be
        # token-identical; the approximate baselines may flip a
        # near-tie argmax between jit and eager float rounding of
        # their own accord (bucketing parity itself is pinned
        # eager-vs-eager by tests/test_bucketed_prefill.py), so for
        # them the agreement is reported, not asserted
        assert tokens_match, \
            "centaur: bucketed prefill changed the decoded tokens"
    exact_bits = sum(rstats[r]["online_bits"] for r in rref)

    out = {
        "tokens_match_exact_length": tokens_match,
        "n_requests": len(prompts),
        "distinct_lengths": n_lengths,
        "buckets": list(eng.buckets),
        "prefill_programs": cs["prefill_programs"],
        "decode_programs": cs["decode_programs"],
        "tokens": res["tokens"],
        "time_s": res["time_s"],
        "tokens_per_sec": res["tokens_per_sec"],
        "online_bits_padded": padded_bits,
        "online_bits_exact_length": exact_bits,
        "padding_bits_overhead": round(padded_bits / exact_bits, 4),
    }
    print(f"[private-serving] {mode} mixed-lengths ({n_lengths} "
          f"lengths): {res['tokens_per_sec']:.2f} tok/s warm, "
          f"{cs['prefill_programs']}+{cs['decode_programs']} programs, "
          f"padding comm overhead {out['padding_bits_overhead']}x")
    return out


def run(slot_counts=(1, 2, 4), n_requests: int = 8, n_new: int = 6,
        max_len: int = 24, rounds: int = 2, out: str | None = OUT,
        smoke: bool = False, modes=MODES, mixed: bool | None = None,
        uniform: bool = True):
    from repro.configs.paper_models import GPT2_TINY as CFG
    from repro.models.registry import get_api

    if mixed is None:
        mixed = not smoke   # full runs always measure realistic traffic
    if smoke:
        n_requests, n_new, rounds = 4, 3, 2
        slot_counts = (1, 4)
    key = jax.random.key(0)
    params = get_api(CFG).init_params(CFG, key)
    prompts = _prompts(n_requests)

    results = {"config": CFG.name, "n_requests": n_requests,
               "n_new": n_new, "max_len": max_len, "modes": {}}
    if uniform:
        for mode in modes:
            results["modes"][mode] = run_mode(
                mode, CFG, params, prompts, slot_counts=slot_counts,
                n_new=n_new, max_len=max_len, rounds=rounds)
        ratio = _speedup_ratio(results["modes"])
        if ratio is not None:
            results["centaur_vs_smpc_tokens_per_sec"] = ratio
            print(f"[private-serving] centaur vs smpc (identical "
                  f"serving conditions): {ratio}x tokens/sec")
    if mixed:
        mslots = max(slot_counts)
        results["mixed_lengths"] = {
            mode: run_mixed(mode, CFG, params,
                            _mixed_prompts(n_requests, max_len),
                            slots=mslots, n_new=n_new, max_len=max_len,
                            rounds=rounds)
            for mode in modes}
        mm = results["mixed_lengths"]
        if "centaur" in mm and "smpc" in mm \
                and mm["smpc"]["tokens_per_sec"] > 0:
            r = round(mm["centaur"]["tokens_per_sec"]
                      / mm["smpc"]["tokens_per_sec"], 3)
            results["centaur_vs_smpc_tokens_per_sec_mixed"] = r
            print(f"[private-serving] centaur vs smpc under "
                  f"mixed-length traffic: {r}x tokens/sec")
    if out:
        with open(out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"[private-serving] wrote {os.path.abspath(out)}")
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run; skips writing the json")
    ap.add_argument("--mode", default=",".join(MODES),
                    help="comma-separated PPTI modes to serve "
                         "(default: centaur,smpc)")
    wl = ap.add_mutually_exclusive_group()
    wl.add_argument("--mixed-lengths", action="store_true",
                    help="serve the mixed-length workload through the "
                         "bucketed prefill path (always on for full "
                         "runs; use with --smoke for the CI "
                         "recompile-regression check)")
    wl.add_argument("--uniform-only", action="store_true",
                    help="skip the mixed-length workload")
    ap.add_argument("--out", default=OUT)
    args = ap.parse_args(argv)
    modes = tuple(m.strip() for m in args.mode.split(",") if m.strip())
    run(out=None if args.smoke else args.out, smoke=args.smoke,
        modes=modes,
        mixed=(True if args.mixed_lengths
               else False if args.uniform_only else None),
        uniform=not (args.smoke and args.mixed_lengths))


if __name__ == "__main__":
    main()
