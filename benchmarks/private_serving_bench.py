"""Continuous-batching private decode benchmark (DESIGN.md §7).

Serves the same request set through the slot-based PrivateServingEngine
at slots ∈ {1, 2, 4} on the tiny dense config and reports warm
tokens/sec — slots=1 is the sequential baseline (same code path, batch
of one).  With the protocol-suite executor every servable PPTI mode
runs the identical serving loop, so `--mode centaur,smpc` (the default)
also measures the paper's headline end-to-end: the centaur-vs-smpc
tokens/sec ratio under identical continuous-batching conditions.

Each engine serves a warm-up round first so jit compiles and
triple-generator programs are excluded from the timed round; token
outputs are cross-checked against the *same-mode* sequential run on
every slot count.

    PYTHONPATH=src python benchmarks/private_serving_bench.py \
        [--smoke] [--mode centaur,smpc]

Writes BENCH_private_serving.json next to the repo root.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

OUT = os.path.join(os.path.dirname(__file__), "..",
                   "BENCH_private_serving.json")

MODES = ("centaur", "smpc")


def _prompts(n_requests: int, length: int = 3):
    # deterministic varied content at a UNIFORM length: every engine
    # compiles exactly one prefill and one decode program, so the
    # timed warm round measures serving, not jit churn (mixed-length /
    # staggered-admission correctness is pinned by the tests)
    return [[(3 * i + j) % 300 + 1 for j in range(length)]
            for i in range(n_requests)]


def _speedup_ratio(per_mode: dict) -> float | None:
    """centaur/smpc warm tokens-per-sec ratio at the best slot count
    (None when either mode is missing or degenerate — smoke runs)."""
    try:
        cent = max(r["tokens_per_sec"]
                   for r in per_mode["centaur"]["slots"].values())
        smpc = max(r["tokens_per_sec"]
                   for r in per_mode["smpc"]["slots"].values())
    except KeyError:
        return None
    if smpc <= 0:
        return None
    return round(cent / smpc, 3)


def run_mode(mode: str, cfg, params, prompts, slot_counts, n_new: int,
             max_len: int, rounds: int):
    from repro.serving.engine import PrivateServingEngine

    results = {"slots": {}}
    baseline_tokens = None
    for slots in slot_counts:
        eng = PrivateServingEngine(cfg, params, jax.random.key(0),
                                   mode=mode, max_slots=slots,
                                   max_len=max_len)
        for _ in range(rounds):            # last round is the warm one
            rids = [eng.submit(p, max_new_tokens=n_new)
                    for p in prompts]
            t0 = time.monotonic()
            outs, stats = eng.run_to_completion()
            dt = time.monotonic() - t0
        tokens = [outs[r] for r in rids]
        if baseline_tokens is None:
            baseline_tokens = tokens
        assert tokens == baseline_tokens, \
            f"{mode} slots={slots} changed the decoded tokens"
        total = sum(len(t) for t in tokens)
        per_req = [stats[r] for r in rids]
        results["slots"][str(slots)] = {
            "tokens": total,
            "time_s": round(dt, 4),
            "tokens_per_sec": round(total / dt, 2),
            "online_bits_total": sum(s["online_bits"] for s in per_req),
            "rounds_total": sum(s["rounds"] for s in per_req),
        }
        print(f"[private-serving] {mode} slots={slots}: "
              f"{total / dt:.2f} tok/s warm ({total} tokens, {dt:.2f}s)")

    seq = results["slots"].get("1")
    if seq and seq["tokens_per_sec"] > 0:
        for slots, r in results["slots"].items():
            r["speedup_vs_sequential"] = round(
                r["tokens_per_sec"] / seq["tokens_per_sec"], 3)
        best = max(r["speedup_vs_sequential"]
                   for r in results["slots"].values())
        print(f"[private-serving] {mode} best speedup vs sequential: "
              f"{best}x")
    return results


def run(slot_counts=(1, 2, 4), n_requests: int = 8, n_new: int = 6,
        max_len: int = 24, rounds: int = 2, out: str | None = OUT,
        smoke: bool = False, modes=MODES):
    from repro.configs.paper_models import GPT2_TINY as CFG
    from repro.models.registry import get_api

    if smoke:
        n_requests, n_new, rounds = 4, 3, 2
        slot_counts = (1, 4)
    key = jax.random.key(0)
    params = get_api(CFG).init_params(CFG, key)
    prompts = _prompts(n_requests)

    results = {"config": CFG.name, "n_requests": n_requests,
               "n_new": n_new, "max_len": max_len, "modes": {}}
    for mode in modes:
        results["modes"][mode] = run_mode(
            mode, CFG, params, prompts, slot_counts=slot_counts,
            n_new=n_new, max_len=max_len, rounds=rounds)
    ratio = _speedup_ratio(results["modes"])
    if ratio is not None:
        results["centaur_vs_smpc_tokens_per_sec"] = ratio
        print(f"[private-serving] centaur vs smpc (identical serving "
              f"conditions): {ratio}x tokens/sec")
    if out:
        with open(out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"[private-serving] wrote {os.path.abspath(out)}")
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run; skips writing the json")
    ap.add_argument("--mode", default=",".join(MODES),
                    help="comma-separated PPTI modes to serve "
                         "(default: centaur,smpc)")
    ap.add_argument("--out", default=OUT)
    args = ap.parse_args(argv)
    modes = tuple(m.strip() for m in args.mode.split(",") if m.strip())
    run(out=None if args.smoke else args.out, smoke=args.smoke,
        modes=modes)


if __name__ == "__main__":
    main()
