"""Continuous-batching private decode benchmark (DESIGN.md §7).

Serves the same request set through the slot-based PrivateServingEngine
at slots ∈ {1, 2, 4} on the tiny dense config and reports warm
tokens/sec — slots=1 is the sequential baseline (same code path, batch
of one).  With the protocol-suite executor every servable PPTI mode
runs the identical serving loop, so `--mode centaur,smpc` (the default)
also measures the paper's headline end-to-end: the centaur-vs-smpc
tokens/sec ratio under identical continuous-batching conditions.

Each engine serves a warm-up round first so jit compiles and
triple-generator programs are excluded from the timed round; token
outputs are cross-checked against the *same-mode* sequential run on
every slot count.

Full runs also serve a mixed-length workload (>= 4 distinct prompt
lengths) through the bucketed prefill path — the first realistic-
traffic number for the impossible-trinity ratio: warm tokens/sec,
compiled-program counts (asserted <= len(buckets) prefill + 1 decode),
and the padded-vs-exact-length online comm bits (bucketing bills the
padded bucket's S^2 attention cost; the overhead is itself measured) —
and a long-prompt workload through the chunked prefill path
(DESIGN.md §10): ONE compiled chunk program, exact-length token parity,
and online bits below the bucket ladder's padded-S^2 bill — asserted
for EVERY mode now that weight-share masks persist (DESIGN.md §12).

Persistent weight masks (§12) are measured directly: each engine
reports its one-time `weight_open_bits` (asserted constant across
slot counts, i.e. in tokens served) and `weight_open_amortized`, and
SMPC-family modes get a decode-tick breakdown — online bits per tick
now, the reconstructed pre-§12 bill (tick + the removed per-GEMM
weight re-opens), and their ratio `decode_tick_online_bits_drop`
(asserted >= 2x for smpc).

    PYTHONPATH=src python benchmarks/private_serving_bench.py \
        [--smoke] [--mode centaur,smpc] [--mixed-lengths] \
        [--long-prompts]

Writes BENCH_private_serving.json next to the repo root.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

OUT = os.path.join(os.path.dirname(__file__), "..",
                   "BENCH_private_serving.json")

MODES = ("centaur", "smpc")


def _prompts(n_requests: int, length: int = 3):
    # deterministic varied content at a UNIFORM length: every engine
    # compiles exactly one prefill and one decode program, so the
    # timed warm round measures serving, not jit churn (mixed-length /
    # staggered-admission correctness is pinned by the tests)
    return [[(3 * i + j) % 300 + 1 for j in range(length)]
            for i in range(n_requests)]


MIXED_LENGTHS = (3, 5, 7, 10, 13, 2, 9, 6)


def _mixed_prompts(n_requests: int, max_len: int):
    # deterministic mixed-length traffic (>= 4 distinct lengths): the
    # realistic MLaaS arrival pattern the bucketed prefill path exists
    # for — an exact-length engine compiles one prefill program per
    # distinct length here
    return [[(5 * i + j) % 300 + 1
             for j in range(min(MIXED_LENGTHS[i % len(MIXED_LENGTHS)],
                                max_len - 1))]
            for i in range(n_requests)]


LONG_FRACTIONS = (0.72, 0.95, 0.8, 0.88, 0.7, 0.92, 0.76, 0.84)


def _long_prompts(n_requests: int, max_len: int):
    # long-prompt traffic (lengths clustered near max_len): the regime
    # where the bucket ladder's padded-S^2 bill dominates and chunked
    # prefill exists — every prompt lands in the TOP bucket, while the
    # chunk program bills ~S*max_len plus per-row protocol costs
    return [[(7 * i + j) % 300 + 1
             for j in range(min(int(LONG_FRACTIONS[i % len(LONG_FRACTIONS)]
                                    * max_len), max_len - 1))]
            for i in range(n_requests)]


def _speedup_ratio(per_mode: dict) -> float | None:
    """centaur/smpc warm tokens-per-sec ratio at the best slot count
    (None when either mode is missing or degenerate — smoke runs)."""
    try:
        cent = max(r["tokens_per_sec"]
                   for r in per_mode["centaur"]["slots"].values())
        smpc = max(r["tokens_per_sec"]
                   for r in per_mode["smpc"]["slots"].values())
    except KeyError:
        return None
    if smpc <= 0:
        return None
    return round(cent / smpc, 3)


def _timed_rounds(eng, prompts, n_new: int, rounds: int):
    """Serve `prompts` through `eng` `rounds` times (the last round is
    the warm, timed one) and aggregate that round's per-request stats."""
    for _ in range(rounds):
        rids = [eng.submit(p, max_new_tokens=n_new) for p in prompts]
        t0 = time.monotonic()
        outs, stats = eng.run_to_completion()
        dt = time.monotonic() - t0
    tokens = [outs[r] for r in rids]
    per_req = [stats[r] for r in rids]
    total = sum(len(t) for t in tokens)
    return {"tokens": total,
            "time_s": round(dt, 4),
            "tokens_per_sec": round(total / dt, 2),
            "online_bits_total": sum(s["online_bits"] for s in per_req),
            "rounds_total": sum(s["rounds"] for s in per_req),
            }, tokens


def _weight_reopen_bits_per_tick(wp) -> int:
    """What ONE tick additionally paid before persistent weight masks
    (DESIGN.md §12): every GEMM against a static weight re-opened
    F = W - B (2*numel(W)*RING_BITS online bits), and each opened
    weight tree (`{"f", "m"}`) is consumed by exactly one GEMM per
    decode tick — tied embed/head count twice, as the old per-GEMM
    opens did."""
    from repro.core import comm

    bits = 0

    def walk(t):
        nonlocal bits
        if isinstance(t, dict):
            if "f" in t and "m" in t:
                bits += 2 * comm.numel(t["f"].shape) * comm.RING_BITS
            else:
                for v in t.values():
                    walk(v)
        elif isinstance(t, (list, tuple)):
            for v in t:
                walk(v)

    walk(wp)
    return bits


def _decode_tick_stats(mode: str, cfg, params, slots: int,
                       max_len: int) -> dict:
    """One warm decode tick's online bill at the full slot width, plus
    the once-per-engine-lifetime `weight_open` ledger and the per-tick
    delta vs the pre-persistent-mask protocol (which re-opened every
    static weight on every tick)."""
    import jax.numpy as jnp

    from repro.core import comm
    from repro.core.private_model import (build_private_model,
                                          init_slot_caches,
                                          private_decode_step)

    with comm.ledger() as boot:
        pm = build_private_model(cfg, params, jax.random.key(0),
                                 mode=mode, use_pool=True)
    weight_open = sum(e.bits for e in boot.events
                      if e.protocol == "weight_open")
    caches = init_slot_caches(pm, slots, max_len)
    tok = jnp.ones((slots, 1), jnp.int32)
    _, caches = private_decode_step(                     # warm/compile
        pm, caches, tok, jnp.zeros((slots,), jnp.int32), jit=True)
    with comm.ledger() as led:
        private_decode_step(pm, caches, tok,
                            jnp.ones((slots,), jnp.int32), jit=True)
    tick = led.total_bits()
    reopen = (_weight_reopen_bits_per_tick(pm.wp)
              if weight_open else 0)
    out = {"decode_tick_online_bits": tick,
           "decode_tick_online_bits_pre_weight_masks": tick + reopen,
           "decode_tick_weight_reopen_bits_saved": reopen,
           "weight_open_bits": weight_open}
    if reopen:
        out["decode_tick_online_bits_drop"] = round(
            (tick + reopen) / tick, 3)
    return out


def _first_divergence_is_near_tie(cfg, params, prompt, base, new,
                                  tol: float = 0.25) -> bool:
    """Greedy decoding bifurcates when fixed-point truncation noise
    lands on an argmax near-tie — and the noise draw legitimately
    differs across slot counts (different dealer mask shapes).  After
    the first divergent token the histories differ, so later tokens
    are incomparable.  A cross-slot token mismatch in an approximate
    mode is acceptable iff the two candidates at the FIRST divergence
    are near-tied in the PLAINTEXT logits (tol ~ the documented
    smpc-family logit error bound)."""
    import jax.numpy as jnp
    import numpy as np

    from repro.models import layers as L
    from repro.models.registry import get_api

    k = next(i for i, (a, b) in enumerate(zip(base, new)) if a != b)
    api = get_api(cfg)
    seq = jnp.asarray([list(prompt) + list(base[:k])], jnp.int32)
    hid, _, _ = api.forward(cfg, params, {"tokens": seq})
    lg = np.asarray(L.lm_head(cfg, params.get("head", {}),
                              params["embed"], hid))[0, -1]
    return abs(float(lg[base[k]] - lg[new[k]])) < tol


def run_mode(mode: str, cfg, params, prompts, slot_counts, n_new: int,
             max_len: int, rounds: int):
    from repro.serving.engine import PrivateServingEngine

    results = {"slots": {}}
    baseline_tokens = None
    weight_open_by_slots = {}
    for slots in slot_counts:
        eng = PrivateServingEngine(cfg, params, jax.random.key(0),
                                   mode=mode, max_slots=slots,
                                   max_len=max_len)
        res, tokens = _timed_rounds(eng, prompts, n_new, rounds)
        if baseline_tokens is None:
            baseline_tokens = tokens
        if tokens != baseline_tokens:
            # exact protocol: strict identity; approximate baselines
            # may flip a genuine near-tie (same stance as the
            # mixed/long-prompt checks below)
            flips = [(p, a, b) for p, a, b in
                     zip(prompts, baseline_tokens, tokens) if a != b]
            assert mode != "centaur" and all(
                _first_divergence_is_near_tie(cfg, params, p, a, b)
                for p, a, b in flips), \
                f"{mode} slots={slots} changed the decoded tokens"
        res["weight_open_bits"] = eng.weight_open_bits
        if res["tokens"]:
            res["weight_open_amortized"] = round(
                eng.weight_open_bits / res["tokens"], 1)
        weight_open_by_slots[slots] = eng.weight_open_bits
        results["slots"][str(slots)] = res
        print(f"[private-serving] {mode} slots={slots}: "
              f"{res['tokens_per_sec']:.2f} tok/s warm "
              f"({res['tokens']} tokens, {res['time_s']:.2f}s)")
    # the one-time weight-open bill is an engine-lifetime constant:
    # identical across slot counts (= served token counts)
    assert len(set(weight_open_by_slots.values())) == 1, \
        f"{mode}: weight_open_bits varies with serving {weight_open_by_slots}"
    results["tick"] = _decode_tick_stats(mode, cfg, params,
                                         slots=max(slot_counts),
                                         max_len=max_len)
    if "decode_tick_online_bits_drop" in results["tick"]:
        print(f"[private-serving] {mode} decode tick: "
              f"{results['tick']['decode_tick_online_bits']} online bits "
              f"({results['tick']['decode_tick_online_bits_drop']}x drop "
              f"vs per-tick weight re-opens)")

    seq = results["slots"].get("1")
    if seq and seq["tokens_per_sec"] > 0:
        for slots, r in results["slots"].items():
            r["speedup_vs_sequential"] = round(
                r["tokens_per_sec"] / seq["tokens_per_sec"], 3)
        best = max(r["speedup_vs_sequential"]
                   for r in results["slots"].values())
        print(f"[private-serving] {mode} best speedup vs sequential: "
              f"{best}x")
    return results


def run_mixed(mode: str, cfg, params, prompts, slots: int, n_new: int,
              max_len: int, rounds: int):
    """Mixed-length serving through the bucketed prefill path: warm
    tokens/sec, compiled-program counts (the bucketing guarantee:
    <= len(buckets) prefill + 1 decode programs no matter how lengths
    mix), and the comm overhead of padding — bucketed prefill bills the
    padded bucket's S^2 attention cost, so both the padded and the
    exact-length online bits are reported."""
    from repro.serving.engine import PrivateServingEngine

    eng = PrivateServingEngine(cfg, params, jax.random.key(0),
                               mode=mode, max_slots=slots,
                               max_len=max_len, buckets="pow2")
    res, tokens = _timed_rounds(eng, prompts, n_new, rounds)
    cs = eng.compile_stats()
    n_lengths = len({len(p) for p in prompts})
    assert cs["prefill_programs"] <= len(eng.buckets), \
        (f"{mode}: {cs['prefill_programs']} prefill programs for "
         f"{len(eng.buckets)} buckets — per-shape recompile regression")
    assert cs["decode_programs"] <= 1, cs
    padded_bits = res["online_bits_total"]

    # exact-length reference: same workload, exact prefill, eager (no
    # compiles; eager and jit bill bit-identical online ledgers)
    ref = PrivateServingEngine(cfg, params, jax.random.key(0),
                               mode=mode, max_slots=slots,
                               max_len=max_len, buckets=None,
                               decode_jit=False)
    rref = [ref.submit(p, max_new_tokens=n_new) for p in prompts]
    routs, rstats = ref.run_to_completion()
    tokens_match = [routs[r] for r in rref] == tokens
    if mode == "centaur":
        # exact protocol: jit-bucketed vs eager-exact must be
        # token-identical; the approximate baselines may flip a
        # near-tie argmax between jit and eager float rounding of
        # their own accord (bucketing parity itself is pinned
        # eager-vs-eager by tests/test_bucketed_prefill.py), so for
        # them the agreement is reported, not asserted
        assert tokens_match, \
            "centaur: bucketed prefill changed the decoded tokens"
    exact_bits = sum(rstats[r]["online_bits"] for r in rref)

    out = {
        "tokens_match_exact_length": tokens_match,
        "n_requests": len(prompts),
        "distinct_lengths": n_lengths,
        "buckets": list(eng.buckets),
        "prefill_programs": cs["prefill_programs"],
        "decode_programs": cs["decode_programs"],
        "tokens": res["tokens"],
        "time_s": res["time_s"],
        "tokens_per_sec": res["tokens_per_sec"],
        "online_bits_padded": padded_bits,
        "online_bits_exact_length": exact_bits,
        "padding_bits_overhead": round(padded_bits / exact_bits, 4),
    }
    print(f"[private-serving] {mode} mixed-lengths ({n_lengths} "
          f"lengths): {res['tokens_per_sec']:.2f} tok/s warm, "
          f"{cs['prefill_programs']}+{cs['decode_programs']} programs, "
          f"padding comm overhead {out['padding_bits_overhead']}x")
    return out


def run_long(mode: str, cfg, params, prompts, slots: int, n_new: int,
             max_len: int, rounds: int, chunk_size: int):
    """Long-prompt serving, chunked vs bucketed (DESIGN.md §10): the
    chunk engine must hold the 1 chunk + 1 decode program budget,
    decode the exact-length tokens (centaur), and undercut the bucket
    ladder's padded-S^2 online bill — the measured trade the chunked
    prefill path exists for."""
    from repro.serving.engine import PrivateServingEngine

    eng = PrivateServingEngine(cfg, params, jax.random.key(0),
                               mode=mode, max_slots=slots,
                               max_len=max_len, chunk_size=chunk_size)
    res_c, tokens_c = _timed_rounds(eng, prompts, n_new, rounds)
    cs = eng.compile_stats()
    assert cs["chunk_programs"] == 1, \
        (f"{mode}: {cs['chunk_programs']} chunk programs — the chunked "
         f"path must compile ONCE per (chunk_size, max_len)")
    assert cs["prefill_programs"] == 1 and cs["decode_programs"] <= 1, cs

    bng = PrivateServingEngine(cfg, params, jax.random.key(0),
                               mode=mode, max_slots=slots,
                               max_len=max_len, buckets="pow2")
    res_b, tokens_b = _timed_rounds(bng, prompts, n_new, rounds)

    # exact-length reference: eager (no compiles; eager and jit bill
    # bit-identical online ledgers)
    ref = PrivateServingEngine(cfg, params, jax.random.key(0),
                               mode=mode, max_slots=slots,
                               max_len=max_len, decode_jit=False)
    rref = [ref.submit(p, max_new_tokens=n_new) for p in prompts]
    routs, rstats = ref.run_to_completion()
    tokens_match = [routs[r] for r in rref] == tokens_c
    chunk_bits = res_c["online_bits_total"]
    bucket_bits = res_b["online_bits_total"]
    exact_bits = sum(rstats[r]["online_bits"] for r in rref)
    if mode == "centaur":
        assert tokens_match, \
            "centaur: chunked prefill changed the decoded tokens"
        assert tokens_b == tokens_c, \
            "centaur: chunked and bucketed serving disagree"
    # with persistent weight masks (DESIGN.md §12) the chunked bill
    # undercuts the bucket ladder in EVERY servable mode, not just
    # centaur — the previously-impossible smpc assertion
    assert chunk_bits < bucket_bits, \
        (f"{mode} long prompts: chunked online bits {chunk_bits} "
         f"not below bucketed {bucket_bits}")

    out = {
        "tokens_match_exact_length": tokens_match,
        "n_requests": len(prompts),
        "chunk_size": chunk_size,
        "lengths": sorted({len(p) for p in prompts}),
        "chunk_programs": cs["chunk_programs"],
        "decode_programs": cs["decode_programs"],
        "chunk_ticks": cs["chunk_ticks"],
        "tokens": res_c["tokens"],
        "tokens_per_sec_chunked": res_c["tokens_per_sec"],
        "tokens_per_sec_bucketed": res_b["tokens_per_sec"],
        "online_bits_chunked": chunk_bits,
        "online_bits_bucketed": bucket_bits,
        "online_bits_exact_length": exact_bits,
        "chunked_vs_bucketed_bits": round(chunk_bits / bucket_bits, 4),
        "chunked_vs_exact_bits": round(chunk_bits / exact_bits, 4),
    }
    print(f"[private-serving] {mode} long-prompts (C={chunk_size}): "
          f"{res_c['tokens_per_sec']:.2f} tok/s chunked vs "
          f"{res_b['tokens_per_sec']:.2f} bucketed warm, "
          f"{cs['chunk_programs']}+{cs['decode_programs']} programs, "
          f"chunked comm {out['chunked_vs_bucketed_bits']}x of bucketed "
          f"({out['chunked_vs_exact_bits']}x of exact-length)")
    return out


def run_paged(mode: str, cfg, params, prompts, slots: int, n_new: int,
              max_len: int, chunk_size: int, page_size: int):
    """Paged share-domain KV cache (DESIGN.md §13): token parity
    against the dense slot cache under mixed-length traffic, the
    live-page memory ratio (high-water live pages vs the dense
    engine's always-reserved max_slots*max_len rows — gated <= 0.5x),
    and batched-admission throughput at 4 simultaneous mixed-length
    arrivals vs one-request-at-a-time admission (gated >= 1.5x)."""
    from repro.serving.engine import PrivateServingEngine

    def mk(**kw):
        return PrivateServingEngine(cfg, params, jax.random.key(0),
                                    mode=mode, max_slots=slots,
                                    max_len=max_len,
                                    chunk_size=chunk_size, **kw)

    dense = mk()
    rd = [dense.submit(p, max_new_tokens=n_new) for p in prompts]
    t0 = time.monotonic()
    outs_d, _ = dense.run_to_completion()
    dt_d = time.monotonic() - t0
    paged = mk(paged=True, page_size=page_size)
    rp = [paged.submit(p, max_new_tokens=n_new) for p in prompts]
    t0 = time.monotonic()
    outs_p, _ = paged.run_to_completion()
    dt_p = time.monotonic() - t0
    tokens_d = [outs_d[r] for r in rd]
    tokens_p = [outs_p[r] for r in rp]
    if tokens_d != tokens_p:
        flips = [(p, a, b) for p, a, b in zip(prompts, tokens_d,
                                              tokens_p) if a != b]
        assert mode != "centaur" and all(
            _first_divergence_is_near_tie(cfg, params, p, a, b)
            for p, a, b in flips), \
            f"{mode}: paged tokens diverge from the dense slot cache"
    # dense reserves max_slots*max_len rows for the engine lifetime;
    # paged memory is the high-water count of live pages
    dense_rows = slots * max_len
    live_ratio = round(paged.alloc.high_water * page_size
                       / dense_rows, 4)
    assert live_ratio <= 0.5, \
        (f"{mode}: live-page memory {live_ratio}x of dense — paging "
         f"is not earning its keep at this length mix")
    assert paged.alloc.used == 0, "pages leaked past eviction"

    # batched admission: 4 simultaneous long-ish arrivals (mixed
    # lengths, several chunks each), timed at the admission seam
    # (prefill only: max_new=1), both engines warm
    arrivals = _long_prompts(4, max_len // 2)

    def admit_time(batch: bool):
        eng = mk(paged=True, page_size=page_size,
                 batch_admission=batch)
        eng.submit(arrivals[0], max_new_tokens=1)   # warm/compile
        eng.run_to_completion()
        for p in arrivals:
            eng.submit(p, max_new_tokens=1)
        t0 = time.monotonic()
        eng._admit()
        dt = time.monotonic() - t0
        outs, _ = eng.run_to_completion()
        return dt, [outs[r] for r in sorted(outs)]

    dt_seq, toks_seq = admit_time(batch=False)
    dt_bat, toks_bat = admit_time(batch=True)
    assert toks_seq == toks_bat, \
        f"{mode}: batched admission changed tokens"
    admit_tokens = sum(len(p) for p in arrivals)
    speedup = round(dt_seq / dt_bat, 3)
    assert speedup >= 1.5, \
        (f"{mode}: batched admission {speedup}x — prefill dispatch "
         f"collapse regressed")

    out = {
        "tokens_match_dense": tokens_d == tokens_p,
        "n_requests": len(prompts),
        "page_size": page_size,
        "num_pages": paged.alloc.n_pages,
        "high_water_pages": paged.alloc.high_water,
        "live_page_memory_ratio": live_ratio,
        "tokens_per_sec_dense": round(sum(map(len, tokens_d)) / dt_d,
                                      2),
        "tokens_per_sec_paged": round(sum(map(len, tokens_p)) / dt_p,
                                      2),
        "admission": {
            "arrivals": len(arrivals),
            "prompt_tokens": admit_tokens,
            "sequential_s": round(dt_seq, 4),
            "batched_s": round(dt_bat, 4),
            "sequential_tokens_per_sec": round(admit_tokens / dt_seq,
                                               2),
            "batched_tokens_per_sec": round(admit_tokens / dt_bat, 2),
            "batched_speedup": speedup,
        },
    }
    print(f"[private-serving] {mode} paged (P={page_size}): live "
          f"memory {live_ratio}x of dense, batched admission "
          f"{speedup}x ({out['admission']['batched_tokens_per_sec']:.0f}"
          f" vs {out['admission']['sequential_tokens_per_sec']:.0f} "
          f"prompt tok/s at {len(arrivals)} arrivals)")
    return out


def run_prefix_cache(mode: str, cfg, params, max_len: int,
                     chunk_size: int, page_size: int):
    """Shared-prefix COW caching: a hit request must skip EXACTLY its
    skipped chunk ticks' online bits.  max_new_tokens=1 keeps stats
    prefill-only; the per-tick bill b_t is measured from two fresh
    engines (1-tick vs 2-tick prompts), and the gate is
    saved >= 0.999 * skipped_ticks * b_t — i.e. hits save at least the
    prefix share of the online prefill chunk bits."""
    from repro.serving.engine import PrivateServingEngine

    C, P = chunk_size, page_size
    prefix = [(11 * j) % 300 + 1 for j in range(2 * P)]  # two pages
    suffix = [(13 * j) % 300 + 1 for j in range(C - 1)]
    prompt = prefix + suffix

    def serve(toks, register: bool):
        eng = PrivateServingEngine(cfg, params, jax.random.key(0),
                                   mode=mode, max_slots=2,
                                   max_len=max_len,
                                   chunk_size=C, paged=True,
                                   page_size=P)
        if register:
            eng.register_prefix(prefix)
        rid = eng.submit(toks, max_new_tokens=1)
        outs, stats = eng.run_to_completion()
        return stats[rid]["online_bits"], outs[rid], eng

    miss_bits, tok_m, _ = serve(prompt, register=False)
    hit_bits, tok_h, eng = serve(prompt, register=True)
    assert eng.prefix_hits == 1, "prefix never hit"
    assert tok_m == tok_h, f"{mode}: prefix hit changed tokens"
    t_miss = -(-len(prompt) // C)
    t_hit = -(-(len(prompt) - 2 * P) // C)
    # per-chunk-tick online bits, by difference of fresh 1/2-tick runs
    one, _, _ = serve(prompt[:C], register=False)
    two, _, _ = serve(prompt[:2 * C], register=False)
    b_t = two - one
    saved = miss_bits - hit_bits
    expected = (t_miss - t_hit) * b_t
    assert saved >= 0.999 * expected, \
        (f"{mode}: prefix hit saved {saved} online bits, expected "
         f"~{expected} ({t_miss - t_hit} skipped ticks x {b_t})")
    out = {
        "prefix_tokens": len(prefix),
        "prefix_pages": 2,
        "prompt_tokens": len(prompt),
        "chunk_ticks_miss": t_miss,
        "chunk_ticks_hit": t_hit,
        "online_bits_miss": miss_bits,
        "online_bits_hit": hit_bits,
        "online_bits_saved": saved,
        "online_bits_per_tick": b_t,
        "prefill_bits_saved_ratio": round(saved / miss_bits, 4),
        "prefix_fill_bits_engine": eng.prefix_bits,
    }
    print(f"[private-serving] {mode} prefix-cache: hit skips "
          f"{t_miss - t_hit}/{t_miss} chunk ticks, saving {saved} "
          f"online prefill bits "
          f"({out['prefill_bits_saved_ratio']:.0%} of a miss)")
    return out


TRANSPORT_RTTS = (0.0, 1.0, 10.0)
LAN_BANDWIDTH_BPS = 3e9          # paper's LAN point for the bits term


def run_transport(modes, cfg, params, prompts, slots: int, n_new: int,
                  max_len: int, rtts=TRANSPORT_RTTS):
    """Measured serving throughput over the REAL socket transport
    (DESIGN.md §14) under injected per-round RTT, against the loopback
    reference and the closed-form analytic model.

    Each mode serves the workload through one warm jitted socket
    engine, once per RTT point, whose replayed comm schedule moves
    size-faithful bytes and blocks rounds * rtt on the wire — the
    measured realization of the `simulate_time` closed form, which is
    reported alongside (`analytic_network_s`, LAN bits term).  Tokens
    are asserted identical to loopback at every RTT.  The headline is
    the paper's round-complexity claim made wall-clock: the seconds
    per token that the largest RTT ADDS over rtt=0 must be strictly
    smaller for centaur than for smpc (fewer rounds per token -> a
    flatter RTT curve in absolute time; the normalized tok/s slowdown
    is reported too, but a slow compute baseline can mask round count
    there, so the gate is on added time)."""
    import numpy as np  # noqa: F401  (kept: parity with sibling runners)

    from repro.core import comm
    from repro.serving.engine import PrivateServingEngine

    def serve(eng):
        rids = [eng.submit(p, max_new_tokens=n_new) for p in prompts]
        with comm.ledger() as led:
            t0 = time.monotonic()
            outs, _ = eng.run_to_completion()
            dt = time.monotonic() - t0
        return [outs[r] for r in rids], dt, led

    out = {"rtt_ms": list(rtts), "slots": slots,
           "n_requests": len(prompts), "n_new": n_new, "modes": {}}
    for mode in modes:
        per = {}
        # LOCKSTEP engines: serving the same workload repeatedly from
        # one engine consumes fresh pool triples each serve, and the
        # approximate modes' ±1-LSB triple noise can flip a near-tie
        # token between serves — so the loopback reference and the
        # socket engine are built from the same key and serve the SAME
        # number of rounds, and each socket serve is compared to the
        # loopback serve at the same index (identical triple stream ->
        # bit-identical tokens, the §14 parity contract).  The RTT
        # sweep re-shapes the one live socket transport between serves
        # (the reply delay is computed per message from
        # transport.rtt_s, so no respawn is needed).
        ref = PrivateServingEngine(cfg, params, jax.random.key(0),
                                   mode=mode, max_slots=slots,
                                   max_len=max_len, transport="loopback")
        eng = PrivateServingEngine(cfg, params, jax.random.key(0),
                                   mode=mode, max_slots=slots,
                                   max_len=max_len, transport="socket")
        serve(ref)                            # warm pair (jit compiles)
        serve(eng)
        for rtt in rtts:
            eng.transport.rtt_s = float(rtt) / 1e3
            ts0 = eng.transport.stats()
            base_tokens, _, _ = serve(ref)
            tokens, dt, led = serve(eng)
            assert tokens == base_tokens, \
                (f"{mode} rtt={rtt}: socket transport changed the "
                 f"decoded tokens")
            ts = eng.transport.stats()
            total = sum(len(t) for t in tokens)
            per[str(rtt)] = {
                "tokens": total,
                "time_s": round(dt, 4),
                "tokens_per_sec": round(total / dt, 2),
                "wire_s": round(ts["wire_s"] - ts0["wire_s"], 4),
                "wire_bytes": ts["bytes_moved"] - ts0["bytes_moved"],
                "billed_rounds": led.total_rounds(),
                "billed_online_bits": led.total_bits(),
                "analytic_network_s": round(
                    led.simulate_time(LAN_BANDWIDTH_BPS, rtt / 1e3), 4),
            }
        ref.close()
        eng.close()
        lo, hi = str(rtts[0]), str(rtts[-1])
        slowdown = round(per[lo]["tokens_per_sec"]
                         / per[hi]["tokens_per_sec"], 3)
        added = round((per[hi]["time_s"] - per[lo]["time_s"])
                      / per[hi]["tokens"], 5)
        out["modes"][mode] = {"rtt": per,
                              "slowdown_at_max_rtt": slowdown,
                              "added_s_per_token_at_max_rtt": added}
        print(f"[private-serving] transport {mode}: "
              + ", ".join(f"{r}ms -> {per[str(r)]['tokens_per_sec']}"
                          f" tok/s" for r in rtts)
              + f" (+{added * 1e3:.1f} ms/token at {rtts[-1]}ms)")
    if "centaur" in out["modes"] and "smpc" in out["modes"] \
            and len(rtts) > 1:
        c = out["modes"]["centaur"]["added_s_per_token_at_max_rtt"]
        s = out["modes"]["smpc"]["added_s_per_token_at_max_rtt"]
        # the impossible-trinity round claim, measured on a real wire:
        # centaur's RTT curve must be strictly flatter than smpc's
        assert c < s, \
            (f"centaur added {c}s/token not strictly below smpc {s} at "
             f"rtt={rtts[-1]}ms — the round-complexity win vanished "
             f"on the measured transport")
        out["centaur_vs_smpc_rtt_resilience"] = round(s / c, 3)
        print(f"[private-serving] transport: {rtts[-1]}ms RTT adds "
              f"{c * 1e3:.1f} ms/token to centaur vs {s * 1e3:.1f} to "
              f"smpc ({out['centaur_vs_smpc_rtt_resilience']}x more "
              f"RTT-resilient)")
    return out


CHAOS_PLANS = (
    ("corrupt_open_prefill",
     dict(kind="corrupt_open", phase="prefill", rid=0, index=2)),
    ("nan_logits_decode", dict(kind="nan_logits", phase="decode", rid=0)),
    ("transport_drop_decode",
     dict(kind="transport_drop", phase="decode", index=4)),
    ("pool_exhaust_decode",
     dict(kind="pool_exhaust", phase="decode", index=3, persist=True)),
)


def run_chaos(mode: str, cfg, params, prompts, slots: int, n_new: int,
              max_len: int):
    """Chaos smoke (DESIGN.md §11): serve the workload under each
    representative fault plan with the paranoid guards armed, and hold
    the robustness contract — every request is either token-identical
    to the fault-free run or marked failed/quarantined with exact
    partial comm accounting, and the engine ends with no stuck slots.
    Eager (value corruption skips tracers by design)."""
    from repro.core import comm
    from repro.runtime import faults
    from repro.serving.engine import PrivateServingEngine

    def serve(injector=None):
        eng = PrivateServingEngine(cfg, params, jax.random.key(0),
                                   mode=mode, max_slots=slots,
                                   max_len=max_len, decode_jit=False,
                                   integrity="paranoid")
        rids = [eng.submit(p, max_new_tokens=n_new) for p in prompts]
        with comm.ledger() as led:
            if injector is None:
                outs, stats = eng.run_to_completion()
            else:
                with faults.inject(injector):
                    outs, stats = eng.run_to_completion()
        return rids, outs, stats, led, eng

    rids, base, _, _, _ = serve()
    out = {}
    for name, spec in CHAOS_PLANS:
        spec = dict(spec)  # CHAOS_PLANS stays reusable
        inj = faults.FaultInjector(
            faults.FaultPlan(spec.pop("kind"), **spec))
        rids, outs, stats, led, eng = serve(inj)
        assert inj.fired, f"{mode}/{name}: plan never fired"
        statuses = {}
        for r in rids:
            st = stats[r]
            statuses[st["status"]] = statuses.get(st["status"], 0) + 1
            if st["status"] in ("failed", "quarantined"):
                assert r not in outs, f"{mode}/{name}: delivered a " \
                    f"failed request"
            elif st["status"] == "ok":
                assert outs[r] == base[r], \
                    f"{mode}/{name}: unaffected request diverged"
        assert sum(s["online_bits"] for s in stats.values()) \
            == led.total_bits(), f"{mode}/{name}: conservation broke"
        assert all(s is None for s in eng.slots), \
            f"{mode}/{name}: stuck slot"
        out[name] = {"fired": len(inj.fired), "statuses": statuses,
                     "survived_faults": eng.health()["faults"]}
        print(f"[private-serving] chaos {mode}/{name}: "
              f"fired {len(inj.fired)}, statuses {statuses}")
    return out


def run(slot_counts=(1, 2, 4), n_requests: int = 8, n_new: int = 6,
        max_len: int = 24, rounds: int = 2, out: str | None = OUT,
        smoke: bool = False, modes=MODES, mixed: bool | None = None,
        uniform: bool = True, long_prompts: bool | None = None,
        chunk_size: int = 4, chaos: bool = False,
        paged: bool | None = None, prefix_cache: bool | None = None,
        page_size: int = 4, transport: bool | None = None,
        rtts=TRANSPORT_RTTS):
    from repro.configs.paper_models import GPT2_TINY as CFG
    from repro.models.registry import get_api

    if mixed is None:
        mixed = not smoke   # full runs always measure realistic traffic
    if long_prompts is None:
        long_prompts = not smoke
    if paged is None:
        paged = not smoke
    if prefix_cache is None:
        prefix_cache = not smoke
    if transport is None:
        transport = not smoke
    if smoke:
        n_requests, n_new, rounds = 4, 3, 2
        slot_counts = (1, 4)
    key = jax.random.key(0)
    params = get_api(CFG).init_params(CFG, key)
    prompts = _prompts(n_requests)

    results = {"config": CFG.name, "n_requests": n_requests,
               "n_new": n_new, "max_len": max_len, "modes": {}}
    if uniform:
        for mode in modes:
            results["modes"][mode] = run_mode(
                mode, CFG, params, prompts, slot_counts=slot_counts,
                n_new=n_new, max_len=max_len, rounds=rounds)
        ratio = _speedup_ratio(results["modes"])
        if ratio is not None:
            results["centaur_vs_smpc_tokens_per_sec"] = ratio
            print(f"[private-serving] centaur vs smpc (identical "
                  f"serving conditions): {ratio}x tokens/sec")
    if mixed:
        mslots = max(slot_counts)
        results["mixed_lengths"] = {
            mode: run_mixed(mode, CFG, params,
                            _mixed_prompts(n_requests, max_len),
                            slots=mslots, n_new=n_new, max_len=max_len,
                            rounds=rounds)
            for mode in modes}
        mm = results["mixed_lengths"]
        if "centaur" in mm and "smpc" in mm \
                and mm["smpc"]["tokens_per_sec"] > 0:
            r = round(mm["centaur"]["tokens_per_sec"]
                      / mm["smpc"]["tokens_per_sec"], 3)
            results["centaur_vs_smpc_tokens_per_sec_mixed"] = r
            print(f"[private-serving] centaur vs smpc under "
                  f"mixed-length traffic: {r}x tokens/sec")
    if chaos:
        results["chaos"] = {
            mode: run_chaos(mode, CFG, params, prompts,
                            slots=max(slot_counts), n_new=n_new,
                            max_len=max_len)
            for mode in modes}
    if long_prompts:
        # every servable mode: with persistent weight masks (DESIGN.md
        # §12) the smpc-family chunk program no longer re-opens weight
        # masks per chunk, so the chunked-vs-bucketed comm win holds —
        # and is asserted — for the baselines too
        results["long_prompts"] = {
            mode: run_long(mode, CFG, params,
                           _long_prompts(n_requests, max_len),
                           slots=max(slot_counts), n_new=n_new,
                           max_len=max_len, rounds=rounds,
                           chunk_size=chunk_size)
            for mode in modes}
    if paged:
        # the paged engine serves a DOUBLE-length slot context: dense
        # must reserve max_slots * 2*max_len rows up front for the
        # same admission guarantee, while paging allocates only the
        # pages the realistic length mix actually touches — that gap
        # is the live-page memory ratio the gate holds <= 0.5x
        results["paged"] = {
            mode: run_paged(mode, CFG, params,
                            _mixed_prompts(n_requests, max_len),
                            slots=4, n_new=n_new,
                            max_len=2 * max_len,
                            chunk_size=chunk_size,
                            page_size=page_size)
            for mode in modes}
    if prefix_cache:
        results["prefix_cache"] = {
            mode: run_prefix_cache(mode, CFG, params, max_len=max_len,
                                   chunk_size=chunk_size,
                                   page_size=page_size)
            for mode in modes}
    if transport:
        results["transport"] = run_transport(
            modes, CFG, params, prompts, slots=2, n_new=n_new,
            max_len=max_len, rtts=rtts)
    if out:
        # read-update-write: a focused run (e.g. --transport-bench)
        # refreshes only its own sections; the closed-form numbers of
        # prior full runs stay alongside the measured ones
        data = {}
        if os.path.exists(out):
            try:
                with open(out) as f:
                    data = json.load(f)
            except (OSError, json.JSONDecodeError):
                data = {}
        data.update(results)
        with open(out, "w") as f:
            json.dump(data, f, indent=1)
        print(f"[private-serving] wrote {os.path.abspath(out)}")
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run; skips writing the json")
    ap.add_argument("--mode", default=",".join(MODES),
                    help="comma-separated PPTI modes to serve "
                         "(default: centaur,smpc)")
    wl = ap.add_mutually_exclusive_group()
    wl.add_argument("--mixed-lengths", action="store_true",
                    help="serve the mixed-length workload through the "
                         "bucketed prefill path (always on for full "
                         "runs; use with --smoke for the CI "
                         "recompile-regression check)")
    wl.add_argument("--long-prompts", action="store_true",
                    help="serve the long-prompt workload through the "
                         "chunked prefill path vs the bucket ladder "
                         "(always on for full runs; use with --smoke "
                         "for the CI 1-chunk-program check)")
    wl.add_argument("--uniform-only", action="store_true",
                    help="skip the mixed-length/long-prompt workloads")
    wl.add_argument("--inject-faults", action="store_true",
                    help="chaos smoke (DESIGN.md §11): serve under "
                         "each representative fault plan with paranoid "
                         "guards armed and assert the robustness "
                         "contract (token-identical or quarantined, "
                         "exact partial comm, no stuck slots)")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV-cache workload (DESIGN.md §13): "
                         "dense-vs-paged token parity, the <= 0.5x "
                         "live-page memory gate and the >= 1.5x "
                         "batched-admission gate at 4 mixed-length "
                         "arrivals (always on for full runs; use with "
                         "--smoke for the CI paging check)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="shared-prefix COW caching: hit-vs-miss token "
                         "parity and the saved-online-bits gate "
                         "(>= the prefix share of prefill chunk bits; "
                         "always on for full runs)")
    ap.add_argument("--transport-bench", action="store_true",
                    help="measured tok/s over the real socket "
                         "transport at each --rtt-ms point, loopback "
                         "token parity and the centaur-flatter-than-"
                         "smpc RTT-degradation gate (always on for "
                         "full runs; with --smoke it focuses and "
                         "shrinks for CI)")
    ap.add_argument("--rtt-ms", default=None,
                    help="comma-separated injected RTTs (ms) for the "
                         "transport bench (default 0,1,10; smoke "
                         "default 0,2)")
    ap.add_argument("--page-size", type=int, default=4,
                    help="KV page size in rows; must be a multiple of "
                         "--chunk-size and divide max_len")
    ap.add_argument("--chunk-size", type=int, default=4,
                    help="chunk size for the long-prompt workload; "
                         "must divide max_len, and the comm win over "
                         "bucketing needs C << max_len (the tail chunk "
                         "pads S up to ceil(S/C)*C rows)")
    ap.add_argument("--out", default=OUT)
    args = ap.parse_args(argv)
    modes = tuple(m.strip() for m in args.mode.split(",") if m.strip())
    # a workload flag FOCUSES only under --smoke (the CI regression
    # checks); full runs always measure every workload so the written
    # BENCH json never silently drops a section
    focused = args.smoke and (args.mixed_lengths or args.long_prompts
                              or args.inject_faults or args.paged
                              or args.prefix_cache
                              or args.transport_bench)
    if args.rtt_ms is not None:
        rtts = tuple(float(x) for x in args.rtt_ms.split(","))
    else:
        rtts = (0.0, 2.0) if args.smoke else TRANSPORT_RTTS
    run(out=None if args.smoke else args.out, smoke=args.smoke,
        modes=modes,
        mixed=(False if args.uniform_only or args.inject_faults
               else True if args.mixed_lengths
               else False if focused else None),
        long_prompts=(False if args.uniform_only or args.inject_faults
                      else True if args.long_prompts
                      else False if focused else None),
        uniform=not focused, chunk_size=args.chunk_size,
        chaos=args.inject_faults,
        paged=(True if args.paged
               else False if focused or args.uniform_only
               or args.inject_faults else None),
        prefix_cache=(True if args.prefix_cache
                      else False if focused or args.uniform_only
                      or args.inject_faults else None),
        page_size=args.page_size,
        transport=(True if args.transport_bench
                   else False if focused or args.uniform_only
                   or args.inject_faults else None),
        rtts=rtts)


if __name__ == "__main__":
    main()
