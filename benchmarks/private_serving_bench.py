"""Continuous-batching private decode benchmark (DESIGN.md §7).

Serves the same request set through the slot-based PrivateServingEngine
at slots ∈ {1, 2, 4} on the tiny dense config and reports warm
tokens/sec — slots=1 is the sequential baseline (same code path, batch
of one).  Each engine serves a warm-up round first so jit compiles and
triple-generator programs are excluded from the timed round; token
outputs are cross-checked against the sequential run on every setting.

    PYTHONPATH=src python benchmarks/private_serving_bench.py [--smoke]

Writes BENCH_private_serving.json next to the repo root.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

OUT = os.path.join(os.path.dirname(__file__), "..",
                   "BENCH_private_serving.json")


def _prompts(n_requests: int):
    # deterministic mixed lengths (2..5) — staggered admissions at
    # every slot count
    return [[(3 * i + j) % 300 + 1 for j in range(2 + i % 4)]
            for i in range(n_requests)]


def run(slot_counts=(1, 2, 4), n_requests: int = 8, n_new: int = 6,
        max_len: int = 24, rounds: int = 2, out: str | None = OUT,
        smoke: bool = False):
    from repro.configs.paper_models import GPT2_TINY as CFG
    from repro.models.registry import get_api
    from repro.serving.engine import PrivateServingEngine

    if smoke:
        n_requests, n_new, rounds = 4, 3, 2
    key = jax.random.key(0)
    params = get_api(CFG).init_params(CFG, key)
    prompts = _prompts(n_requests)

    results = {"config": CFG.name, "n_requests": n_requests,
               "n_new": n_new, "max_len": max_len, "slots": {}}
    baseline_tokens = None
    for slots in slot_counts:
        eng = PrivateServingEngine(CFG, params, key, max_slots=slots,
                                   max_len=max_len)
        for _ in range(rounds):            # last round is the warm one
            rids = [eng.submit(p, max_new_tokens=n_new)
                    for p in prompts]
            t0 = time.monotonic()
            outs, stats = eng.run_to_completion()
            dt = time.monotonic() - t0
        tokens = [outs[r] for r in rids]
        if baseline_tokens is None:
            baseline_tokens = tokens
        assert tokens == baseline_tokens, \
            f"slots={slots} changed the decoded tokens"
        total = sum(len(t) for t in tokens)
        per_req = [stats[r] for r in rids]
        results["slots"][str(slots)] = {
            "tokens": total,
            "time_s": round(dt, 4),
            "tokens_per_sec": round(total / dt, 2),
            "online_bits_total": sum(s["online_bits"] for s in per_req),
            "rounds_total": sum(s["rounds"] for s in per_req),
        }
        print(f"[private-serving] slots={slots}: "
              f"{total / dt:.2f} tok/s warm ({total} tokens, {dt:.2f}s)")

    seq = results["slots"].get("1")
    if seq:
        for slots, r in results["slots"].items():
            r["speedup_vs_sequential"] = round(
                r["tokens_per_sec"] / seq["tokens_per_sec"], 3)
        best = max(r["speedup_vs_sequential"]
                   for r in results["slots"].values())
        print(f"[private-serving] best speedup vs sequential: {best}x")
    if out:
        with open(out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"[private-serving] wrote {os.path.abspath(out)}")
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run; skips writing the json")
    ap.add_argument("--out", default=OUT)
    args = ap.parse_args(argv)
    run(out=None if args.smoke else args.out, smoke=args.smoke)


if __name__ == "__main__":
    main()
