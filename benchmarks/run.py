"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.  Run with
``PYTHONPATH=src python -m benchmarks.run``."""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (comm_protocols, comm_volume, kernel_bench, latency_sim,
                   performance_parity, privacy_attack, roofline,
                   secure_matmul_bench)

    suites = [
        ("table1_comm_protocols", comm_protocols.run),
        ("fig7_comm_volume", comm_volume.run),
        ("fig8_latency_sim", latency_sim.run),
        ("table3_performance_parity", performance_parity.run),
        ("table2_privacy_attack", privacy_attack.run),
        ("kernels", kernel_bench.run),
        ("roofline", roofline.run),
        # full sizes via `python -m benchmarks.secure_matmul_bench --full`
        ("secure_matmul", lambda: secure_matmul_bench.run(sizes=(512,))),
    ]
    failed = []
    for name, fn in suites:
        print(f"# ==== {name} ====", flush=True)
        try:
            fn()
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"# FAILED suites: {failed}")
        sys.exit(1)
    print("# all benchmark suites passed")


if __name__ == "__main__":
    main()
