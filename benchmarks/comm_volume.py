"""Paper Fig 3 / Fig 7: total + per-layer-kind communication volume for
BERT_BASE/LARGE and GPT-2_BASE/LARGE under each PPTI mode.

Full-size models are traced with jax.eval_shape — the ledger only needs
static shapes, so no 100M-parameter arrays are ever materialized."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import comm
from repro.core.private_model import build_private_model, private_forward
from repro.models.registry import get_api

from .common import emit

SEQ = 128
MODES = ("centaur", "smpc", "mpcformer", "secformer")
MODELS = ("bert-base", "bert-large", "gpt2-base", "gpt2-large")


def trace_comm(cfg, mode: str, seq: int = SEQ):
    api = get_api(cfg)

    def f():
        params = api.init_params(cfg, jax.random.key(0))
        pm = build_private_model(cfg, params, jax.random.key(1), mode)
        tokens = jnp.zeros((1, seq), jnp.int32)
        private_forward(pm, tokens)

    with comm.ledger() as led:
        jax.eval_shape(f)
    return led


def run(models=MODELS, modes=MODES, seq=SEQ):
    results = {}
    for name in models:
        cfg = get_config(name)
        per_mode = {}
        for mode in modes:
            led = trace_comm(cfg, mode, seq)
            per_mode[mode] = {
                "total_GB": led.total_bytes() / 1e9,
                "rounds": led.total_rounds(),
                "by_tag": {t: v["bits"] / 8e9
                           for t, v in led.by_tag().items()},
            }
            emit(f"fig7/{name}/{mode}", 0.0,
                 f"GB={per_mode[mode]['total_GB']:.3f};"
                 f"rounds={per_mode[mode]['rounds']}")
        base = per_mode[modes[0]]["total_GB"]
        for mode in modes[1:]:
            ratio = per_mode[mode]["total_GB"] / max(base, 1e-12)
            emit(f"fig7/{name}/reduction_vs_{mode}", 0.0,
                 f"centaur_x{ratio:.1f}_less")
        results[name] = per_mode
    return results


if __name__ == "__main__":
    run()
