"""Paper Table 3: Centaur matches plaintext exactly (no approximation),
MPCFormer-style substitution does not.

Without GLUE checkpoints, parity is shown as (a) logits equivalence
within fixed-point tolerance, (b) 100% argmax agreement on a synthetic
classification task, (c) perplexity identity on a synthetic LM stream —
the function computed is the same, which is the paper's claim."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_models import BERT_TINY, GPT2_TINY
from repro.core.private_model import build_private_model, private_forward
from repro.models.registry import get_api

from .common import emit

KEY = jax.random.key(3)


def run(seq=24, batch=4):
    results = {}
    for cfg in (BERT_TINY, GPT2_TINY):
        api = get_api(cfg)
        params = api.init_params(cfg, KEY)
        tokens = jax.random.randint(KEY, (batch, seq), 0, cfg.vocab_size)
        if cfg.family == "encoder":
            from repro.models.transformer import encoder_classify
            plain = encoder_classify(cfg, params, {"tokens": tokens})
        else:
            hidden, _, _ = api.forward(cfg, params, {"tokens": tokens})
            from repro.models import layers as L
            plain = L.lm_head(cfg, params.get("head", {}),
                              params["embed"], hidden)
        per_mode = {}
        for mode in ("centaur", "smpc", "mpcformer", "permute"):
            pm = build_private_model(cfg, params, KEY, mode=mode)
            out = np.asarray(private_forward(pm, tokens))
            p = np.asarray(plain)
            err = float(np.max(np.abs(out - p)))
            agree = float((out.argmax(-1) == p.argmax(-1)).mean())
            per_mode[mode] = {"max_err": err, "argmax_agree": agree}
            emit(f"table3/{cfg.name}/{mode}", 0.0,
                 f"max_abs_err={err:.4f};argmax_agree={agree:.3f}")
        # the paper's claims, as assertions:
        assert per_mode["centaur"]["argmax_agree"] == 1.0
        assert per_mode["centaur"]["max_err"] < 0.1
        assert per_mode["mpcformer"]["max_err"] > \
            per_mode["centaur"]["max_err"]
        results[cfg.name] = per_mode

        if cfg.family != "encoder":  # synthetic perplexity identity
            logz = jax.nn.logsumexp(jnp.asarray(plain), -1)
            gold = jnp.take_along_axis(
                jnp.asarray(plain), jnp.roll(tokens, -1, -1)[..., None],
                -1)[..., 0]
            ppl_plain = float(jnp.exp(jnp.mean(logz - gold)))
            pm = build_private_model(cfg, params, KEY, mode="centaur")
            out = jnp.asarray(private_forward(pm, tokens))
            logz = jax.nn.logsumexp(out, -1)
            gold = jnp.take_along_axis(
                out, jnp.roll(tokens, -1, -1)[..., None], -1)[..., 0]
            ppl_c = float(jnp.exp(jnp.mean(logz - gold)))
            emit(f"table3/{cfg.name}/perplexity", 0.0,
                 f"plain={ppl_plain:.2f};centaur={ppl_c:.2f}")
            assert abs(ppl_plain - ppl_c) / ppl_plain < 0.02
    return results


if __name__ == "__main__":
    run()
