"""Paper Fig 8 / Appendix C: end-to-end PPTI latency under LAN/WAN.

Model: time = compute + bits/bandwidth + rounds * RTT.
  * comm terms come from the exact ledger (comm_volume traces),
  * compute comes from a measured plaintext forward of the same model on
    this host, scaled by a mode-specific factor kappa measured on a tiny
    model (centaur: int64 ScalMuls + reshares; smpc: 3x Beaver matmul
    work + iterative approximations).  kappa is measured, not assumed —
    see _measure_kappa().

The deliverable is the *relative* speedup structure (paper: 5.0-30.4x
vs SMPC baselines), which is communication-dominated in WAN and hence
robust to the compute model.

This file stays closed-form on purpose.  For MEASURED wall-clock under
injected RTT — payload bytes actually moving through a peer process
over TCP (DESIGN.md §14) — see private_serving_bench.py
--transport-bench; its `transport` block reports tok/s per RTT next to
this model's analytic_network_s so the two can be cross-checked."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.paper_models import BERT_TINY
from repro.core.private_model import build_private_model, private_forward
from repro.models.registry import get_api

from .common import NETWORKS, emit, time_call
from .comm_volume import trace_comm

MODES = ("centaur", "smpc", "mpcformer", "secformer")


def _measure_kappa(modes=MODES):
    """private-forward / plaintext-forward wall-time ratio (tiny model)."""
    cfg = BERT_TINY
    api = get_api(cfg)
    params = api.init_params(cfg, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (1, 32), 0,
                                cfg.vocab_size)

    def plain():
        from repro.models.transformer import encoder_classify
        return encoder_classify(cfg, params, {"tokens": tokens})

    t_plain = time_call(jax.jit(plain))
    out = {}
    for mode in modes:
        pm = build_private_model(cfg, params, jax.random.key(2), mode)

        def priv():
            # per-layer jitted hot path (fused Beaver online phase,
            # pool-fed triples); embedding/head run eagerly — this is
            # the serving configuration, so kappa measures it.
            return private_forward(pm, tokens, jit=True)

        out[mode] = max(time_call(priv) / max(t_plain, 1e-9), 1.0)
    return out, t_plain


def _measure_plain_forward(cfg, seq: int):
    api = get_api(cfg)
    params = api.init_params(cfg, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (1, seq), 0,
                                cfg.vocab_size)

    if cfg.family == "encoder":
        from repro.models.transformer import encoder_classify
        fn = jax.jit(lambda: encoder_classify(cfg, params,
                                              {"tokens": tokens}))
    else:
        fn = jax.jit(lambda: api.train_loss(
            cfg, params, {"tokens": tokens, "labels": tokens}))
    return time_call(fn) / 1e6  # seconds


def run(models=("bert-base", "gpt2-base"), seq=128):
    kappa, _ = _measure_kappa()
    results = {}
    for name in models:
        cfg = get_config(name)
        t_plain = _measure_plain_forward(cfg, seq)
        per_mode = {}
        for mode in MODES:
            led = trace_comm(cfg, mode, seq)
            compute = t_plain * kappa[mode]
            per_net = {}
            for net, (bw, rtt) in NETWORKS.items():
                t = compute + led.simulate_time(bw, rtt)
                per_net[net] = t
                emit(f"fig8/{name}/{mode}/{net}", t * 1e6,
                     f"compute_s={compute:.2f};"
                     f"comm_GB={led.total_bytes()/1e9:.2f};"
                     f"rounds={led.total_rounds()}")
            per_mode[mode] = per_net
        for net in NETWORKS:
            for base in ("smpc", "mpcformer", "secformer"):
                sp = per_mode[base][net] / per_mode["centaur"][net]
                emit(f"fig8/{name}/speedup_vs_{base}/{net}", 0.0,
                     f"{sp:.1f}x")
        results[name] = per_mode
    return results


if __name__ == "__main__":
    run()
