"""Secure-GEMM microbenchmark: fused vs unfused Beaver online phase.

Times the *online* combine Z = E@F + E@B + A@F + C (the protocol work
left after the one-round opening of E and F) for square n x n operands,
comparing three variants (DESIGN.md §4):

  * fused       — ONE leading-dim-2 GEMM dispatch carrying both
                  parties' block GEMMs [E|A_i]@[B_i(+F);F], E@F folded
                  into party 1's block (4n^3 MACs);
  * fused_stack — the 2-block GEMM stack + a separate E@F (2 dispatches
                  instead of 5);
  * unfused     — the textbook 5-GEMM reference (5n^3 MACs);

plus the vectorized TriplePool offline phase against the lazy per-call
dealer.  All variants are asserted bit-identical under the same triple.

GEMM-dispatch counts come from ring.matmul_dispatches deltas measured
at trace time (shapes are static, so one trace == one call's dispatch
schedule).  Emits a BENCH_secure_matmul.json trajectory entry.

    PYTHONPATH=src python -m benchmarks.secure_matmul_bench [--full]
"""
from __future__ import annotations

import sys

import jax

from repro.core import beaver, comm, ring
from repro.core.sharing import share

from .common import emit, time_call, write_json

# default sizes keep a CPU run to ~a minute; --full adds the paper-scale
# points (hours of int64 GEMM time off-TPU)
SIZES = (512, 1024)
FULL_SIZES = SIZES + (2048, 4096)


def _setup(n: int, key):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    x = share(k1, ring.rand_ring(k2, (n, n)))
    y = share(k3, ring.rand_ring(k4, (n, n)))
    with comm.muted():
        triple = beaver.TripleDealer(k5).matmul_triple(x.shape, y.shape)
    a, b, _ = triple
    e = jax.block_until_ready((x - a).s0 + (x - a).s1)
    f = jax.block_until_ready((y - b).s0 + (y - b).s1)
    return e, f, triple


def _count_gemms(fn, *args) -> int:
    """GEMM dispatches issued by one abstract trace of fn."""
    before = ring.matmul_dispatches
    jax.eval_shape(fn, *args)
    return ring.matmul_dispatches - before


def run(sizes=SIZES, offline_batch: int = 4):
    sink = []
    key = jax.random.key(0)
    for n in sizes:
        e, f, (a, b, c) = _setup(n, key)

        variants = {
            # one leading-dim-2 dispatch, E@F folded: 2 block GEMMs
            "fused": jax.jit(lambda e_, f_: beaver.matmul_online(
                e_, f_, a, b, c, fused=True)),
            # the 2-GEMM block stack + separate E@F (2 dispatches)
            "fused_stack": jax.jit(lambda e_, f_: beaver.matmul_online(
                e_, f_, a, b, c, fused="stack")),
            # textbook 5-GEMM reference
            "unfused": jax.jit(lambda e_, f_: beaver.matmul_online(
                e_, f_, a, b, c, fused=False)),
        }
        times, ref = {}, None
        for name, fn in variants.items():
            g = _count_gemms(fn, e, f)
            times[name] = time_call(fn, e, f)
            z = fn(e, f)
            if ref is None:
                ref = z
            else:  # bit-exactness under the same triple (exact ring adds)
                assert bool((z.s0 == ref.s0).all()
                            and (z.s1 == ref.s1).all()), \
                    f"{name} mismatch at n={n}"
            block = {"fused": "2(+EF folded)", "fused_stack": "2(+1 EF)",
                     "unfused": "5"}[name]
            emit(f"secure_matmul/online_{name}/n{n}", times[name],
                 f"dispatches={g};block_gemms={block}", sink)
        emit(f"secure_matmul/online_speedup/n{n}", 0.0,
             f"fused={times['unfused'] / times['fused']:.2f}x;"
             f"stack={times['unfused'] / times['fused_stack']:.2f}x",
             sink)

        # offline phase: vectorized pool batch vs lazy per-call dealer
        spec = beaver._canon_spec(("matmul", (n, n), (n, n)))
        pool = beaver.TriplePool(key, batch=offline_batch)

        def pool_batch():
            with comm.muted():
                pool.generate(spec, offline_batch)
            store = pool._pools[spec]
            jax.block_until_ready(store[-1][0].s0)
            store.clear()  # don't accumulate across timing iterations

        def dealer_lazy():
            d = beaver.TripleDealer(key)
            with comm.muted():
                last = None
                for _ in range(offline_batch):
                    last = d.matmul_triple((n, n), (n, n))
            jax.block_until_ready(last[0].s0)

        t_pool = time_call(pool_batch, warmup=1, iters=3)
        t_lazy = time_call(dealer_lazy, warmup=1, iters=3)
        emit(f"secure_matmul/offline_pool_batch{offline_batch}/n{n}",
             t_pool, f"{t_lazy / t_pool:.2f}x vs lazy dealer", sink)
        emit(f"secure_matmul/offline_lazy_dealer/n{n}", t_lazy, "", sink)

    write_json("BENCH_secure_matmul.json", sink)
    return sink


if __name__ == "__main__":
    run(FULL_SIZES if "--full" in sys.argv else SIZES)
