"""Paper Table 1: per-protocol communication (rounds, bits).

Runs each Centaur protocol on n x n operands, reads the ledger, and
asserts the closed-form costs the paper reports."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import beaver, comm, nonlinear, permute, protocols, ring
from repro.core.sharing import share_float

from .common import emit, time_call

N = 64
KEY = jax.random.key(0)


def run():
    k1, k2, k3 = jax.random.split(KEY, 3)
    x = share_float(k1, jax.random.normal(k1, (N, N)))
    y = share_float(k2, jax.random.normal(k2, (N, N)))
    w = ring.encode(jax.random.normal(k3, (N, N)))
    dealer = beaver.TripleDealer(k3)
    p = permute.gen_perm(k3, N)

    cases = {
        "Pi_Add": (lambda: x + y, 0, 0),
        "Pi_ScalMul": (lambda: protocols.scal_mul(w, x), 0, 0),
        "Pi_MatMul": (lambda: beaver.matmul(x, y, dealer), 1, 256 * N * N),
        "Pi_PPP": (lambda: protocols.pp_permute(x, p), 1, 256 * N * N),
        "Pi_PPSM": (lambda: nonlinear.pp_softmax(x, k1), 2, 128 * N * N),
        "Pi_PPGeLU": (lambda: nonlinear.pp_gelu(x, k1), 2, 128 * N * N),
        "Pi_PPLN": (lambda: nonlinear.pp_layernorm(
            x, jnp.ones((N,)), jnp.zeros((N,)), k1), 2, 128 * N * N),
    }
    rows = []
    for name, (fn, want_rounds, want_bits) in cases.items():
        with comm.ledger() as led:
            fn()
        rounds, bits = led.total_rounds(), led.total_bits()
        assert rounds == want_rounds, (name, rounds, want_rounds)
        assert bits == want_bits, (name, bits, want_bits)
        us = time_call(fn)
        emit(f"table1/{name}", us,
             f"rounds={rounds};bits={bits};paper_match=exact")
        rows.append((name, rounds, bits))
    return rows


if __name__ == "__main__":
    run()
