"""Shared benchmark utilities."""
from __future__ import annotations

import time

import jax


def time_call(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time in microseconds."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}")


# network settings from the paper §7.1
NETWORKS = {
    "LAN(3Gbps,0.8ms)": (3e9, 0.8e-3),
    "WAN(200Mbps,40ms)": (200e6, 40e-3),
    "WAN(100Mbps,80ms)": (100e6, 80e-3),
}
