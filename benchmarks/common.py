"""Shared benchmark utilities."""
from __future__ import annotations

import json
import time

import jax


def time_call(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time in microseconds."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us: float, derived: str = "", sink: list | None = None):
    """Print one trajectory entry; optionally collect it into `sink`
    (a list later flushed to a BENCH_*.json file via write_json)."""
    print(f"{name},{us:.1f},{derived}")
    if sink is not None:
        sink.append({"name": name, "us": us, "derived": derived})


def write_json(path: str, entries: list):
    """Flush emit()-collected entries as a JSON trajectory file."""
    with open(path, "w") as f:
        json.dump(entries, f, indent=1)
    print(f"# wrote {path} ({len(entries)} entries)")


# network settings from the paper §7.1
NETWORKS = {
    "LAN(3Gbps,0.8ms)": (3e9, 0.8e-3),
    "WAN(200Mbps,40ms)": (200e6, 40e-3),
    "WAN(100Mbps,80ms)": (100e6, 80e-3),
}
